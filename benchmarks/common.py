"""Shared benchmark plumbing: results dir, CSV/JSON emitters, trained-net
cache (several figures reuse the same trained nets)."""

from __future__ import annotations

import json
import os
import time
from typing import Any

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def results_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


def emit(table: str, rows: list[dict[str, Any]], keys: list[str]) -> None:
    """Print CSV to stdout and persist JSON under results/."""
    print(f"\n# {table}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    with open(results_path(f"{table}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}] {time.perf_counter() - self.t0:.1f}s")
