"""Shared benchmark plumbing: results dir, CSV/JSON emitters, trained-net
cache (several figures reuse the same trained nets)."""

from __future__ import annotations

import json
import os
import time
from typing import Any

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def results_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


def emit(table: str, rows: list[dict[str, Any]], keys: list[str]) -> None:
    """Print CSV to stdout and persist JSON under results/."""
    print(f"\n# {table}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    with open(results_path(f"{table}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}] {time.perf_counter() - self.t0:.1f}s")


def gen_requests(
    vocab: int,
    n: int,
    *,
    seed: int = 0,
    len_lo: int = 4,
    len_hi: int = 12,
    max_new: int = 8,
    temperature: float = 0.0,
    uid_base: int = 0,
):
    """Shared serving-bench request generation (one path for all benches)."""
    import numpy as np

    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            uid=uid_base + i,
            prompt=rng.integers(
                1, vocab, size=int(rng.integers(len_lo, len_hi + 1))
            ).astype(np.int32),
            max_new_tokens=max_new,
            temperature=temperature,
        )
        for i in range(n)
    ]


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0):
    """Cumulative Poisson-process arrival offsets (seconds), length n."""
    import numpy as np

    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))
