"""§Perf hillclimb driver: measure the three chosen cells, baseline vs
optimized variants, and emit the before/after table for EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.hillclimb

Variants (launch/dryrun.py):
  sp   : sequence-parallel residual stream
  moe  : MoE local-groups dispatch (layout-preserving split + vmap)
  q8   : PQS int8 QTensor weights + serve-mode sharding (decode)
Baseline rows lower the same cells with default flags. All cells include
the always-on fixes (vocab-table sharding, pinned activation shardings,
GQA-native attention) — the *original* pre-fix baselines are archived in
results/dryrun_single.json from the first sweep.
"""

from __future__ import annotations

import json

from repro.launch.dryrun import run_cell

from benchmarks.common import results_path

PEAK, HBM, LINK = 197e12, 819e9, 50e9

CELLS = [
    ("qwen2-vl-72b", "train_4k", None, "sp"),
    ("granite-moe-3b-a800m", "prefill_32k", None, "sp+moe"),
    ("qwen3-32b", "decode_32k", None, "q8"),
]


def terms(cell: dict) -> dict:
    c = cell["collectives"]
    d = cell.get("derived", {})
    return {
        "compute_s": d.get("flops_per_device", 0) / PEAK,
        "memory_s": d.get("bytes_per_device", 0) / HBM,
        "collective_s": c["total_link_bytes_per_device"] / LINK,
        "peak_bytes": cell["memory"]["peak_bytes"],
    }


def run() -> list[dict]:
    rows = []
    for arch, shape, base_v, opt_v in CELLS:
        base = run_cell(arch, shape, False, variant=base_v)
        opt = run_cell(arch, shape, False, variant=opt_v)
        tb, to = terms(base), terms(opt)
        rows.append({
            "arch": arch, "shape": shape, "variant": opt_v,
            "base": tb, "opt": to,
            "collective_x": tb["collective_s"] / max(to["collective_s"], 1e-12),
            "memory_x": tb["memory_s"] / max(to["memory_s"], 1e-12),
        })
    with open(results_path("hillclimb.json"), "w") as f:
        json.dump(rows, f, indent=1)

    print("\n| cell | variant | term | baseline s | optimized s | x |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        for t in ("compute_s", "memory_s", "collective_s"):
            x = r["base"][t] / max(r["opt"][t], 1e-12)
            print(f"| {r['arch']} {r['shape']} | {r['variant']} | "
                  f"{t[:-2]} | {r['base'][t]:.3e} | {r['opt'][t]:.3e} "
                  f"| {x:.2f} |")
    return rows


if __name__ == "__main__":
    run()
