"""Kernel-level structural benchmark (no TPU: interpret mode wall-time is
meaningless, so this reports the quantities that determine TPU speed).

Per kernel configuration:
  - VMEM working set per grid step (must be << 128 MiB on v5e)
  - arithmetic intensity (flops per HBM byte) against the v5e ridge point
    (197e12 / 819e9 ~= 241 flop/byte)
  - HBM bytes per output element vs the dense int8 baseline (the N:M and
    narrow-accumulator bandwidth story, DESIGN.md §2)
  - bit-exactness spot check vs the ref.py oracle (fails loudly here, not
    just in tests)
"""

from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core.dispatch import pqs_dot
from repro.core.pruning import nm_prune_mask
from repro.kernels import ops, ref

from benchmarks.common import emit

RIDGE = 197e12 / 819e9


def _sorted_rows():
    rows = []
    for bm, bn, bk in ((8, 128, 256), (8, 128, 512), (16, 128, 256)):
        vmem = (bm * bk + bn * bk) * 1 + bm * bn * bk * 4 + bm * bn * 4
        m = k = 1024
        n = 512
        flops = 2 * m * n * k  # products+adds (sort stages add ~log2^2(bk) VPU ops)
        sort_ops = m * n * k * (np.log2(bk) ** 2)  # compare-exchange ops
        hbm = m * k + n * k + m * n * 4  # int8 in, int32 out
        rows.append({
            "kernel": "sorted_matmul", "block": f"{bm}x{bn}x{bk}",
            "vmem_kib": round(vmem / 1024, 1),
            "flops_per_byte": round(flops / hbm, 1),
            "vpu_sort_ops_per_mxu_flop": round(sort_ops / flops, 2),
            "hbm_bytes_per_out": round(hbm / (m * n), 2),
        })
    return rows


def _nm_rows():
    rows = []
    for n_keep, m_group in ((4, 16), (8, 16), (2, 16)):
        m = k = 1024
        n = 512
        nm_hbm = m * k + 2 * n * (k // m_group) * n_keep + m * n * 4
        rows.append({
            "kernel": "nm_spmm", "block": f"{n_keep}:{m_group}",
            "vmem_kib": round((128 * 32 * 16 + 128 * 32 * n_keep * 5
                               + 128 * 128 * 4) / 1024, 1),
            "flops_per_byte": round(2 * m * n * k / nm_hbm, 1),
            "weight_bytes_vs_dense": round(
                (2 * n * (k // m_group) * n_keep) / (n * k), 3),
            "hbm_bytes_per_out": round(nm_hbm / (m * n), 2),
        })
    return rows


def _vmem_bytes(kernel: str, bm: int, bn: int, k: int, k_tile: int) -> int:
    """Per-grid-step VMEM working set of the sort kernels (the quantity
    that decides whether a K compiles at all)."""
    n_tiles = max(k // k_tile, 1)
    if kernel == "onepass":  # product cube fully resident
        return (bm + bn) * k + bm * bn * k * 4 + bm * bn * 4
    # twopass: int8 slabs + perm block + interleaved working pair
    return ((bm + bn) * k + bm * bn * n_tiles * 4
            + bm * bn * 2 * k_tile * 4 + bm * bn * 4)


def _time_us(fn, reps: int) -> float:
    from repro.kernels.autotune import measure_us  # one timing protocol

    return measure_us(fn, reps)


def bench_kernels(quick: bool = False) -> list[dict]:
    """One-pass vs two-pass sort kernels and tuned vs static blocks over
    an (M, N, K) sweep -> BENCH_kernels.json.

    On CPU the kernels run interpret mode, so absolute wall-times are
    NOT TPU predictions — they are recorded to seed the perf trajectory
    (the same harness on a TPU runner produces honest numbers) alongside
    the structural VMEM working sets, which are platform truths. The
    one-pass column reads "refused" where the compiled kernel would
    exceed MAX_RESIDENT_K.
    """
    import os
    import tempfile

    from repro.kernels import autotune, ops

    reps = 1 if quick else 3
    shapes = [(16, 16, 512), (16, 16, 2048)] if quick else [
        (16, 16, 512), (16, 16, 2048), (8, 16, 8192), (32, 32, 1024)]
    k_tile, bm, bn = 128, 4, 8  # small blocks: interpret grids are loops
    rng = np.random.default_rng(0)
    rows = []
    for policy in ("sorted", "sorted_tiled"):
        for m, n, k in shapes:
            x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
            w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
            kp = ops.padded_k(k, policy, k_tile)
            base = dict(policy=policy, acc_bits=16, k_tile=k_tile,
                        bm=bm, bn=bn)
            two_us = _time_us(lambda: ops.policy_matmul(
                x, w, sort_impl="twopass", **base), reps)
            # VMEM columns are computed at the SAME blocks the timings
            # ran on (recorded in "blocks"), so time and footprint in a
            # row describe one configuration
            row = {
                "policy": policy, "m": m, "n": n, "k": k,
                "blocks": f"{bm}x{bn}x{k_tile}",
                "twopass_us": round(two_us),
                "twopass_vmem_kib": round(
                    _vmem_bytes("twopass", bm, bn, kp, k_tile) / 1024, 1),
                "onepass_vmem_kib": round(
                    _vmem_bytes("onepass", bm, bn, kp, k_tile) / 1024, 1),
            }
            if kp <= ops.MAX_RESIDENT_K:
                one_us = _time_us(lambda: ops.policy_matmul(
                    x, w, sort_impl="onepass", **base), reps)
                row["onepass_us"] = round(one_us)
                out_a = ops.policy_matmul(x, w, sort_impl="onepass", **base)
                out_b = ops.policy_matmul(x, w, sort_impl="twopass", **base)
                assert (np.asarray(out_a) == np.asarray(out_b)).all(), (
                    policy,
                    m,
                    n,
                    k,
                )
            else:
                row["onepass_us"] = "refused"
            rows.append(row)

    # policy x sparse-storage composition: both nm kernel families — the
    # one-hot expand oracle and the fused activation-gather — against the
    # dense kernels on the same (decompressed) weights. Three-way parity
    # asserted, all three timed, plus the compressed-weight HBM ratio
    # (the structural platform truth; interpret-mode wall-times seed the
    # trajectory only, but gather's n_keep/m work reduction shows up even
    # there: the contraction narrows from K to G*n_keep elements)
    # 2:4 rides on sorted_tiled: the gather win there is structural (the
    # resident sort cube shrinks by m/n_keep), so it shows even in
    # interpret mode, where clip's thinner 2x stepwise saving drowns in
    # per-element gather overhead
    for policy, n_keep, mg in (("clip", 4, 16), ("sorted_tiled", 4, 16),
                               ("sorted_tiled", 2, 4)):
        m, n, k = (16, 16, 1024)
        wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
        mask = np.asarray(
            nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
        wd = (wd * mask).astype(np.int8)
        vals, idx = ops.compress_nm_weights(wd, n_keep, mg)
        x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
        w = jnp.asarray(wd)
        base = dict(policy=policy, acc_bits=16, k_tile=k_tile, bm=bm, bn=bn)
        nm_base = dict(m_group=mg, policy=policy, acc_bits=16,
                       k_tile=k_tile, bm=bm, bn=bn)
        dense_us = _time_us(lambda: ops.policy_matmul(x, w, **base), reps)
        expand_us = _time_us(lambda: ops.nm_policy_matmul(
            x, vals, idx, nm_impl="expand", **nm_base), reps)
        gather_us = _time_us(lambda: ops.nm_policy_matmul(
            x, vals, idx, nm_impl="gather", **nm_base), reps)
        out_d = ops.policy_matmul(x, w, **base)
        for impl in ("expand", "gather"):
            out_s = ops.nm_policy_matmul(x, vals, idx, nm_impl=impl,
                                         **nm_base)
            assert (np.asarray(out_d) == np.asarray(out_s)).all(), (
                policy, impl)
        rows.append({
            # sparsity pattern in the label: the same policy benched at
            # two (n_keep, m) patterns must not collide on the row key
            "policy": f"nm:{policy}:{n_keep}:{mg}", "m": m, "n": n, "k": k,
            "blocks": f"{bm}x{bn}x{k_tile}",
            "nm_expand_us": round(expand_us),
            "nm_gather_us": round(gather_us),
            "dense_us": round(dense_us),
            "weight_bytes_vs_dense": round(2 * n_keep / mg, 3),
        })

    # K-sharded path: per-shard partials + tree combine vs the full-K
    # dot. The hierarchy changes policy semantics (per-shard order), so
    # correctness is asserted against the hierarchical jnp oracle —
    # pqs_dot(k_shards=) on the jnp backend — not against the full-K
    # result; both variants are timed so the --check-against guard
    # covers the K-sharded entry points too. Weights are pre-enforced
    # against the acc_bits=16 accumulator bound (certify.truncate_rows)
    # so a certificate holds for them: certified_us times the
    # census-free fast path next to the censused full_us, asserted
    # bit-identical in-run and guarded by CERTIFIED_SLACK below.
    from repro.core import certify

    for policy, k_shards in (("clip", 4), ("sorted_tiled_seq", 4)):
        m, n, k = (16, 16, 2048)
        x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
        w = jnp.asarray(certify.truncate_rows(
            rng.integers(-127, 127, (n, k)).astype(np.int32), 16, 8
        ).astype(np.int8))
        base = dict(acc_bits=16, policy=policy, k_tile=k_tile,
                    block_m=bm, block_n=bn, backend="pallas")
        full_us = _time_us(lambda: pqs_dot(x, w, **base), reps)
        certified_us = _time_us(
            lambda: pqs_dot(x, w, certified=True, **base), reps)
        kshard_us = _time_us(
            lambda: pqs_dot(x, w, k_shards=k_shards, **base), reps)
        oracle = pqs_dot(x, w, acc_bits=16, policy=policy, k_tile=k_tile,
                         k_shards=k_shards, backend="jnp")
        out = pqs_dot(x, w, k_shards=k_shards, **base)
        assert (np.asarray(out) == np.asarray(oracle)).all(), policy
        cert_out = pqs_dot(x, w, certified=True, **base)
        full_out = pqs_dot(x, w, **base)
        assert (np.asarray(cert_out) == np.asarray(full_out)).all(), policy
        # combine tail in isolation: defer_combine splits the dot into
        # per-shard partials + the pending exchange; timing .combine()
        # on materialized partials is the latency the overlap hides.
        # The structural interconnect story rides along: the butterfly
        # moves log2(S) registers per member where the old gather moved
        # all S partials (exchange_levels vs k_shards columns).
        pend = pqs_dot(x, w, k_shards=k_shards, defer_combine=True, **base)
        jax.block_until_ready(pend.partials)
        combine_us = _time_us(lambda: pend.combine(), reps)
        assert (np.asarray(pend.combine()) == np.asarray(oracle)).all(), (
            policy)
        rows.append({
            "policy": f"kshard:{policy}", "m": m, "n": n, "k": k,
            "blocks": f"{bm}x{bn}x{k_tile}", "k_shards": k_shards,
            "kshard_us": round(kshard_us),
            "full_us": round(full_us),
            "certified_us": round(certified_us),
            "combine_us": round(combine_us, 1),
            "exchange_levels": int(np.log2(k_shards)),
        })

    # tuned vs static blocks: run the measured autotuner on one shape per
    # policy kind with a trimmed candidate set, then compare
    m, n, k = (16, 16, 512)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    tiny = {"clip": ((4, 8, 64), (2, 4, 32), (8, 8, 128)),
            "sorted_tiled": ((4, 8, None), (2, 4, None), (8, 8, None))}
    saved_env = {kk: os.environ.get(kk) for kk in
                 ("REPRO_PQS_AUTOTUNE", "REPRO_PQS_AUTOTUNE_CACHE")}
    saved_cand = autotune.CANDIDATES
    tmp = tempfile.mkdtemp(prefix="pqs-bench-autotune-")
    try:
        os.environ["REPRO_PQS_AUTOTUNE_CACHE"] = os.path.join(tmp, "at.json")
        os.environ["REPRO_PQS_AUTOTUNE"] = "tune"
        autotune.CANDIDATES = tiny
        autotune.reset()
        for policy in ("clip", "sorted_tiled"):
            base = dict(policy=policy, acc_bits=16, k_tile=128)
            static_us = _time_us(
                lambda: ops.policy_matmul(x, w, bm=4, bn=8, **base), reps)
            ops.policy_matmul(x, w, **base)  # schedules the background tune
            autotune.drain()  # measurement lands; winner serves from here
            tuned_us = _time_us(lambda: ops.policy_matmul(x, w, **base),
                                reps)
            win = autotune.best_blocks(policy, m, n,
                                       ops.padded_k(k, policy, 128))
            rows.append({
                "policy": policy, "m": m, "n": n, "k": k,
                "static_us": round(static_us),
                "tuned_us": round(tuned_us),
                "tuned_blocks": f"{win[0]}x{win[1]}x{win[2]}",
            })
    finally:
        autotune.CANDIDATES = saved_cand
        for kk, v in saved_env.items():
            if v is None:
                os.environ.pop(kk, None)
            else:
                os.environ[kk] = v
        autotune.reset()

    keys = ["policy", "m", "n", "k", "blocks", "k_shards", "onepass_us",
            "twopass_us", "onepass_vmem_kib", "twopass_vmem_kib",
            "nm_expand_us", "nm_gather_us", "dense_us",
            "weight_bytes_vs_dense", "kshard_us", "full_us",
            "certified_us", "combine_us", "exchange_levels",
            "static_us", "tuned_us", "tuned_blocks"]
    emit("BENCH_kernels", rows, keys)
    return rows


# In-run cross-column guard: the fused gather kernel must not lose to
# the expand oracle it replaces at the shapes we bench. Both columns come
# from the SAME run on the same machine, so the slack only has to absorb
# timer jitter, not machine drift — much tighter than ``tolerance``.
GATHER_SLACK = 1.25

# Same-run guard for the certified fast path: dropping the census and
# the stepwise-saturation bookkeeping must never cost wall time over the
# censused narrow-policy dot it replaces.
CERTIFIED_SLACK = 1.25


def check_against(
    rows: list[dict], baseline_path: str, tolerance: float = 1.5
) -> list[tuple]:
    """Bench regression guard: compare a fresh kbench run to a committed
    baseline. A row matches on (policy, m, n, k); every ``*_us`` field
    the BASELINE row tracked numerically must still be produced
    numerically and stay within ``tolerance`` x the baseline — a kernel
    that stopped running (e.g. its column turned into "refused") or
    stopped being benched is itself a regression, not a skip. Rows and
    fields absent from the baseline are ignored (new kernels don't fail
    the guard — regenerate the baseline to start tracking them).
    Additionally every fresh nm row timing both implementations must
    show ``nm_gather_us <= GATHER_SLACK * nm_expand_us`` (reported as
    field ``nm_gather_vs_expand``) — sparsity has to pay in wall time,
    not only in bytes — and every fresh row timing both the certified
    and censused paths must show ``certified_us <= CERTIFIED_SLACK *
    full_us`` (field ``certified_vs_censused``): the certificate has to
    pay, a certified path slower than the census it removed is a
    regression. Returns the list of regressions: (key, field,
    baseline_us, now_us) where now_us may be a non-numeric marker.
    """
    import json

    with open(baseline_path) as f:
        base = json.load(f)

    def key(r):
        # "blocks" disambiguates the sweep rows from the autotune rows
        # (which carry no blocks column) at the same (policy, m, n, k)
        return (r.get("policy"), r.get("m"), r.get("n"), r.get("k"),
                r.get("blocks"))

    fresh = {key(r): r for r in rows}
    regressions = []
    for b in base:
        r = fresh.get(key(b))
        if not r:
            continue  # baseline config not benched this run (e.g. --quick)
        for field, bv in b.items():
            if not field.endswith("_us"):
                continue
            if not isinstance(bv, (int, float)) or bv <= 0:
                continue  # baseline itself had a "refused"/zero marker
            val = r.get(field)
            if not isinstance(val, (int, float)):
                # previously-timed kernel now refuses / no longer emits
                regressions.append((key(b), field, bv,
                                    "missing" if val is None else val))
            elif val > tolerance * bv:
                regressions.append((key(b), field, bv, val))
    for r in rows:
        ge, ex = r.get("nm_gather_us"), r.get("nm_expand_us")
        if (isinstance(ge, (int, float)) and isinstance(ex, (int, float))
                and ex > 0 and ge > GATHER_SLACK * ex):
            regressions.append((key(r), "nm_gather_vs_expand", ex, ge))
        ce, fu = r.get("certified_us"), r.get("full_us")
        if (isinstance(ce, (int, float)) and isinstance(fu, (int, float))
                and fu > 0 and ce > CERTIFIED_SLACK * fu):
            regressions.append((key(r), "certified_vs_censused", fu, ce))
    return regressions


def run() -> list[dict]:
    # correctness spot checks (small shapes, interpret mode): every policy
    # through the unified dispatch layer, jnp vs pallas backends
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 127, (8, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (16, 128)), jnp.int8)
    for policy in ("wide", "clip", "wrap", "sorted", "sorted_tiled",
                   "sorted_tiled_seq"):
        a = pqs_dot(x, w, acc_bits=16, policy=policy, k_tile=64,
                    backend="jnp")
        b = pqs_dot(x, w, acc_bits=16, policy=policy, k_tile=64,
                    backend="pallas", block_m=4, block_n=8)
        assert (np.asarray(a) == np.asarray(b)).all(), policy
    assert (np.asarray(ops.sorted_matmul(x, w, acc_bits=16, bm=4, bn=8, bk=64))
            == np.asarray(ref.sorted_matmul_ref(x, w, 16, 1, 64))).all()
    wd = rng.integers(-127, 127, (16, 128)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), 4, 16))
    vals, idx = ops.compress_nm_weights((wd * mask).astype(np.int8), 4, 16)
    assert (np.asarray(ops.nm_spmm(x, vals, idx, m_group=16, bm=8, bn=8, bg=4))
            == np.asarray(ref.nm_spmm_ref(x, np.asarray(vals),
                                          np.asarray(idx), 16))).all()
    print("# kernel correctness spot-checks passed (interpret mode)")
    print(f"# v5e ridge point: {RIDGE:.0f} flop/byte")

    rows = _sorted_rows() + _nm_rows()
    keys = sorted({k for r in rows for k in r}, key=lambda s: s != "kernel")
    emit("kernel_structural", rows, keys)
    return rows


if __name__ == "__main__":
    run()
