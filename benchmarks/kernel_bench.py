"""Kernel-level structural benchmark (no TPU: interpret mode wall-time is
meaningless, so this reports the quantities that determine TPU speed).

Per kernel configuration:
  - VMEM working set per grid step (must be << 128 MiB on v5e)
  - arithmetic intensity (flops per HBM byte) against the v5e ridge point
    (197e12 / 819e9 ~= 241 flop/byte)
  - HBM bytes per output element vs the dense int8 baseline (the N:M and
    narrow-accumulator bandwidth story, DESIGN.md §2)
  - bit-exactness spot check vs the ref.py oracle (fails loudly here, not
    just in tests)
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.dispatch import pqs_dot
from repro.core.pruning import nm_prune_mask
from repro.kernels import ops, ref

from benchmarks.common import emit

RIDGE = 197e12 / 819e9


def _sorted_rows():
    rows = []
    for bm, bn, bk in ((8, 128, 256), (8, 128, 512), (16, 128, 256)):
        vmem = (bm * bk + bn * bk) * 1 + bm * bn * bk * 4 + bm * bn * 4
        m = k = 1024
        n = 512
        flops = 2 * m * n * k  # products+adds (sort stages add ~log2^2(bk) VPU ops)
        sort_ops = m * n * k * (np.log2(bk) ** 2)  # compare-exchange ops
        hbm = m * k + n * k + m * n * 4  # int8 in, int32 out
        rows.append({
            "kernel": "sorted_matmul", "block": f"{bm}x{bn}x{bk}",
            "vmem_kib": round(vmem / 1024, 1),
            "flops_per_byte": round(flops / hbm, 1),
            "vpu_sort_ops_per_mxu_flop": round(sort_ops / flops, 2),
            "hbm_bytes_per_out": round(hbm / (m * n), 2),
        })
    return rows


def _nm_rows():
    rows = []
    for n_keep, m_group in ((4, 16), (8, 16), (2, 16)):
        m = k = 1024
        n = 512
        dense_hbm = m * k + n * k + m * n * 4
        nm_hbm = m * k + 2 * n * (k // m_group) * n_keep + m * n * 4
        rows.append({
            "kernel": "nm_spmm", "block": f"{n_keep}:{m_group}",
            "vmem_kib": round((128 * 32 * 16 + 128 * 32 * n_keep * 5
                               + 128 * 128 * 4) / 1024, 1),
            "flops_per_byte": round(2 * m * n * k / nm_hbm, 1),
            "weight_bytes_vs_dense": round(
                (2 * n * (k // m_group) * n_keep) / (n * k), 3),
            "hbm_bytes_per_out": round(nm_hbm / (m * n), 2),
        })
    return rows


def run() -> list[dict]:
    # correctness spot checks (small shapes, interpret mode): every policy
    # through the unified dispatch layer, jnp vs pallas backends
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 127, (8, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (16, 128)), jnp.int8)
    for policy in ("wide", "clip", "wrap", "sorted", "sorted_tiled",
                   "sorted_tiled_seq"):
        a = pqs_dot(x, w, acc_bits=16, policy=policy, k_tile=64,
                    backend="jnp")
        b = pqs_dot(x, w, acc_bits=16, policy=policy, k_tile=64,
                    backend="pallas", block_m=4, block_n=8)
        assert (np.asarray(a) == np.asarray(b)).all(), policy
    assert (np.asarray(ops.sorted_matmul(x, w, acc_bits=16, bm=4, bn=8, bk=64))
            == np.asarray(ref.sorted_matmul_ref(x, w, 16, 1, 64))).all()
    wd = rng.integers(-127, 127, (16, 128)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), 4, 16))
    vals, idx = ops.compress_nm_weights((wd * mask).astype(np.int8), 4, 16)
    assert (np.asarray(ops.nm_spmm(x, vals, idx, m_group=16, bm=8, bn=8, bg=4))
            == np.asarray(ref.nm_spmm_ref(x, np.asarray(vals),
                                          np.asarray(idx), 16))).all()
    print("# kernel correctness spot-checks passed (interpret mode)")
    print(f"# v5e ridge point: {RIDGE:.0f} flop/byte")

    rows = _sorted_rows() + _nm_rows()
    keys = sorted({k for r in rows for k in r}, key=lambda s: s != "kernel")
    emit("kernel_structural", rows, keys)
    return rows


if __name__ == "__main__":
    run()
