"""Paper Fig 2: overflow profile + accuracy vs accumulator bitwidth.

Trains the 1-layer MLP with 8/8 QAT on the synthetic MNIST stand-in, then
for each accumulator width reports (a) the persistent/transient census and
(b) accuracy when clipping ALL overflows vs resolving transient overflows
via the sorted dot product vs an ideal wide accumulator.

Reproduced claims (trend-level, DESIGN.md §8):
  - at narrow widths most overflows are persistent, yet resolving just the
    transient ones recovers disproportionate accuracy (Fig 2b red-vs-green)
  - overflow counts fall monotonically with accumulator width.
"""

from __future__ import annotations

from repro.configs.paper import MLP1
from repro.core.papernets import (
    evaluate_int,
    overflow_profile,
    train_papernet,
)
from repro.core.pqs import PQSConfig
from repro.data import synth_mnist

from benchmarks.common import Timer, emit


def run(epochs: int = 12, n: int = 4096, eval_limit: int = 512) -> list[dict]:
    data = synth_mnist(n=n, seed=0)
    pqs = PQSConfig(weight_bits=8, act_bits=8, n_keep=16, m=16, order="pq")
    with Timer("fig2/train"):
        res = train_papernet(
            MLP1, pqs, data, epochs=epochs, prune_every=3, fp32_frac=0.6,
            lr=0.1,
        )
    _, test = data.split(0.9)
    rows = []
    for bits in (12, 13, 14, 15, 16, 18, 20):
        census = overflow_profile(res.layers, MLP1, pqs, test, bits,
                                  limit=256)
        row = {
            "acc_bits": bits,
            "fp32_acc": round(res.fp32_acc, 4),
            "n_dots": int(census.n_dots),
            "persistent": int(census.n_persistent),
            "transient": int(census.n_transient),
            "acc_clip_all": round(
                evaluate_int(res.layers, MLP1, pqs, test, "clip", bits,
                             eval_limit), 4),
            "acc_resolve_transient": round(
                evaluate_int(res.layers, MLP1, pqs, test, "sorted", bits,
                             eval_limit), 4),
            "acc_wide": round(
                evaluate_int(res.layers, MLP1, pqs, test, "wide", 30,
                             eval_limit), 4),
        }
        rows.append(row)
    emit("fig2_overflow_profile", rows, list(rows[0].keys()))
    return rows


if __name__ == "__main__":
    run()
