"""Paper Fig 5: accuracy vs accumulator bitwidth pareto — PQS vs A2Q vs clip.

Sweeps the PQS design space (weight/act bits x sparsity), evaluates each
trained model at descending accumulator widths under three regimes:

  PQS (sort)  : N:M pruned + sorted dot product (paper blue)
  PQS (clip)  : same model, transient overflows clipped (paper magenta)
  A2Q         : accumulator-aware L1-constrained QAT baseline (guaranteed
                overflow-free at its design width)

For each regime reports the minimum accumulator width whose accuracy stays
within 1% of the FP32 baseline. Reproduced claims: sorting buys ~2-4
accumulator bits over clipping; PQS reaches narrower accumulators than A2Q
at equal accuracy; frontier models are highly sparse.

Every integer evaluation here executes through the unified
``core.dispatch.pqs_dot`` layer (via ``quant_linear_int_fwd``), the same
entry point the kernels and the serving engine use. For the frontier
numbers to transfer to serving, the serving ``IntegerLinConfig`` must
match this sweep's (policy, acc_bits, k_tile, rounds) — note
``PQSConfig.rounds`` defaults to 2 sorting rounds while
``IntegerLinConfig.rounds`` defaults to the paper's single round.
"""

from __future__ import annotations

from repro.configs.paper import MLP2
from repro.core.papernets import evaluate_fp32, evaluate_int, train_papernet
from repro.core.pqs import PQSConfig
from repro.data import synth_mnist

from benchmarks.common import Timer, emit

ACC_BITS = (11, 12, 13, 14, 15, 16, 18, 20)


def _frontier(rows, regime, fp32_acc, tol=0.01):
    ok = [r["acc_bits"] for r in rows
          if r["regime"] == regime and r["acc"] >= fp32_acc - tol]
    return min(ok) if ok else None


def run(epochs: int = 12, n: int = 4096, eval_limit: int = 512) -> list[dict]:
    data = synth_mnist(n=n, seed=3)
    _, test = data.split(0.9)
    rows = []
    frontier_rows = []

    for wb, ab, n_keep in ((8, 8, 3), (8, 8, 2), (5, 5, 3)):
        tag = f"w{wb}a{ab}_keep{n_keep}"
        pqs = PQSConfig(weight_bits=wb, act_bits=ab, n_keep=n_keep, m=16,
                        order="pq")
        with Timer(f"fig5/pqs/{tag}"):
            res = train_papernet(MLP2, pqs, data, epochs=epochs,
                                 prune_every=2, fp32_frac=0.7, lr=0.1)
        fp32 = evaluate_fp32(res.layers, MLP2, pqs, test)
        for bits in ACC_BITS:
            for regime, policy in (("pqs_sort", "sorted"),
                                   ("pqs_clip", "clip")):
                rows.append({
                    "model": tag, "regime": regime, "acc_bits": bits,
                    "sparsity": round(pqs.sparsity, 3),
                    "acc": round(evaluate_int(res.layers, MLP2, pqs, test,
                                              policy, bits, eval_limit), 4),
                })
        # A2Q baseline at the same (wb, ab): trained per accumulator width
        for bits in (12, 14, 16):
            a2q_cfg = PQSConfig(weight_bits=wb, act_bits=ab, n_keep=16, m=16,
                                order="pq")
            with Timer(f"fig5/a2q/{tag}/p{bits}"):
                a2q = train_papernet(MLP2, a2q_cfg, data, epochs=epochs,
                                     prune_every=2, fp32_frac=0.7, lr=0.1,
                                     a2q_acc_bits=bits)
            rows.append({
                "model": tag, "regime": "a2q", "acc_bits": bits,
                "sparsity": None,
                "acc": round(evaluate_int(a2q.layers, MLP2, a2q_cfg, test,
                                          "clip", bits, eval_limit), 4),
            })
        model_rows = [r for r in rows if r["model"] == tag]
        frontier_rows.append({
            "model": tag, "fp32_acc": round(fp32, 4),
            "min_bits_sort": _frontier(model_rows, "pqs_sort", fp32),
            "min_bits_clip": _frontier(model_rows, "pqs_clip", fp32),
            "min_bits_a2q": _frontier(model_rows, "a2q", fp32),
        })

    emit("fig5_pareto_points", rows,
         ["model", "regime", "acc_bits", "sparsity", "acc"])
    emit("fig5_pareto_frontier", frontier_rows,
         ["model", "fp32_acc", "min_bits_sort", "min_bits_clip",
          "min_bits_a2q"])
    return frontier_rows


if __name__ == "__main__":
    run()
