"""Paper Fig 3: P->Q vs Q->P under low-rank weight approximation (MLP2).

For each rank k in {full, 100, 10, 5} and rising sparsity, trains the
2-layer MLP with both orders and compares test accuracy. Reproduced claim:
P->Q degrades more gracefully as rank falls and sparsity rises — FP32
magnitudes are the better pruning signal.
"""

from __future__ import annotations

from repro.configs.paper import MLP2
from repro.core.papernets import train_papernet
from repro.core.pqs import PQSConfig
from repro.data import synth_mnist

from benchmarks.common import Timer, emit


def run(epochs: int = 12, n: int = 4096) -> list[dict]:
    data = synth_mnist(n=n, seed=1)
    rows = []
    for rank in (None, 100, 10, 5):
        for n_keep in (11, 8, 3):  # ~30%, 50%, 80% sparsity (m=16)
            for order in ("pq", "qp"):
                pqs = PQSConfig(n_keep=n_keep, m=16, order=order)
                with Timer(f"fig3/rank={rank}/keep={n_keep}/{order}"):
                    res = train_papernet(
                        MLP2, pqs, data, epochs=epochs, prune_every=2,
                        fp32_frac=0.7, lr=0.1, low_rank=rank,
                    )
                rows.append({
                    "rank": rank if rank is not None else "full",
                    "sparsity": round(1 - n_keep / 16, 3),
                    "order": order,
                    "acc": round(res.fp32_acc, 4),
                })
    emit("fig3_pq_vs_qp_lowrank", rows, ["rank", "sparsity", "order", "acc"])
    # summary: mean P->Q advantage at the most aggressive setting
    agg = {}
    for r in rows:
        agg.setdefault((r["rank"], r["sparsity"]), {})[r["order"]] = r["acc"]
    adv = [v["pq"] - v["qp"] for v in agg.values() if len(v) == 2]
    print(f"# P->Q minus Q->P accuracy: mean {sum(adv)/len(adv):+.4f}, "
          f"min {min(adv):+.4f}, max {max(adv):+.4f}")
    return rows


if __name__ == "__main__":
    run()
