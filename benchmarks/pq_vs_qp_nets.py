"""Paper Fig 4: P->Q vs Q->P at conv scale + filter-pruning baseline.

Trains the convnet (conv-as-im2col on the same quantized matmul core) at
rising N:M sparsity with both training orders, plus the structured
filter-pruning baseline (magenta in the paper): whole-output-channel
pruning at matched sparsity. Reproduced claims: P->Q >= Q->P, and filter
pruning collapses much earlier than N:M.
"""

from __future__ import annotations

from repro.configs.paper import CONVNET
from repro.core.papernets import train_papernet
from repro.core.pqs import PQSConfig
from repro.data import make_classification

from benchmarks.common import Timer, emit


def run(epochs: int = 10, n: int = 3072) -> list[dict]:
    data = make_classification(n, CONVNET.in_dim, 10, seed=2, noise=1.5,
                               subspace=48)
    rows = []
    for n_keep in (11, 8, 5, 3):  # ~30/50/70/80% sparsity
        for variant in ("pq", "qp", "filter"):
            order = "pq" if variant == "filter" else variant
            pqs = PQSConfig(n_keep=n_keep, m=16, order=order)
            with Timer(f"fig4/keep={n_keep}/{variant}"):
                res = train_papernet(
                    CONVNET, pqs, data, epochs=epochs, prune_every=2,
                    fp32_frac=0.7, lr=0.05,
                    prune_kind="filter" if variant == "filter" else "nm",
                )
            rows.append({
                "sparsity": round(1 - n_keep / 16, 3),
                "variant": variant,
                "acc": round(res.fp32_acc, 4),
            })
    emit("fig4_pq_vs_qp_nets", rows, ["sparsity", "variant", "acc"])
    return rows


if __name__ == "__main__":
    run()
