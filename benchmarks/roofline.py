"""Roofline analysis (assignment §g): three terms per (arch x shape x mesh).

Reads the dry-run captures (benchmarks/results/dryrun_*.json) and derives,
per cell, for TPU v5e targets (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

  compute_term    = HLO_FLOPs / (chips * peak)      [uses the trip-exact
                    probe FLOPs; compiled cost_analysis counts while
                    bodies once — launch/dryrun.py docstring]
  memory_term     = HLO_bytes / (chips * HBM_bw)    [compiled per-device
                    bytes x loop multiplier]
  collective_term = collective_bytes / (chips * link_bw)
                    [trip-weighted HLO census; reported both as the
                    assignment's operand-sum and as a ring-traffic model;
                    dominance uses the ring model]

plus MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference), the
MODEL/HLO ratio (remat+attention overhead), the dominant term, and a
suggested lever. Emits markdown for EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax

from repro.configs import SHAPES, get_config
from repro.models.model import active_param_count, build_model, param_count

from benchmarks.common import results_path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_PARAM_CACHE: dict[str, tuple[int, int]] = {}


def _params(arch: str) -> tuple[int, int]:
    if arch not in _PARAM_CACHE:
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        total = param_count(shapes)
        _PARAM_CACHE[arch] = (total, active_param_count(cfg, total))
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    _, active = _params(arch)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: 1 token/seq


def lever(dom: str, cell: dict) -> str:
    arch, kind = cell["arch"], cell["kind"]
    if dom == "compute":
        return ("compute-bound (the good roofline corner); next lever is "
                "int8/bf16 MXU packing or cutting remat recompute")
    if dom == "memory":
        if kind == "decode":
            return ("HBM-bound on weight/KV streaming: int8+N:M compressed "
                    "weights (PQS!) and head-sharded KV cut bytes/token")
        return ("HBM-bound on activation traffic: fuse attention "
                "(flash-style Pallas kernel keeps scores in VMEM), bf16 "
                "scores, larger per-step tiles")
    return ("ICI-bound: reduce-scatter/all-gather overlap with compute, "
            "coarser FSDP gather granularity, or shift sharding from "
            "model- to data-axes for this cell")


def _probe_index() -> dict[tuple[str, str], dict]:
    """Probe results are mesh-independent (global FLOPs); the multi-pod
    sweep runs --no-probe and reuses the single-pod probes."""
    path = results_path("dryrun_single.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    return {
        (c["arch"], c["shape"]): c.get("probe") or {}
        for c in data["results"]
    }


def analyze(path: str) -> list[dict[str, Any]]:
    with open(path) as f:
        data = json.load(f)
    probes = _probe_index()
    out = []
    for cell in data["results"]:
        ndev = cell["num_devices"]
        probe = cell.get("probe") or probes.get(
            (cell["arch"], cell["shape"]), {}
        )
        flops_dev = (
            probe["global_flops"] / ndev
            if probe.get("global_flops")
            else (cell["cost"].get("flops_per_device_hlo") or 0.0)
        )
        r = 1.0
        if probe.get("global_flops") and cell["cost"].get(
            "flops_per_device_hlo"
        ):
            r = max(
                probe["global_flops"]
                / (cell["cost"]["flops_per_device_hlo"] * ndev),
                1.0,
            )
        bytes_dev = (cell["cost"].get("bytes_per_device_hlo") or 0.0) * r
        coll = cell["collectives"]
        coll_link = coll.get("total_link_bytes_per_device",
                             coll["total_bytes_per_device"])
        t_c = flops_dev / PEAK_FLOPS
        t_m = bytes_dev / HBM_BW
        t_n = coll_link / LINK_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(cell["arch"], cell["shape"])
        hlo_global = probe.get("global_flops") or (flops_dev * ndev)
        # Decode caveat: HLO "bytes accessed" counts each scan iteration's
        # dynamic-update-slice into the stacked KV cache as a FULL-cache
        # read+write (in-place on hardware with donated buffers). Report a
        # streaming lower bound alongside: weights/TP + one cache sweep.
        mem_lb = None
        if cell["kind"] == "decode":
            total, _ = _params(cell["arch"])
            cache_dev = (cell["memory"]["argument_bytes"] or 0)
            mem_lb = (total * 4 / 16 + cache_dev) / HBM_BW
        out.append({
            "arch": cell["arch"],
            "shape": cell["shape"],
            "mesh": cell["mesh"],
            "kind": cell["kind"],
            "compute_s": t_c,
            "memory_s": t_m,
            "collective_s": t_n,
            "collective_opsum_s": coll["total_bytes_per_device"] / LINK_BW,
            "dominant": dom,
            "roofline_fraction": t_c / max(t_c, t_m, t_n, 1e-30),
            "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "model_over_hlo": mf / max(hlo_global, 1e-30),
            "peak_bytes_per_dev": cell["memory"]["peak_bytes"],
            "memory_streaming_lb_s": mem_lb,
            "lever": lever(dom, cell),
        })
    return out


def to_markdown(rows: list[dict], title: str) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | MODEL/HLO flops | peak B/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = [f"### {title}\n", hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} | {r['model_over_hlo']:.3f} "
            f"| {r['peak_bytes_per_dev'] or 0:.2e} |\n"
        )
    return "".join(lines)


def run() -> list[dict]:
    all_rows = []
    for mesh_name in ("single", "multi"):
        path = results_path(f"dryrun_{mesh_name}.json")
        if not os.path.exists(path):
            print(f"[roofline] missing {path}; run launch/dryrun.py first")
            continue
        rows = analyze(path)
        all_rows += rows
        md = to_markdown(rows, f"{mesh_name} mesh")
        with open(results_path(f"roofline_{mesh_name}.md"), "w") as f:
            f.write(md)
        print(md)
    with open(results_path("roofline.json"), "w") as f:
        json.dump(all_rows, f, indent=1)
    return all_rows


if __name__ == "__main__":
    run()
