"""Benchmark driver: one experiment per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig2,tiled
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced epochs
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig3,fig4,fig5,tiled,kernels,"
                         "kbench,roofline,serve")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check-against", default=None, metavar="BASELINE.json",
                    help="bench regression guard: after the kbench suite, "
                         "fail if any kernel's *_us time exceeds "
                         "--tolerance x the committed baseline row")
    ap.add_argument("--check-serving-against", default=None,
                    metavar="BASELINE.json",
                    help="serving regression guard: after the serve suite, "
                         "fail if any mode's tokens_per_s drops below "
                         "baseline / --tolerance")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="allowed slowdown factor vs the baseline "
                         "(default 1.5)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.check_against and only is not None and "kbench" not in only:
        ap.error("--check-against needs the kbench suite in the run "
                 "(drop --only or include kbench in it)")
    if args.check_serving_against and only is not None and "serve" not in only:
        ap.error("--check-serving-against needs the serve suite in the run "
                 "(drop --only or include serve in it)")

    from benchmarks import (
        kernel_bench,
        overflow_profile,
        pareto_accum,
        pq_vs_qp_lowrank,
        pq_vs_qp_nets,
        roofline,
        serving_throughput,
        tiled_sort,
    )

    epochs = 6 if args.quick else 12
    suites = [
        ("fig2", lambda: overflow_profile.run(epochs=epochs)),
        ("fig3", lambda: pq_vs_qp_lowrank.run(epochs=max(epochs - 2, 6))),
        ("fig4", lambda: pq_vs_qp_nets.run(epochs=max(epochs - 2, 6))),
        ("fig5", lambda: pareto_accum.run(epochs=epochs)),
        ("tiled", lambda: tiled_sort.run(epochs=max(epochs - 2, 6))),
        ("kernels", kernel_bench.run),
        ("kbench", lambda: kernel_bench.bench_kernels(quick=args.quick)),
        ("roofline", roofline.run),
        ("serve", lambda: serving_throughput.run(quick=args.quick)),
    ]

    t0 = time.time()
    failures = []
    results = {}
    for name, fn in suites:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        try:
            results[name] = fn()
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
    if args.check_against and "kbench" in results:
        regs = kernel_bench.check_against(
            results["kbench"], args.check_against, args.tolerance)
        if regs:
            print(f"\n[bench-guard] {len(regs)} regression(s) vs "
                  f"{args.check_against} (tolerance {args.tolerance}x):")
            for key, field, base_us, now_us in regs:
                ratio = (f"{now_us / base_us:.2f}x"
                         if isinstance(now_us, (int, float))
                         else "no longer runs")
                print(f"  {key} {field}: {base_us} -> {now_us} us ({ratio})")
            failures.append(("bench-guard", f"{len(regs)} regressions"))
        else:
            print(f"\n[bench-guard] ok — all kernel times within "
                  f"{args.tolerance}x of {args.check_against}")
    if args.check_serving_against and "serve" in results:
        regs = serving_throughput.check_against(
            results["serve"], args.check_serving_against, args.tolerance)
        if regs:
            print(f"\n[serve-guard] {len(regs)} regression(s) vs "
                  f"{args.check_serving_against} "
                  f"(tolerance {args.tolerance}x):")
            for mode, field, base, now in regs:
                ratio = (f"{now / base:.2f}x" if isinstance(now, (int, float))
                         else "no longer runs")
                print(f"  {mode} {field}: {base} -> {now} tok/s ({ratio})")
            failures.append(("serve-guard", f"{len(regs)} regressions"))
        else:
            print(f"\n[serve-guard] ok — all modes within "
                  f"{args.tolerance}x of {args.check_serving_against}")
    print(f"\n[benchmarks] total {time.time() - t0:.0f}s; "
          f"{len(failures)} failures: {failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
