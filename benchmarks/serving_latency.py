"""Decode latency: dynamic vs calibrated-static activation quantization.

The integer serving path quantizes activations before every ``pqs_dot``.
Dynamically that is a data-dependent absmax reduction over the
activations at every projection of every decode step; after the
calibrate→freeze pass (``ServingEngine.calibrate``) the scale is a
frozen constant and the reduction disappears from the step entirely
(paper §2.1 setup: ranges collected offline). This benchmark times the
jitted decode step of the same quantized model in three modes:

  float    — dequantize-to-float matmuls (the bandwidth baseline)
  int/dyn  — integer pqs_dot, dynamic per-call absmax
  int/cal  — integer pqs_dot, calibrated static ranges

and reports per-step latency plus the dyn→cal speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.dispatch import IntegerLinConfig
from repro.core.qtensor import quantize_tree
from repro.models.model import build_model
from repro.serving import Request, ServingEngine


def _time_decode(eng, steps: int, slots: int, vocab: int) -> float:
    """Median wall time of the jitted batched decode step, seconds."""
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(1, vocab, 4).astype(np.int32),
                max_new_tokens=steps + 4)
        for i in range(slots)
    ]
    for r in reqs:
        eng.submit(r)
    eng.step()  # admit + prefill + first decode (compiles)
    eng.step()  # warm
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(arch: str = "qwen2-1.5b", steps: int = 20, slots: int = 4) -> dict:
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
    il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24, k_tile=64,
                          backend="jnp")
    rng = np.random.default_rng(0)
    cal_batches = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
        for _ in range(4)
    ]

    results = {}
    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64)
    results["float"] = _time_decode(eng, steps, slots, cfg.vocab_size)

    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64,
                        int_lin=il)
    results["int_dynamic"] = _time_decode(eng, steps, slots, cfg.vocab_size)

    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64,
                        int_lin=il)
    eng.calibrate(cal_batches)
    results["int_calibrated"] = _time_decode(eng, steps, slots,
                                             cfg.vocab_size)

    speedup = results["int_dynamic"] / max(results["int_calibrated"], 1e-12)
    print(f"[serving_latency] {arch} decode step ({slots} slots, "
          f"median of {steps}):")
    for k in ("float", "int_dynamic", "int_calibrated"):
        print(f"  {k:15s} {results[k] * 1e3:8.2f} ms/step")
    print(f"  calibrated static ranges: {speedup:.2f}x vs dynamic absmax")
    results["dyn_over_cal"] = speedup
    return results


if __name__ == "__main__":
    run()
