"""Serving throughput under Poisson load: dense vs paged vs int8-paged.

Drives the continuous-batching engine with Poisson request arrivals and
reports, per cache mode:

  tokens_per_s     decoded tokens / wall time over the whole run
  p50_ms, p99_ms   end-to-end request latency (scheduled arrival ->
                   last token) percentiles
  step_ms          median jitted decode-step wall time
  cache_mb         cache footprint (pools + tables + state) — the
                   measured memory story: int8 pages vs f32 pages vs
                   dense f32 lanes
  queue_wait/pages engine admission + page-occupancy counters

Modes: ``f32_dense`` (monolithic per-slot lanes), ``f32_paged`` (page
pools, bit-identical decode), ``int8_paged`` (quantized KV pages). The
paged pool is deliberately undersized (num_pages < slots x pages/slot)
so admission backpressure and page recycling are on the measured path.

Also folds in the decode-step latency comparison that used to live in
``serving_latency.py`` (dynamic vs calibrated-static activation
quantization of the integer serving path) — one request-generation and
reporting path for all serving benches (``benchmarks.common``).

``check_against`` gates tokens_per_s against a committed baseline via
``run.py --check-serving-against`` (generous tolerance: CI guards
structural collapses, not jitter).

``--inject-failures`` (or the ``failures`` key of a full run) measures
the fault-tolerance overhead: the same Poisson workload is driven twice
through ``ServingFleet`` + ``ServeSupervisor`` — once failure-free, once
with two injected mid-decode crashes recovered from periodic snapshots —
and reports the per-recovery restore latency, the goodput ratio
(crash-run throughput / failure-free throughput), and whether the
recovered token streams stayed bit-identical. The committed baseline
gates goodput_ratio and tokens_match the same way it gates tokens_per_s.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import emit, gen_requests, poisson_arrivals
from repro.configs import get_config
from repro.core.dispatch import IntegerLinConfig
from repro.core.qtensor import quantize_tree
from repro.models.model import build_model
from repro.serving import ServingEngine

MODES = ("f32_dense", "f32_paged", "int8_paged")


def _make_engine(mode: str, model, params, *, num_slots, max_len, page_size,
                 num_pages):
    kw = {}
    if mode.endswith("paged"):
        kw.update(page_size=page_size, num_pages=num_pages)
    if mode.startswith("int8"):
        kw.update(cache_dtype="int8")
    return ServingEngine(model, params, num_slots=num_slots, max_len=max_len,
                         **kw)


def _warmup(eng, vocab: int, lens=(5, 9, 13)) -> None:
    """Compile the decode step and the prefill buckets the run will hit."""
    for j, n in enumerate(lens):
        reqs = gen_requests(vocab, 1, seed=10_000 + j, len_lo=n, len_hi=n,
                            max_new=2, uid_base=1_000_000 + j)
        eng.drain(reqs)


def _drive(eng, reqs, arrivals) -> dict:
    """Submit requests on their Poisson schedule; step until drained."""
    t0 = time.perf_counter()
    i = 0
    step_ms = []
    while True:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        busy = any(s is not None for s in eng.slots) or eng.queue
        if not busy and i < len(reqs):
            time.sleep(max(float(arrivals[i]) - now, 0.0))
            continue
        t1 = time.perf_counter()
        n_active = eng.step()
        step_ms.append((time.perf_counter() - t1) * 1e3)
        if i >= len(reqs) and n_active == 0 and not eng.queue:
            break
    elapsed = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    # latency vs the *scheduled* arrival: queueing delay under load counts
    lat_ms = [
        (r.t_done - (t0 + float(arrivals[j]))) * 1e3
        for j, r in enumerate(reqs)
    ]
    return {
        "tokens_per_s": toks / max(elapsed, 1e-9),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "step_ms": float(np.median(step_ms)),
        "queue_wait_steps": eng.stats["queue_wait_steps"],
        "hol_skips": eng.stats["hol_skips"],
        "pages_peak": eng.stats["pages_peak"],
    }


def run(arch: str = "qwen2-1.5b", quick: bool = False, seed: int = 0) -> dict:
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_requests = 8 if quick else 24
    max_new = 6 if quick else 12
    num_slots, max_len, page_size = 4, 64, 16
    # undersized pool: 3/4 of the dense worst case, so page recycling
    # and admission backpressure are part of what gets measured
    num_pages = 3 * num_slots * (max_len // page_size) // 4

    results: dict = {}
    rows = []
    for mode in MODES:
        eng = _make_engine(mode, model, params, num_slots=num_slots,
                           max_len=max_len, page_size=page_size,
                           num_pages=num_pages)
        _warmup(eng, cfg.vocab_size)
        reqs = gen_requests(cfg.vocab_size, n_requests, seed=seed,
                            len_lo=4, len_hi=12, max_new=max_new)
        # arrival rate ~ a few requests per measured decode-step time;
        # fast enough to keep slots contended, slow enough to spread out
        arrivals = poisson_arrivals(n_requests, rate_per_s=40.0, seed=seed)
        res = _drive(eng, reqs, arrivals)
        res["cache_mb"] = eng.cache_nbytes() / 1e6
        results[mode] = res
        rows.append({"mode": mode, **{k: round(v, 3) if isinstance(v, float)
                                      else v for k, v in res.items()}})

    emit("BENCH_serving", rows,
         ["mode", "tokens_per_s", "p50_ms", "p99_ms", "step_ms", "cache_mb",
          "queue_wait_steps", "hol_skips", "pages_peak"])
    shrink = results["f32_paged"]["cache_mb"] / max(
        results["int8_paged"]["cache_mb"], 1e-9)
    print(f"[serving_throughput] int8 pages shrink the cache "
          f"{shrink:.2f}x vs f32 pages "
          f"({results['int8_paged']['cache_mb']:.3f} MB vs "
          f"{results['f32_paged']['cache_mb']:.3f} MB; dense f32 "
          f"{results['f32_dense']['cache_mb']:.3f} MB)")
    results["int8_shrink"] = shrink

    results["failures"] = bench_failures(arch, quick=quick, seed=seed)

    if not quick:
        results["int_decode"] = bench_int_decode(arch)
    return results


def bench_failures(arch: str = "qwen2-1.5b", quick: bool = False,
                   seed: int = 0) -> dict:
    """Fault-tolerance overhead: injected crashes vs a failure-free run.

    Drives the same request set through ``ServingFleet`` twice — clean,
    then with two mid-decode crashes recovered from periodic in-memory
    snapshots — and reports per-recovery restore latency, the goodput
    ratio (crashed throughput over clean throughput: snapshotting +
    restore + replayed steps are the overhead), and whether every
    recovered token stream stayed bit-identical to the clean run.
    """
    from repro.runtime import FailureInjector, ServeSupervisor
    from repro.serving import ServingFleet

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_requests = 6 if quick else 12
    max_new = 6 if quick else 10
    num_slots, max_len, page_size = 2, 64, 16
    num_pages = num_slots * (max_len // page_size)

    def drive(inject: bool) -> tuple[dict, dict, float]:
        eng = ServingEngine(model, params, num_slots=num_slots,
                            max_len=max_len, page_size=page_size,
                            num_pages=num_pages)
        _warmup(eng, cfg.vocab_size)
        if inject:
            # schedule relative to the post-warmup step counter
            s = eng._step_idx
            eng.failure_injector = FailureInjector({s + 4, s + 11})
        reqs = gen_requests(cfg.vocab_size, n_requests, seed=seed,
                            len_lo=4, len_hi=10, max_new=max_new)
        fleet = ServingFleet(snapshot_every=4 if inject else 0)
        fleet.add_engine("m", eng)
        for r in reqs:
            fleet.submit("m", r)
        sup = ServeSupervisor(fleet)
        t0 = time.perf_counter()
        sup.run()
        wall = time.perf_counter() - t0
        return {r.uid: list(r.output) for r in reqs}, fleet.stats, wall

    base_out, _, base_wall = drive(inject=False)
    fail_out, stats, fail_wall = drive(inject=True)

    toks = sum(len(o) for o in base_out.values())
    base_tps = toks / max(base_wall, 1e-9)
    fail_tps = sum(len(o) for o in fail_out.values()) / max(fail_wall, 1e-9)
    res = {
        "recoveries": stats["recoveries"],
        "snapshots": stats["snapshots"],
        "recovery_ms": stats["recovery_s"] / max(stats["recoveries"], 1)
        * 1e3,
        "clean_tokens_per_s": base_tps,
        "failed_tokens_per_s": fail_tps,
        "goodput_ratio": fail_tps / max(base_tps, 1e-9),
        "tokens_match": fail_out == base_out,
    }
    emit("BENCH_serving_failures",
         [{k: round(v, 3) if isinstance(v, float) else v
           for k, v in res.items()}],
         ["recoveries", "snapshots", "recovery_ms", "clean_tokens_per_s",
          "failed_tokens_per_s", "goodput_ratio", "tokens_match"])
    print(f"[serving_throughput/failures] {stats['recoveries']} recoveries "
          f"at {res['recovery_ms']:.1f} ms each; goodput ratio "
          f"{res['goodput_ratio']:.2f} "
          f"(bit-identical={res['tokens_match']})")
    return res


def bench_int_decode(arch: str = "qwen2-1.5b", steps: int = 20,
                     slots: int = 4) -> dict:
    """Decode latency: dynamic vs calibrated-static activation quant.

    The integer serving path quantizes activations before every
    ``pqs_dot``; dynamically that is a per-call absmax reduction, after
    calibrate→freeze the scale is a constant and the reduction leaves
    the step (paper §2.1: ranges collected offline). Times the jitted
    decode step in float / int-dynamic / int-calibrated modes.
    """
    import jax.numpy as jnp

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
    il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24, k_tile=64,
                          backend="jnp")
    rng = np.random.default_rng(0)
    cal_batches = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
        for _ in range(4)
    ]

    def time_decode(eng) -> float:
        reqs = gen_requests(cfg.vocab_size, slots, seed=0, len_lo=4,
                            len_hi=4, max_new=steps + 4)
        for r in reqs:
            eng.submit(r)
        eng.step()  # admit + prefill + first decode (compiles)
        eng.step()  # warm
        times = []
        for _ in range(steps):
            t0 = time.perf_counter()
            eng.step()
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    results = {}
    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64)
    results["float"] = time_decode(eng)

    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64,
                        int_lin=il)
    results["int_dynamic"] = time_decode(eng)

    eng = ServingEngine(model, qparams, num_slots=slots, max_len=64,
                        int_lin=il)
    eng.calibrate(cal_batches)
    results["int_calibrated"] = time_decode(eng)

    speedup = results["int_dynamic"] / max(results["int_calibrated"], 1e-12)
    print(f"[serving_throughput/int] {arch} decode step ({slots} slots, "
          f"median of {steps}):")
    for k in ("float", "int_dynamic", "int_calibrated"):
        print(f"  {k:15s} {results[k] * 1e3:8.2f} ms/step")
    print(f"  calibrated static ranges: {speedup:.2f}x vs dynamic absmax")
    results["dyn_over_cal"] = speedup
    return results


def check_against(results: dict, baseline_path: str, tolerance: float):
    """Throughput regression guard vs a committed baseline.

    Returns [(mode, field, baseline, now), ...] for every mode whose
    tokens_per_s fell below baseline / tolerance (or disappeared).
    When both sides carry a ``failures`` entry it is gated too:
    goodput_ratio may not collapse below baseline / tolerance, and
    recovered token streams must stay bit-identical (tokens_match).
    Mode gating is skipped for failures-only runs (--inject-failures).
    """
    with open(baseline_path) as f:
        base = json.load(f)
    regs = []
    if any(m in results for m in MODES):
        for mode, b in base.items():
            if mode not in MODES:
                continue
            now = results.get(mode)
            if now is None:
                regs.append((mode, "tokens_per_s", b["tokens_per_s"], None))
                continue
            if now["tokens_per_s"] < b["tokens_per_s"] / tolerance:
                regs.append((mode, "tokens_per_s", b["tokens_per_s"],
                             now["tokens_per_s"]))
    bf, nf = base.get("failures"), results.get("failures")
    if bf is not None and nf is not None:
        if not nf.get("tokens_match", False):
            regs.append(("failures", "tokens_match", True,
                         nf.get("tokens_match")))
        if nf["goodput_ratio"] < bf["goodput_ratio"] / tolerance:
            regs.append(("failures", "goodput_ratio", bf["goodput_ratio"],
                         nf["goodput_ratio"]))
    return regs


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failures", action="store_true",
                    help="run only the failure-injection bench")
    ap.add_argument("--check-against", default=None,
                    help="baseline JSON; nonzero exit on regression")
    ap.add_argument("--tolerance", type=float, default=2.0)
    args = ap.parse_args()

    if args.inject_failures:
        res = {"failures": bench_failures(args.arch, quick=args.quick,
                                          seed=args.seed)}
    else:
        res = run(args.arch, quick=args.quick, seed=args.seed)
    if args.check_against:
        regs = check_against(res, args.check_against, args.tolerance)
        for mode, field, b, now in regs:
            print(f"[serving_throughput] REGRESSION {mode}.{field}: "
                  f"baseline {b} -> now {now}")
        if regs:
            sys.exit(1)
        print(f"[serving_throughput] baseline check OK "
              f"({args.check_against}, tolerance {args.tolerance}x)")
