"""Paper §6: tiled sorting vs full-dot sorting — transient elimination rate.

Takes real partial products from a trained quantized MLP2 hidden layer
(K = 784) and long synthetic dots (K = 4096, "transformer-scale"), and
measures what fraction of transient overflows each policy eliminates:

  natural            : no sorting (baseline: 0% eliminated)
  sorted (full K)    : paper Alg. 1, one round over the whole dot
  tiled_seq k        : paper §6 — sort within k-tiles, natural tile order
  tiled_interleave k : beyond-paper — tiles paired by net sum and
                       element-interleaved (core.sorted_accum)

Reproduced claim: k=256 tiles still eliminate ~99% of transients on
NN-distributed products. Beyond-paper finding: on harder (longer, margin-
heavy) dots the natural tile order leaves a tail that the sum-ranked
interleave removes (EXPERIMENTS.md §Tiled).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.paper import MLP2
from repro.core.overflow import partial_products, transient_survivors
from repro.core.papernets import freeze_net, train_papernet
from repro.core.pqs import PQSConfig
from repro.core.quant import quantize
from repro.data import synth_mnist

from benchmarks.common import Timer, emit


def _rates(prods, acc_bits, tiles=(64, 256)) -> list[dict]:
    base = int(transient_survivors(prods, acc_bits, policy="natural"))
    rows = [{"policy": "natural", "k_tile": "-", "survivors": base,
             "eliminated_pct": 0.0}]
    if base == 0:
        return rows

    def pct(n):
        return round(100 * (1 - n / base), 2)

    n = int(transient_survivors(prods, acc_bits, policy="sorted", rounds=1))
    rows.append({"policy": "sorted_full", "k_tile": "-", "survivors": n,
                 "eliminated_pct": pct(n)})
    for kt in tiles:
        if prods.shape[-1] % kt:
            continue
        a = int(transient_survivors(prods, acc_bits,
                                    policy="sorted_tiled_seq", k_tile=kt))
        b = int(transient_survivors(prods, acc_bits,
                                    policy="sorted_tiled", k_tile=kt))
        rows.append({"policy": "tiled_seq", "k_tile": kt, "survivors": a,
                     "eliminated_pct": pct(a)})
        rows.append({"policy": "tiled_interleave", "k_tile": kt,
                     "survivors": b, "eliminated_pct": pct(b)})
    return rows


def run(epochs: int = 10, n: int = 3072) -> list[dict]:
    rows = []

    # --- real network products (MLP2 hidden layer, K=784) ---
    data = synth_mnist(n=n, seed=4)
    pqs = PQSConfig(n_keep=8, m=16, order="pq")
    with Timer("tiled/train"):
        res = train_papernet(MLP2, pqs, data, epochs=epochs, prune_every=2,
                             fp32_frac=0.7, lr=0.1)
    frozen = freeze_net(res.layers, MLP2, pqs)
    _, test = data.split(0.9)
    x = jnp.asarray(test.x[:96])
    xq = quantize(x, frozen[0]["x_qp"])
    prods = partial_products(frozen[0]["wq"], xq)
    # pad K=784 -> 1024 for power-of-2 tiles (zeros inert)
    prods = jnp.pad(prods, ((0, 0), (0, 0), (0, 1024 - 784)))
    for acc_bits in (14, 15, 16):
        for r in _rates(prods, acc_bits):
            rows.append({"source": "mlp2_hidden", "acc_bits": acc_bits, **r})

    # --- transformer-scale synthetic dots (K=4096) ---
    rng = np.random.default_rng(0)
    w = rng.normal(size=(512, 4096))
    act = np.abs(rng.normal(size=(4096,)))
    wq = np.clip(np.round(w / np.abs(w).max() * 127), -127, 127)
    aq = np.clip(np.round(act / act.max() * 127), 0, 127)
    prods = jnp.asarray(wq * aq, jnp.int32)
    for acc_bits in (17, 18):
        for r in _rates(prods, acc_bits, tiles=(256, 1024)):
            rows.append({"source": "synthetic_k4096", "acc_bits": acc_bits,
                         **r})

    emit("tiled_sort_rates", rows,
         ["source", "acc_bits", "policy", "k_tile", "survivors",
          "eliminated_pct"])
    return rows


if __name__ == "__main__":
    run()
