"""Analysis-library session (paper §5.0.1): train a quantized net, then use
the overflow library to answer the paper's Fig-2 questions interactively.

  PYTHONPATH=src python examples/overflow_analysis.py
"""

from repro.configs.paper import MLP1
from repro.core.papernets import (
    evaluate_int,
    overflow_profile,
    train_papernet,
)
from repro.core.pqs import PQSConfig
from repro.data import synth_mnist

data = synth_mnist(n=3072, seed=0)
pqs = PQSConfig(weight_bits=8, act_bits=8, n_keep=8, m=16, order="pq")
print("training 1-layer MLP with P->Q (8/8-bit QAT, 8:16 pruning)...")
res = train_papernet(MLP1, pqs, data, epochs=10, prune_every=2,
                     fp32_frac=0.6, lr=0.1)
_, test = data.split(0.9)
print(f"fp32 accuracy: {res.fp32_acc:.3f}\n")
print(f"{'bits':>5} {'persist':>8} {'transnt':>8} "
      f"{'clip-all':>9} {'sort':>7} {'wide':>7}")
for bits in (12, 13, 14, 15, 16, 18):
    c = overflow_profile(res.layers, MLP1, pqs, test, bits, limit=256)
    clip = evaluate_int(res.layers, MLP1, pqs, test, "clip", bits, 256)
    srt = evaluate_int(res.layers, MLP1, pqs, test, "sorted", bits, 256)
    wide = evaluate_int(res.layers, MLP1, pqs, test, "wide", 30, 256)
    print(f"{bits:>5} {int(c.n_persistent):>8} {int(c.n_transient):>8} "
          f"{clip:>9.3f} {srt:>7.3f} {wide:>7.3f}")
print("\npaper Fig 2 story: transient overflows are the minority at narrow")
print("widths, but resolving just them (sort column vs clip-all column)")
print("recovers disproportionate accuracy — without adding bits.")
