"""PQS quickstart: the paper's idea in one file.

1. Quantize a weight/activation pair to int8 (paper §2.1).
2. Show a *transient* overflow: the exact dot product fits a 16-bit
   accumulator, but natural-order accumulation leaves the range.
3. Fix it with the sorted dot product (paper Alg. 1) — no extra bits.
4. Do the same at matmul scale with the Pallas TPU kernel (interpret mode
   on CPU) and its pure-jnp oracle.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.overflow import census
from repro.core.pruning import nm_prune_mask
from repro.core.quant import activation_qparams, quantize, weight_qparams
from repro.core.sorted_accum import monotone_accumulate, sorted_order
from repro.kernels import ops, ref

rng = np.random.default_rng(0)  # seed 0 yields a transient case at 16 bits

# --- 1. quantize ------------------------------------------------------------
w = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
x = jnp.asarray(np.abs(rng.normal(size=(256,))), jnp.float32)  # post-ReLU
wq = quantize(w, weight_qparams(w, 8))
xq = quantize(x, activation_qparams(jnp.min(x), jnp.max(x), 8))
prods = (wq * xq)[None, :]
print(f"dot length K={prods.shape[-1]}, exact sum = {int(prods.sum())}")

# --- 2. transient overflow with a 16-bit accumulator ------------------------
ACC = 16
c = census(prods, ACC)
nat, ovf_nat = monotone_accumulate(prods, ACC, saturate=True)
print(f"natural order @ {ACC}b: value {int(nat[0])} "
      f"(overflowed={bool(ovf_nat[0])}, transient={int(c.n_transient)})")

# --- 3. sorted dot product fixes it -----------------------------------------
srt, ovf_srt = monotone_accumulate(sorted_order(prods, 1), ACC, saturate=True)
print(f"sorted order  @ {ACC}b: value {int(srt[0])} "
      f"(overflowed={bool(ovf_srt[0])}) — exact: {int(srt[0]) == int(prods.sum())}")

# --- 4. matmul scale: Pallas kernel vs oracle vs wide -----------------------
X = jnp.asarray(rng.integers(0, 127, (32, 512)), jnp.int8)
W = jnp.asarray(rng.integers(-127, 127, (64, 512)), jnp.int8)
wide = np.asarray(ref.quant_matmul_ref(X, jnp.asarray(np.asarray(W).T)))
srtk = np.asarray(ops.sorted_matmul(X, W, acc_bits=18, bk=256))
clpk = np.asarray(ops.clip_matmul(X, W, acc_bits=18, bk=256))
fits = (np.abs(wide) < 2**17)
print(f"\nmatmul 32x512x64 @ 18-bit accumulator "
      f"(kernel, interpret mode):")
print(f"  sorted kernel exact on {100*(srtk == wide)[fits].mean():.2f}% "
      f"of in-range outputs")
print(f"  clip   kernel exact on {100*(clpk == wide)[fits].mean():.2f}%")

# --- 5. N:M pruning shortens the dot (fights persistent overflow) -----------
mask = nm_prune_mask(jnp.asarray(np.asarray(W), jnp.float32), 4, 16)
Wp = (np.asarray(W) * np.asarray(mask)).astype(np.int8)
vals, idx = ops.compress_nm_weights(Wp, 4, 16)
out = np.asarray(ops.nm_spmm(X, vals, idx, m_group=16))
print(f"\n4:16-pruned compressed matmul == dense-on-pruned: "
      f"{(out == np.asarray(ref.quant_matmul_ref(X, jnp.asarray(Wp.T)))).all()}")
print("weight bytes vs dense int8: "
      f"{vals.size + idx.size}/{Wp.size} (values+int32 idx; int8-packable)")
