"""End-to-end driver (the paper's kind = inference): serve a small LM with
batched requests under PQS int8 + N:M quantized weights.

Pipeline:
  1. build + briefly train a reduced qwen2-family LM on the synthetic
     token stream (so the weights are not random noise),
  2. P->Q: N:M-prune + quantize every large matrix to a QTensor
     (int8 values + per-channel scales) — the PQS storage format,
  3. serve a batch of requests through the continuous-batching engine in
     both fp32 and PQS form; compare outputs and report the bandwidth win,
  4. calibrate->freeze->serve: run the TRUE integer decode path
     (pqs_dot under an accumulation policy) with activation ranges
     frozen from a calibration pass — the paper's S2.1 static setup,
  5. run the overflow census on the LM head matmul to show the
     accumulator story end-to-end on a *model*, not a toy.

  PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.overflow import matmul_census
from repro.core.qtensor import QTensor, quantize_tree
from repro.core.quant import activation_qparams, quantize
from repro.data import TokenStream
from repro.models.model import build_model, param_count
from repro.optim import adamw
from repro.serving import Request, ServingEngine

cfg = get_config("qwen2-1.5b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"[1] model {cfg.name}: {param_count(params):,} params")

# --- brief training so serving ops see trained statistics -------------------
opt = adamw(lr=1e-3)
opt_state = opt.init(params)
data = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)


@jax.jit
def step(params, opt_state, batch):
    loss, g = jax.value_and_grad(model.loss)(params, batch)
    params, opt_state = opt.update(g, opt_state, params)
    return params, opt_state, loss


t0 = time.time()
for i in range(60):
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    params, opt_state, loss = step(params, opt_state, batch)
print(f"[2] trained 60 steps in {time.time()-t0:.1f}s, "
      f"final loss {float(loss):.3f}")

# --- PQS quantization ---------------------------------------------------
# int8-only (lossless-ish) for the serving comparison, and int8 + 8:16 N:M
# for the compression numbers. One-shot 50% pruning of a briefly-trained
# model without the P->Q fine-tuning phase is intentionally aggressive —
# launch/train.py runs the full schedule when accuracy matters.
qparams = quantize_tree(params, bits=8, min_size=1 << 12, min_dim=16)
qparams_nm = quantize_tree(params, bits=8, n_keep=8, m=16,
                           min_size=1 << 12, min_dim=16)
n_q = sum(isinstance(x, QTensor)
          for x in jax.tree_util.tree_leaves(
              qparams, is_leaf=lambda l: isinstance(l, QTensor)))
fp_bytes = sum(a.size * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(params))
q_bytes = sum(
    (a.size if a.dtype == jnp.int8 else a.size * a.dtype.itemsize)
    for a in jax.tree_util.tree_leaves(qparams_nm))
print(f"[3] PQS-quantized {n_q} matrices; "
      f"param bytes {fp_bytes:,} -> {q_bytes:,} "
      f"({fp_bytes/q_bytes:.1f}x smaller before N:M packing; 8:16 zeros "
      f"compress a further 2x via kernels/nm_spmm)")

# --- serve the same requests through both ------------------------------------
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
           for _ in range(6)]


def serve(p):
    eng = ServingEngine(model, p, num_slots=3, max_len=64)
    reqs = [Request(uid=i, prompt=pr, max_new_tokens=12)
            for i, pr in enumerate(prompts)]
    t0 = time.time()
    eng.drain(reqs)
    return reqs, time.time() - t0


fp_reqs, fp_t = serve(params)
q_reqs, q_t = serve(qparams)
qnm_reqs, _ = serve(qparams_nm)


def agreement(a_reqs, b_reqs):
    return 100 * np.mean([
        np.mean(np.asarray(a.output) == np.asarray(b.output))
        for a, b in zip(a_reqs, b_reqs)
    ])


print(f"[4] served {len(prompts)} requests: fp32 {fp_t:.1f}s, "
      f"PQS-int8 {q_t:.1f}s; greedy agreement int8 "
      f"{agreement(fp_reqs, q_reqs):.1f}%, int8+8:16-one-shot "
      f"{agreement(fp_reqs, qnm_reqs):.1f}% (no P->Q fine-tune)")
print(f"    sample fp32: {fp_reqs[0].output}")
print(f"    sample pqs : {q_reqs[0].output}")

# --- calibrate -> freeze -> serve (true integer decode) ----------------------
from repro.core.dispatch import IntegerLinConfig  # noqa: E402

int_eng = ServingEngine(
    model, qparams, num_slots=3, max_len=64,
    int_lin=IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                             k_tile=64, backend="jnp"),
)
frozen = int_eng.calibrate(
    [{k: jnp.asarray(v) for k, v in data.next_batch().items()}
     for _ in range(4)]
)
int_reqs = [Request(uid=i, prompt=pr, max_new_tokens=12)
            for i, pr in enumerate(prompts)]
int_eng.drain(int_reqs)
print(f"[4b] integer decode (sorted_tiled_seq @ 24b, calibrated static "
      f"ranges over {len(frozen)} sites): greedy agreement vs fp32 "
      f"{agreement(fp_reqs, int_reqs):.1f}%; "
      f"{int_eng.stats['prefill_steps']} batched prefill steps for "
      f"{int_eng.stats['cohorts']} admission cohorts")

# --- accumulator census on the real LM head ----------------------------------
head = qparams_nm["embed"]  # tied head, QTensor (V, d) -> dot length d
x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
x_qp = activation_qparams(jnp.min(x), jnp.max(x), 8)
xq = quantize(x, x_qp)
for bits in (14, 16, 18):
    c = matmul_census(head.values.astype(jnp.int32), xq, acc_bits=bits)
    print(f"[5] LM-head dots @ {bits}b: {int(c.n_persistent)} persistent, "
          f"{int(c.n_transient)} transient of {int(c.n_dots)} "
          f"(sorted accumulation removes the transient share)")
