"""Training driver example: a reduced LM end-to-end with the production
substrate — sharded init, AdamW+cosine, async checkpointing, supervised
restart, resumable data iterator. (The paper's kind is inference, so the
flagship end-to-end example is serve_quantized.py; this one exercises the
training half of the framework. On a real pod, launch/train.py runs the
full configs with the same code path.)

  PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step
from repro.configs import get_config
from repro.data import TokenStream
from repro.models.model import build_model, param_count
from repro.optim import adamw, cosine_schedule
from repro.runtime import FailureInjector, TrainSupervisor

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = get_config("qwen2-1.5b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw(lr=cosine_schedule(3e-3, args.steps, warmup_steps=20))
opt_state = opt.init(params)
print(f"model: {param_count(params):,} params; {args.steps} steps of "
      f"batch {args.batch} x seq {args.seq}")

data = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   batch_size=args.batch)


@jax.jit
def step_fn(state, batch):
    params, opt_state = state
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    params, opt_state = opt.update(grads, opt_state, params)
    return (params, opt_state), {"loss": loss}


losses = []


def next_batch():
    b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    return b


with tempfile.TemporaryDirectory() as ckpt_dir:
    # inject a "node failure" mid-run: the supervisor restores and resumes
    sup = TrainSupervisor(
        ckpt_dir, step_fn, ckpt_every=25,
        failure_injector=FailureInjector({args.steps // 2}),
    )
    state, step = sup.run((params, opt_state), next_batch, args.steps,
                          data=data)
    params, opt_state = state
    print(f"finished at step {step} with {sup.restarts} restart(s); "
          f"last checkpoint step {latest_step(ckpt_dir)}")

# loss trend: evaluate on held-out stream
eval_stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch_size=args.batch, seed=999)
batch = {k: jnp.asarray(v) for k, v in eval_stream.next_batch().items()}
final_loss = float(model.loss(params, batch))
rand_loss = float(np.log(cfg.vocab_size))
print(f"held-out loss {final_loss:.3f} vs random {rand_loss:.3f} "
      f"-> learned structure: {final_loss < rand_loss - 0.2}")
