#!/usr/bin/env bash
# Tier-1 offline CI: runs the full test suite exactly as the roadmap
# specifies. Works from any checkout location, no network, no TPU.
set -euo pipefail
cd "$(dirname "$0")/.."

# pythonpath is also set via pyproject.toml [tool.pytest.ini_options];
# exporting it here keeps bare `python -m pytest` and subprocess tests
# (launch/dryrun.py) working identically.
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
