#!/usr/bin/env bash
# Tier-1 offline CI. Works from any checkout location, no network, no TPU.
#
#   1. full single-device test suite (exactly as the roadmap specifies)
#   2. forced-multi-device shard: sharded pqs_dot + integer serving on an
#      8-way host-device mesh (tests/test_sharded_dispatch.py self-skips
#      in pass 1, so this is the only place it runs)
#   3. examples/quickstart.py smoke run (the paper's idea end-to-end)
set -euo pipefail
cd "$(dirname "$0")/.."

# pythonpath is also set via pyproject.toml [tool.pytest.ini_options];
# exporting it here keeps bare `python -m pytest` and subprocess tests
# (launch/dryrun.py) working identically.
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

echo "== multi-device shard (8 forced host devices) =="
REPRO_FORCE_MULTIDEVICE=1 python -m pytest -x -q tests/test_sharded_dispatch.py

echo "== quickstart smoke =="
python examples/quickstart.py

echo "== kernel bench smoke (one-pass vs two-pass sort, CPU interpret) =="
python -m benchmarks.run --only kbench --quick
