#!/usr/bin/env bash
# Tier-1 offline CI. Works from any checkout location, no network, no TPU.
#
# One definition shared by local runs and .github/workflows/ci.yml: every
# Actions job invokes a single stage of this script, so what CI gates is
# exactly what `scripts/ci.sh --stage all` checks on a laptop.
#
#   scripts/ci.sh [--stage lint|unit|shard|smoke|bench|serve|fault|certify|all] [pytest args]
#
#   lint   ruff check + ruff format --check (config in pyproject.toml);
#          skipped with a notice when ruff is not installed locally (the
#          offline container does not ship it) — but a hard FAILURE when
#          it is missing under CI ($CI/$GITHUB_ACTIONS set), so a broken
#          setup step can never silently skip the lint gate
#   unit   full single-device test suite (exactly as the roadmap
#          specifies), incl. the property-based K-shard parity suite
#          (tests/test_property_parity.py, >= 200 drawn cases per run
#          through the hypothesis shim); extra args go to pytest
#   shard  forced-multi-device shard: sharded pqs_dot + integer serving
#          + nm-storage composition + the K-sharded (k_axis) pairwise-
#          exchange sweep (the log2(S) ppermute butterfly combine —
#          dense + nm, all six policies, S=2 and S=4, incl. total K =
#          2x MAX_STREAM_K), the deferred/overlapped combine parity,
#          and the serve_mode pool-sharded decode, all on an 8-way
#          host-device mesh (the selected tests self-skip in the unit
#          stage, so this is the only place they run; test_nm_policy's
#          single-device tests already ran in unit and are not
#          repeated here)
#   smoke  examples/quickstart.py (the paper's idea end-to-end)
#   bench  kernel bench smoke -> BENCH_kernels.json, gated against the
#          committed CPU baseline (see REPRO_BENCH_TOL below)
#   serve  serving throughput smoke (dense / paged / int8-paged under
#          Poisson load) -> BENCH_serving.json, tokens/s gated against
#          the committed CPU baseline (same REPRO_BENCH_TOL)
#   fault  fault-tolerance suite on an 8-way forced host-device mesh:
#          supervisors, snapshot/restore bit-exactness, census-triggered
#          degradation, and the mesh-member-drop remesh-recovery tests
#          that self-skip in the unit stage
#   certify  accumulator-safety certification gate on the same 8-way
#          forced mesh: tiny-model QAT -> certify -> serve smoke proving
#          the certified engine decodes a drifted workload with ZERO
#          census events, bit-identical to the censused path, while an
#          uncertified engine on the same fleet still degrades
#   all    every stage above, in order (the default)
set -euo pipefail
cd "$(dirname "$0")/.."

# pythonpath is also set via pyproject.toml [tool.pytest.ini_options];
# exporting it here keeps bare `python -m pytest` and subprocess tests
# (launch/dryrun.py) working identically.
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE="all"
if [[ "${1:-}" == "--stage" ]]; then
    STAGE="${2:?--stage needs an argument}"
    shift 2
fi
case "$STAGE" in
    lint|unit|shard|smoke|bench|serve|fault|certify|all) ;;
    *) echo "unknown stage '$STAGE'" \
            "(lint|unit|shard|smoke|bench|serve|fault|certify|all)" >&2
       exit 2 ;;
esac

# Interpret-mode CPU wall-times jitter >2x even on one machine (single
# --quick rep) and runner generations vary another 2-3x, so the CI
# wiring widens the guard: the catch target is structural regressions
# (a disabled fast path, an accidental O(K^2) — those show up as 10x+),
# not jitter. `benchmarks/run.py --check-against` itself defaults to
# 1.5x for stable same-machine comparisons.
REPRO_BENCH_TOL="${REPRO_BENCH_TOL:-8.0}"

STAGE_NAMES=()
STAGE_SECS=()

run_stage() {
    local name="$1"; shift
    echo
    echo "== stage: $name =="
    local t0=$SECONDS
    "$@"
    STAGE_NAMES+=("$name")
    STAGE_SECS+=("$((SECONDS - t0))")
}

summary() {
    echo
    echo "== stage timing summary =="
    local i
    for i in "${!STAGE_NAMES[@]}"; do
        printf '  %-8s %4ss\n' "${STAGE_NAMES[$i]}" "${STAGE_SECS[$i]}"
    done
}
trap summary EXIT

lint_stage() {
    if command -v ruff >/dev/null 2>&1; then
        ruff check src tests benchmarks examples scripts
        ruff format --check src tests benchmarks examples scripts
    elif [[ -n "${CI:-}${GITHUB_ACTIONS:-}" ]]; then
        # under CI the setup step installs ruff; its absence means the
        # environment is broken, and a skip here would silently drop
        # the lint gate from every run
        echo "ruff not installed under CI — lint stage FAILED" >&2
        return 1
    else
        echo "ruff not installed — lint stage skipped (CI installs it)"
    fi
}

unit_stage() {
    python -m pytest -x -q "$@"
}

shard_stage() {
    # 8 forced host devices: the K-shard sweep needs a 3-axis
    # ("data", "model", "k") mesh next to the M/N layouts
    REPRO_FORCE_MULTIDEVICE=8 python -m pytest -x -q \
        tests/test_sharded_dispatch.py \
        "tests/test_nm_policy.py::test_nm_sharded_bit_identical" \
        "tests/test_nm_policy.py::test_nm_sharded_census_counts_once" \
        "tests/test_nm_policy.py::test_nm_gather_sharded_k_axis"
}

smoke_stage() {
    python examples/quickstart.py
}

bench_stage() {
    python -m benchmarks.run --only kbench --quick \
        --check-against benchmarks/baselines/BENCH_kernels_cpu.json \
        --tolerance "$REPRO_BENCH_TOL"
}

serve_stage() {
    python -m benchmarks.run --only serve --quick \
        --check-serving-against benchmarks/baselines/BENCH_serving_cpu.json \
        --tolerance "$REPRO_BENCH_TOL"
}

fault_stage() {
    # multi-device members (elastic remesh, mesh-member drop + remesh
    # recovery) only run here; the rest also ran single-device in unit
    REPRO_FORCE_MULTIDEVICE=8 python -m pytest -x -q \
        tests/test_fault_tolerance.py \
        tests/test_serving_fleet.py
}

certify_stage() {
    # the certification acceptance gate (see tests/test_certify.py):
    # QAT -> certify -> serve on the same forced mesh the fault stage
    # uses, proving the census-free path and its bit-identity
    REPRO_FORCE_MULTIDEVICE=8 python -m pytest -x -q \
        tests/test_certify.py
}

case "$STAGE" in
    lint)  run_stage lint lint_stage ;;
    unit)  run_stage unit unit_stage "$@" ;;
    shard) run_stage shard shard_stage ;;
    smoke) run_stage smoke smoke_stage ;;
    bench) run_stage bench bench_stage ;;
    serve) run_stage serve serve_stage ;;
    fault) run_stage fault fault_stage ;;
    certify) run_stage certify certify_stage ;;
    all)
        run_stage lint lint_stage
        run_stage unit unit_stage "$@"
        run_stage shard shard_stage
        run_stage smoke smoke_stage
        run_stage bench bench_stage
        run_stage serve serve_stage
        run_stage fault fault_stage
        run_stage certify certify_stage
        ;;
esac
