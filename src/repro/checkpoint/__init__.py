from repro.checkpoint.ckpt import (  # noqa: F401
    AsyncCheckpointer,
    cleanup,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
