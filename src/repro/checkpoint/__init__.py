from repro.checkpoint.ckpt import (  # noqa: F401
    AsyncCheckpointer,
    cleanup,
    latest_step,
    load_checkpoint,
    restore_checkpoint,
    save_checkpoint,
    unflatten_like,
)
