"""Sharded checkpointing: atomic step directories, async writer, retention.

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, shapes, dtypes
           shard_<i>.npz        arrays, chunked ~512 MB per file
         <dir>/step_<N>.tmp/    staging; renamed atomically when complete

Restore is sharding-aware: pass ``shardings`` (a pytree of
jax.sharding.Sharding or a single sharding) and each leaf is device_put
directly to its target placement — on a real cluster each host reads only
the bytes it needs via np.load's lazy zip access.

``AsyncCheckpointer`` snapshots device arrays to host (blocking, fast) and
does file IO on a background thread — the train loop never waits on disk
(fault-tolerance story in DESIGN.md §6 / runtime/).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in leaves]
    vals = [v for _, v in leaves]
    return keys, vals, jax.tree_util.tree_structure(tree)


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names including the ml_dtypes family (bfloat16, fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _is_native(dt: np.dtype) -> bool:
    try:
        return np.dtype(dt.name) == dt and dt.kind in "biufc"
    except TypeError:
        return False


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write an atomic checkpoint for ``step``. Returns the final path.

    Exotic dtypes (bfloat16, fp8 — unsupported by .npz) are stored as raw
    uint8 bytes and re-viewed on restore; the manifest records the truth.
    """
    keys, vals, _ = _flatten(tree)
    host_vals = [np.asarray(v) for v in vals]

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    # chunk arrays into shards of ~_SHARD_BYTES
    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    manifest = {"step": step, "leaves": []}
    for k, v in zip(keys, host_vals):
        if sizes[-1] > 0 and sizes[-1] + v.nbytes > _SHARD_BYTES:
            shards.append({})
            sizes.append(0)
        sid = len(shards) - 1
        raw = not _is_native(v.dtype)
        stored = (
            np.ascontiguousarray(v).view(np.uint8).reshape(-1) if raw else v
        )
        shards[sid][k.replace("/", "__")] = stored
        sizes[-1] += v.nbytes
        manifest["leaves"].append(
            {"key": k, "shard": sid, "shape": list(v.shape),
             "dtype": v.dtype.name, "raw": raw}
        )
    for i, sh in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"), **sh)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``target``. Returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    opened: dict[int, Any] = {}

    def shard(i: int):
        if i not in opened:
            opened[i] = np.load(os.path.join(path, f"shard_{i}.npz"))
        return opened[i]

    keys, vals, _ = _flatten(target)
    flat_shardings = None
    if shardings is not None:
        if isinstance(shardings, jax.sharding.Sharding):
            flat_shardings = [shardings] * len(vals)
        else:
            flat_shardings = [
                s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]
            ]

    out = []
    for i, (k, tgt) in enumerate(zip(keys, vals)):
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        e = by_key[k]
        arr = shard(e["shard"])[k.replace("/", "__")]
        if e.get("raw"):
            arr = arr.view(_np_dtype(e["dtype"])).reshape(e["shape"])
        if list(arr.shape) != list(np.shape(tgt)):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs target {np.shape(tgt)}"
            )
        if flat_shardings is not None:
            arr = jax.device_put(arr, flat_shardings[i])
        out.append(arr)
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out), step


def load_checkpoint(
    ckpt_dir: str, step: Optional[int] = None
) -> tuple[dict[str, np.ndarray], int]:
    """Target-free restore: flat ``{key: array}`` straight off the manifest.

    ``restore_checkpoint`` needs a shape-matching target tree, which
    rules out payloads with variable-length leaves (e.g. the serving
    engine's pickled request-state blob — its length changes between
    snapshots). This loader reconstructs every leaf exactly as stored;
    pair with ``unflatten_like`` to rebuild a pytree around the
    shape-stable subset.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    opened: dict[int, Any] = {}

    def shard(i: int):
        if i not in opened:
            opened[i] = np.load(os.path.join(path, f"shard_{i}.npz"))
        return opened[i]

    flat: dict[str, np.ndarray] = {}
    for e in manifest["leaves"]:
        arr = shard(e["shard"])[e["key"].replace("/", "__")]
        if e.get("raw"):
            arr = arr.view(_np_dtype(e["dtype"])).reshape(e["shape"])
        flat[e["key"]] = arr
    return flat, step


def unflatten_like(target: Any, flat: dict[str, np.ndarray]) -> Any:
    """Rebuild ``target``'s tree structure from a ``load_checkpoint`` dict.

    Leaf values come from ``flat`` by the same path keys ``_flatten``
    produces; ``target`` supplies only the structure (leaf shapes are
    free to differ — that is the point for variable-length blobs).
    """
    keys, _, treedef = _flatten(target)
    missing = [k for k in keys if k not in flat]
    if missing:
        raise KeyError(f"checkpoint missing leaves {missing!r}")
    return jax.tree_util.tree_unflatten(treedef, [flat[k] for k in keys])


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Retain the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread writer: snapshot on-thread, IO off-thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # one in flight at a time
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def run():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                cleanup(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
