"""Architecture config registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    cells_for,
)

# arch id -> module name
_REGISTRY = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-medium": "whisper_medium",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "command-r-35b": "command_r_35b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-1.5b": "qwen2_1_5b",
    "mamba2-2.7b": "mamba2_2_7b",
}
# Paper-scale configs (the PQS paper's own MLP/CNN models) live in
# repro.configs.paper — they are not LM archs and have their own schema.

ARCH_IDS = list(_REGISTRY)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
