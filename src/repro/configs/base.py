"""Model / run configuration schema shared by every architecture.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<arch_id>.py`` (exact published numbers), plus reduced
"smoke" variants of the same family for CPU tests. ``ShapeSpec`` encodes the
assigned input-shape cells (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert FFN width
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # every `period` layers, layers at `offset` (mod period) are MoE
    layer_period: int = 1
    layer_offset: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple[int, ...]] = None  # qwen2-vl M-RoPE
    sliding_window: Optional[int] = None  # local-attention window
    global_period: Optional[int] = None  # gemma3: 1 global per N layers
    attn_logit_softcap: Optional[float] = None
    # structure
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "silu"  # silu (gated) | gelu (gated) | gelu_plain
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: x *= sqrt(d_model)
    input_is_embeddings: bool = False  # vlm/audio stubs feed embeddings
    # hybrid (jamba): attention layer at i % attn_period == attn_offset
    attn_period: int = 1
    attn_offset: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # max context the arch supports (for decode cache sanity checks)
    max_seq_len: int = 131_072
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # sequence parallelism: shard the residual stream's seq axis on
    # "model" between layers (norms/elementwise run sharded; TP boundary
    # all-reduces become reduce-scatter + all-gather pairs). §Perf iter 4.
    seq_parallel: bool = False
    # MoE local-groups dispatch: fold a slice of the sequence into the
    # group axis and shard groups over ALL mesh axes with expert weights
    # replicated over "model" — dispatch/expert-FFN/combine become fully
    # local (zero MoE collectives). Right call when experts are small
    # (granite d_ff=512); EP stays better for big experts. §Perf iter 5.
    moe_local_groups: bool = False
    # remat / scan
    remat: bool = True
    scan_layers: bool = True
    # scan unroll factor for layer loops; True = fully unroll. The roofline
    # probe lowers with True because HLO cost analysis counts while-loop
    # bodies exactly once (launch/dryrun.py).
    scan_unroll: Any = 1
    # attention chunking threshold (memory-efficient attention)
    attn_chunk_q: int = 512
    attn_chunk_threshold: int = 4096

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def validate(self) -> None:
        assert self.family in (
            "dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"
        )
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.family in ("moe", "hybrid"):
            assert self.moe is not None
        if self.family in ("ssm", "hybrid"):
            assert self.ssm is not None
        if self.is_encoder_decoder:
            assert self.encoder_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (arch x shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / bounded-KV): see DESIGN.md.
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "jamba-v0.1-52b", "gemma3-12b"}


def cells_for(arch_name: str) -> list[str]:
    """The live shape cells for an arch (skips documented in DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
