"""command-r-35b — dense [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000, no-bias.
Command-R ties embeddings and uses a large vocab.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    norm="layernorm",  # command-r uses LayerNorm (no bias)
    activation="silu",
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
)

SMOKE_CONFIG = ModelConfig(
    name="command-r-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    head_dim=16,
    norm="layernorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=512,
)
