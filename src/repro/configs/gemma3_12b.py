"""gemma3-12b — dense with 5:1 local:global attention [hf:google/gemma-3].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, sliding window
(1024) on local layers, 1 global layer per 6 (global_period=6), 128k
context. Gemma3 uses gated GELU and qk-norm.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    qk_norm=True,
    sliding_window=1024,
    global_period=6,
    norm="rmsnorm",
    activation="gelu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    max_seq_len=131_072 * 8,  # long-context arch (runs long_500k)
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=6,  # one full 5-local + 1-global period
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
    sliding_window=32,
    global_period=6,
    norm="rmsnorm",
    activation="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    max_seq_len=1024,
)
