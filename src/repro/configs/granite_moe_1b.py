"""granite-moe-1b-a400m — MoE [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 32 experts top-8 on every layer.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
    norm="rmsnorm",
    activation="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-1b-smoke",
    family="moe",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=128,
    head_dim=12,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=32),
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=256,
)
