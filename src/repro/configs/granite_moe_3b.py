"""granite-moe-3b-a800m — MoE [hf:ibm-granite/granite-3.0-*-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 40 experts top-8 on every layer.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff=512),
    norm="rmsnorm",
    activation="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=64),
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=512,
)
