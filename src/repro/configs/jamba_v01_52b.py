"""jamba-v0.1-52b — hybrid Mamba+attention MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336, MoE 16e top-2, Mamba:attn 1:7
interleave. Published structure: attn_layer_period=8, attn_layer_offset=4
(layers 4, 12, 20, 28 are attention; the rest Mamba); expert_layer_period=2,
expert_layer_offset=1 (odd layers are MoE, even are dense MLP).
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    attn_period=8,
    attn_offset=4,
    moe=MoEConfig(
        num_experts=16, top_k=2, d_ff=14336, layer_period=2, layer_offset=1
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    norm="rmsnorm",
    activation="silu",
    rope_theta=10_000.0,  # jamba attn layers carry no RoPE in v0.1; kept for ablation
    max_seq_len=262_144,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,  # one full period: attn at 4, MoE on odd layers
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_period=8,
    attn_offset=4,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, layer_period=2, layer_offset=1),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=32),
    norm="rmsnorm",
    activation="silu",
    max_seq_len=1024,
)
