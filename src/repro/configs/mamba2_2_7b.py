"""mamba2-2.7b — attention-free SSM (SSD) [arXiv:2405.21060].

64L d_model=2560, d_inner = 2*d_model = 5120, headdim=64 (80 SSM heads),
d_state=128, vocab=50280. Pure Mamba2 blocks (no attention, no FFN).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=1_048_576,  # O(1)-state decode: runs long_500k
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=32),
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=1024,
)
