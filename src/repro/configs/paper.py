"""The PQS paper's own evaluation models (§3.1, §4, §5).

- 1-layer MLP (linear+ReLU) on an MNIST-class task — Fig 2 overflow census.
- 2-layer MLP (784x784 hidden + 784x10 head) — Fig 3 P->Q vs Q->P.
- Small conv net standing in for MobileNetV2/ResNet-18 scale — Fig 4/5.
  (No CIFAR offline; see DESIGN.md §8 — trends, not absolute accuracies.)
"""

from __future__ import annotations

import dataclasses

from repro.core.pqs import PQSConfig


@dataclasses.dataclass(frozen=True)
class PaperNetConfig:
    name: str
    kind: str  # mlp1 | mlp2 | convnet
    in_dim: int = 784
    hidden: int = 784
    num_classes: int = 10
    # convnet only
    channels: tuple[int, ...] = (16, 32)
    img_hw: int = 14
    pqs: PQSConfig = dataclasses.field(default_factory=PQSConfig)


MLP1 = PaperNetConfig(name="mlp1-mnist", kind="mlp1")
MLP2 = PaperNetConfig(name="mlp2-mnist", kind="mlp2")
CONVNET = PaperNetConfig(name="convnet-cifar-scale", kind="convnet")
