"""qwen2-1.5b — dense [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, QKV bias,
tied embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=12,
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    max_seq_len=512,
)
