"""qwen2-vl-72b — VLM backbone [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE. The vision
frontend is a stub: input_specs() feeds precomputed patch/text embeddings
(B, S, d_model) plus 3-section M-RoPE position ids (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,  # qwen2 family uses QKV bias
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # t/h/w sections of head_dim//2
    activation="silu",
    norm="rmsnorm",
    input_is_embeddings=True,
    max_seq_len=32_768,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(2, 3, 3),
    activation="silu",
    norm="rmsnorm",
    input_is_embeddings=True,
    max_seq_len=512,
)
