"""qwen3-32b — dense with qk_norm [hf:Qwen/Qwen3 family].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm, no QKV
bias (qwen3 dropped it), head_dim=128.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    qkv_bias=False,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
    norm="rmsnorm",
    activation="silu",
    max_seq_len=512,
)
