"""whisper-medium — audio encoder-decoder [arXiv:2212.04356].

24L (per stack) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. Conv
frontend is a stub: encoder input is precomputed frame embeddings
(B, S_enc, d_model); decoder consumes token ids. Whisper uses plain (non-
gated) GELU MLPs, LayerNorm, learned positions (we use sinusoidal-free
RoPE-less absolute embeddings folded into the stub; see models/encdec.py).
Assigned-shape convention (DESIGN.md §5): train/prefill use encoder frames
= decoder tokens = seq_len; decode uses decoder KV = seq_len with a fixed
1500-frame encoder context.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    qkv_bias=True,
    norm="layernorm",
    activation="gelu_plain",
    input_is_embeddings=True,  # encoder side
    rope_theta=10_000.0,
    max_seq_len=32_768,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    is_encoder_decoder=True,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
    norm="layernorm",
    activation="gelu_plain",
    input_is_embeddings=True,
    max_seq_len=512,
)
