"""PQS core: prune, quantize, and sort for low-bitwidth accumulation."""

from repro.core.pqs import PQSConfig  # noqa: F401
