"""PQS core: prune, quantize, and sort for low-bitwidth accumulation."""

from repro.core.dispatch import (  # noqa: F401
    IntegerLinConfig,
    integer_lin,
    pqs_dot,
)
from repro.core.pqs import PQSConfig  # noqa: F401
