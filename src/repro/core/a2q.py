"""A2Q baseline: accumulator-aware quantization (Colbert et al., ICCV'23).

The paper's primary comparison point (paper §3.1, Fig 5). A2Q guarantees
overflow-free accumulation into a p-bit register by bounding each dot
product's quantized weight L1 norm:

    sum_i |w_i^q| = ||w^q||_1 <= B := (2^(p-1) - 1) / (2^(b-1))

(worst case: every activation maximal, |x_i^q| = 2^(b-1)). A2Q uses
per-output-channel weight quantization; we implement the projection form in
the *integer* domain, which is the only domain where the bound is actually
enforceable: with max-calibrated scales the FP constraint is the
scale-invariant shape condition ||w||_1/||w||_inf <= B/qmax, so shrinking a
row in FP32 changes nothing after requantization. Instead we quantize
per-channel, then multiplicatively shrink and *truncate toward zero* the
integer row — truncation guarantees the post-projection L1 never exceeds the
bound. During QAT the projection runs inside a straight-through estimator,
reproducing both A2Q's guarantee and its accuracy cost / induced
unstructured sparsity (small integers truncate to zero) that PQS avoids.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import qrange


def a2q_l1_bound(weight_bits: int, acc_bits: int) -> float:
    """Maximum allowed ||w^q||_1 for overflow-free p-bit accumulation."""
    return (2 ** (acc_bits - 1) - 1) / (2 ** (weight_bits - 1))


@partial(jax.jit, static_argnames=("weight_bits", "acc_bits"))
def a2q_quantize_project(
    w: jax.Array, weight_bits: int, acc_bits: int
) -> tuple[jax.Array, jax.Array]:
    """Per-channel quantize + L1 projection. w: (out, K).

    Returns (wq, scale) with wq int32-carrier, scale (out,) f32, and every
    row satisfying sum|wq| <= B exactly.
    """
    _, qmax = qrange(weight_bits)
    bound = a2q_l1_bound(weight_bits, acc_bits)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=-1, keepdims=True), 1e-8)
    scale = amax / qmax  # per-channel symmetric scale
    wq = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    l1 = jnp.sum(jnp.abs(wq), axis=-1, keepdims=True)
    factor = jnp.minimum(1.0, bound / jnp.maximum(l1, 1.0))
    # trunc toward zero => sum |trunc(wq * f)| <= f * sum |wq| <= bound
    wq = jnp.trunc(wq * factor).astype(jnp.int32)
    return wq, scale[..., 0]


def a2q_fake_quant(w: jax.Array, weight_bits: int, acc_bits: int) -> jax.Array:
    """QAT forward for A2Q weights: quantize+project+dequantize with STE."""
    wq, scale = a2q_quantize_project(w, weight_bits, acc_bits)
    w_star = wq.astype(jnp.float32) * scale[:, None]
    return w + jax.lax.stop_gradient(w_star - w)


def a2q_violations(wq: jax.Array, weight_bits: int, acc_bits: int) -> jax.Array:
    """Number of rows violating the bound (0 after projection, by design)."""
    l1 = jnp.sum(jnp.abs(wq.astype(jnp.int32)), axis=-1)
    return jnp.sum(l1 > a2q_l1_bound(weight_bits, acc_bits))


def a2q_sparsity(wq: jax.Array) -> jax.Array:
    """Fraction of zero integers — A2Q's induced unstructured sparsity."""
    return jnp.mean((wq == 0).astype(jnp.float32))
