"""A2Q baseline: accumulator-aware quantization (Colbert et al., ICCV'23).

The paper's primary comparison point (paper §3.1, Fig 5). A2Q guarantees
overflow-free accumulation into a p-bit register by bounding each dot
product's quantized weight L1 norm:

    sum_i |w_i^q| = ||w^q||_1 <= B := (2^(p-1) - 1) / (2^(b-1))

(worst case: every activation maximal, |x_i^q| = 2^(b-1)). A2Q uses
per-output-channel weight quantization; we implement the projection form in
the *integer* domain, which is the only domain where the bound is actually
enforceable: with max-calibrated scales the FP constraint is the
scale-invariant shape condition ||w||_1/||w||_inf <= B/qmax, so shrinking a
row in FP32 changes nothing after requantization. Instead we quantize
per-channel, then multiplicatively shrink and *truncate toward zero* the
integer row — truncation guarantees the post-projection L1 never exceeds the
bound. During QAT the projection runs inside a straight-through estimator,
reproducing both A2Q's guarantee and its accuracy cost / induced
unstructured sparsity (small integers truncate to zero) that PQS avoids.

Asymmetric tightening (certification): the symmetric L1 bound above assumes
|x^q| <= 2^(b-1) on *both* sides, but the serving path clips integer
activation codes to qrange(b) = [-2^(b-1), 2^(b-1)-1] — the positive side is
one code short. The true worst case is therefore one-sided and
sign-dependent: splitting each weight row into positive and negative parts
(wp = sum of positive entries, wn = sum of |negative| entries) the extreme
partial-sum excursions under ANY accumulation order are

    pos(w) = qhi * wp + |qlo| * wn     (all products driven positive)
    neg(w) = |qlo| * wp + qhi * wn     (all products driven negative)

and a p-bit register is safe iff pos <= 2^(p-1)-1 and neg <= 2^(p-1).
Every partial sum is a subset sum of the K products, so these two numbers
bound every intermediate value reachable under any ordering/tiling — the
foundation of `core.certify`. Functions below accept an optional frozen
activation range (``act_qparams`` from calibrate→freeze, or plain
``act_bits``) and fall back to the legacy symmetric assumption when absent.

Float32 caveat: the jnp projections compute row sums in f32, exact for
excursions up to 2^24. The certification pass (`core.certify`) redoes the
arithmetic host-side in int64 and is the authority on the guarantee.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import qrange


def act_code_range(
    act_qparams=None, act_bits: int | None = None
) -> tuple[int, int] | None:
    """Admissible integer activation codes at serving time, or None.

    The serving path (`dispatch.qtensor_dot`) clips quantized activations to
    qrange(bits) on both the static (asymmetric or symmetric) and dynamic
    routes, so the admissible set is the full signed code range of the
    frozen bitwidth — for *any* input, drifted workloads included. That clip
    is what makes certificates sound without assumptions on the data.
    """
    if act_qparams is not None:
        return qrange(int(act_qparams.bits))
    if act_bits is not None:
        return qrange(int(act_bits))
    return None


def a2q_acc_caps(acc_bits: int) -> tuple[int, int]:
    """(max positive, max |negative|) value a p-bit register can hold."""
    return 2 ** (acc_bits - 1) - 1, 2 ** (acc_bits - 1)


def a2q_l1_bound(weight_bits: int, acc_bits: int) -> float:
    """Maximum allowed ||w^q||_1 for overflow-free p-bit accumulation.

    Sign-agnostic sufficient condition (legacy A2Q form): a row of unknown
    sign pattern can drive the register to |qlo| * ||w^q||_1 on either
    side, so no asymmetric tightening is possible at the L1 level — use
    `a2q_row_bounds` for the per-row sign-split bound that certification
    relies on.
    """
    return (2 ** (acc_bits - 1) - 1) / (2 ** (weight_bits - 1))


def a2q_row_bounds(
    wq: jax.Array,
    weight_bits: int | None = None,
    *,
    act_qparams=None,
    act_bits: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Exact worst-case accumulator excursions per row. wq: (..., K) ints.

    Returns (pos, neg): the largest positive value and largest negative
    magnitude any partial sum of x^q · w^q can reach over admissible integer
    activations. Uses the frozen activation range when given, else the
    legacy symmetric |x^q| <= 2^(b-1) with b = weight_bits.
    """
    rng = act_code_range(act_qparams, act_bits)
    if rng is None:
        if weight_bits is None:
            raise ValueError("need weight_bits or an activation range")
        mag = 2 ** (weight_bits - 1)
        qlo, qhi = -mag, mag
    else:
        qlo, qhi = rng
    w = wq.astype(jnp.float32)
    wp = jnp.sum(jnp.maximum(w, 0.0), axis=-1)
    wn = jnp.sum(jnp.maximum(-w, 0.0), axis=-1)
    pos = qhi * wp + (-qlo) * wn
    neg = (-qlo) * wp + qhi * wn
    return pos, neg


def _resolve_act_bits(act_qparams, act_bits) -> int | None:
    if act_qparams is not None:
        return int(act_qparams.bits)
    return None if act_bits is None else int(act_bits)


@partial(jax.jit, static_argnames=("weight_bits", "acc_bits", "act_bits"))
def _quantize_project(
    w: jax.Array, weight_bits: int, acc_bits: int, act_bits: int | None
) -> tuple[jax.Array, jax.Array]:
    _, qmax = qrange(weight_bits)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=-1, keepdims=True), 1e-8)
    scale = amax / qmax  # per-channel symmetric scale
    wq = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    if act_bits is None:
        bound = a2q_l1_bound(weight_bits, acc_bits)
        l1 = jnp.sum(jnp.abs(wq), axis=-1, keepdims=True)
        factor = jnp.minimum(1.0, bound / jnp.maximum(l1, 1.0))
    else:
        cap_pos, cap_neg = a2q_acc_caps(acc_bits)
        pos, neg = a2q_row_bounds(wq, act_bits=act_bits)
        factor = jnp.minimum(
            jnp.minimum(1.0, cap_pos / jnp.maximum(pos, 1.0)),
            cap_neg / jnp.maximum(neg, 1.0),
        )[..., None]
    # trunc toward zero => sum |trunc(wq * f)| <= f * sum |wq| <= bound,
    # and the same contraction holds for the sign-split pos/neg sums
    wq = jnp.trunc(wq * factor).astype(jnp.int32)
    return wq, scale[..., 0]


def a2q_quantize_project(
    w: jax.Array,
    weight_bits: int,
    acc_bits: int,
    act_qparams=None,
    act_bits: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-channel quantize + accumulator projection. w: (out, K).

    Returns (wq, scale) with wq int32-carrier, scale (out,) f32, and every
    row satisfying the accumulator bound: the legacy symmetric L1 form by
    default, or the tighter sign-split form against the frozen activation
    range when ``act_qparams``/``act_bits`` is given.
    """
    return _quantize_project(
        w, weight_bits, acc_bits, _resolve_act_bits(act_qparams, act_bits)
    )


def a2q_fake_quant(
    w: jax.Array,
    weight_bits: int,
    acc_bits: int,
    act_qparams=None,
    act_bits: int | None = None,
) -> jax.Array:
    """QAT forward for A2Q weights: quantize+project+dequantize with STE."""
    wq, scale = a2q_quantize_project(w, weight_bits, acc_bits, act_qparams, act_bits)
    w_star = wq.astype(jnp.float32) * scale[:, None]
    return w + jax.lax.stop_gradient(w_star - w)


def a2q_violations(
    wq: jax.Array,
    weight_bits: int,
    acc_bits: int,
    act_qparams=None,
    act_bits: int | None = None,
) -> jax.Array:
    """Number of rows violating the bound (0 after projection, by design).

    With a frozen activation range this checks the sign-split condition —
    the same one serving-time certification enforces — so the QAT signal
    matches what `core.certify` will later verify.
    """
    bits = _resolve_act_bits(act_qparams, act_bits)
    if bits is None:
        l1 = jnp.sum(jnp.abs(wq.astype(jnp.int32)), axis=-1)
        return jnp.sum(l1 > a2q_l1_bound(weight_bits, acc_bits))
    cap_pos, cap_neg = a2q_acc_caps(acc_bits)
    pos, neg = a2q_row_bounds(wq, act_bits=bits)
    return jnp.sum((pos > cap_pos) | (neg > cap_neg))


def a2q_sparsity(wq: jax.Array) -> jax.Array:
    """Fraction of zero integers — A2Q's induced unstructured sparsity."""
    return jnp.mean((wq == 0).astype(jnp.float32))
