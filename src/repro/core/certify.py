"""Accumulator-safety certification: frozen weights -> proof of no overflow.

The census machinery (overflow counters, CensusWatch degradation) *observes*
accumulator safety at serving time; this module *proves* it ahead of time,
so certified sites can drop the census and stepwise-saturation bookkeeping
from the hot path entirely (`pqs_dot(..., certified=True)`).

The bound. Serving quantizes activations and clips their integer codes to
qrange(b) = [qlo, qhi] = [-2^(b-1), 2^(b-1)-1] on every path (static
asymmetric, static symmetric, dynamic) — see `dispatch.qtensor_dot`. So for
ANY input, drifted workloads included, the admissible activation codes are
exactly that range. For one output row with integer weights w, split
wp = sum of positive entries, wn = sum of |negative| entries; the extreme
excursions of the dot product are

    pos(w) = qhi * wp + |qlo| * wn      (every product driven positive)
    neg(w) = |qlo| * wp + qhi * wn      (every product driven negative)

Every intermediate value of ANY accumulation order — sequential, k-tiled,
magnitude-sorted, K-sharded partials and their tree combine — is a subset
sum of the K products, and any subset sum lies in [-neg(w), pos(w)]. Hence
if pos(w) <= 2^(p-1)-1 and neg(w) <= 2^(p-1), a p-bit register can never
saturate at any step, under any policy, and the narrow result equals the
exact wide sum bit-for-bit. `acc_bits_safe` is the smallest such p.

Tightenings over the classic A2Q worst-case L1 bound:
  * one-sided: the positive activation code caps at 2^(b-1)-1, not 2^(b-1),
    and the sign-split uses each row's actual sign pattern instead of
    assuming every product can reach |qlo| * |w_i|;
  * N:M-aware: compressed `SparseQTensor` rows sum only the n_keep-of-m
    kept weights — pruned products can never fire, so the bound tightens
    by exactly the pruned mass.

Certificates hash the *integer* weight values (not scales): the guarantee
depends only on the integer codes and the activation bitwidth, so
re-calibration or activation-range drift cannot invalidate a certificate —
which is precisely why certified sites stay safe on drifted workloads.

All arithmetic here is host-side numpy int64 (exact); the jnp mirrors in
`core.a2q` are f32 training signals, this module is the authority.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pickle
from typing import Any, Optional

import jax
import numpy as np

from repro.core.qtensor import QTensor, SparseQTensor
from repro.core.quant import qrange


class CertificateError(ValueError):
    """Certificate does not match the parameters it is asked to cover."""


def acc_caps(acc_bits: int) -> tuple[int, int]:
    """(max positive value, max negative magnitude) of a p-bit register."""
    return 2 ** (acc_bits - 1) - 1, 2 ** (acc_bits - 1)


def row_excursions(
    wq: np.ndarray, act_bits: int
) -> tuple[np.ndarray, np.ndarray]:
    """Exact worst-case (pos, neg) excursions per row. wq: (..., K) ints."""
    qlo, qhi = qrange(act_bits)
    w = np.asarray(wq, dtype=np.int64)
    wp = np.maximum(w, 0).sum(axis=-1)
    wn = np.maximum(-w, 0).sum(axis=-1)
    return qhi * wp + (-qlo) * wn, (-qlo) * wp + qhi * wn


def min_acc_bits(pos: np.ndarray, neg: np.ndarray) -> int:
    """Smallest p with pos <= 2^(p-1)-1 and neg <= 2^(p-1), elementwise."""
    pmax = int(np.max(pos, initial=0))
    nmax = int(np.max(neg, initial=0))
    p = 2
    while True:
        cap_pos, cap_neg = acc_caps(p)
        if pmax <= cap_pos and nmax <= cap_neg:
            return p
        p += 1


def _leaf_rows(leaf) -> np.ndarray:
    """Integer weight rows (R, K): one row per output channel.

    Dense (..., in, out) transposes to channel-major; compressed
    (..., out, G, n_keep) flattens the kept products — the only ones that
    can ever fire, which is the N:M tightening.
    """
    v = np.asarray(jax.device_get(leaf.values))
    if isinstance(leaf, SparseQTensor):
        return v.reshape(-1, v.shape[-2] * v.shape[-1])
    return np.swapaxes(v, -1, -2).reshape(-1, v.shape[-2])


def _leaf_hash(leaf) -> str:
    """sha256 over the integer content (values; + indices/geometry for nm).

    Scales and activation qparams are deliberately excluded: the bound
    depends only on integer codes, so calibration must not invalidate it.
    """
    h = hashlib.sha256()
    v = np.asarray(jax.device_get(leaf.values))
    h.update(str(v.shape).encode())
    h.update(np.ascontiguousarray(v).tobytes())
    if isinstance(leaf, SparseQTensor):
        idx = np.asarray(jax.device_get(leaf.indices))
        h.update(np.ascontiguousarray(idx).tobytes())
        h.update(f"{leaf.m_group},{leaf.k_dim}".encode())
    return h.hexdigest()


def _site_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _site_leaves(params) -> dict[str, list[Any]]:
    """All QTensor/SparseQTensor leaves grouped by call-site name."""
    sites: dict[str, list[Any]] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor))
    )[0]:
        if isinstance(leaf, (QTensor, SparseQTensor)):
            sites.setdefault(_site_name(path), []).append(leaf)
    return sites


def _combined_hash(hashes: list[str]) -> str:
    if len(hashes) == 1:
        return hashes[0]
    h = hashlib.sha256()
    for part in sorted(hashes):
        h.update(part.encode())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class SiteCertificate:
    """Proof record for one linear call site (hashable python scalars)."""

    site: str
    acc_bits_safe: int  # smallest register width that can never saturate
    bound_pos: int      # worst-case positive excursion over all rows
    bound_neg: int      # worst-case negative magnitude over all rows
    slack: float        # headroom at the certified target width (< 0: none)
    act_bits: int       # activation code range the bound was taken over
    weight_hash: str    # sha256 of the integer weights it certifies


@dataclasses.dataclass(frozen=True)
class Certificate:
    """Per-site accumulator-safety proofs riding on a checkpoint.

    Policy-independent: the subset-sum bound covers every accumulation
    order, so one certificate serves wide/clip/wrap/sorted/* alike, K
    sharding and N:M storage included.
    """

    sites: tuple[SiteCertificate, ...]
    acc_bits: int  # target register width the slack was measured against

    def site(self, name: str) -> Optional[SiteCertificate]:
        for sc in self.sites:
            if sc.site == name:
                return sc
        return None

    def covers(self, name: str, acc_bits: int, act_bits: int) -> bool:
        """Is (site, register width, activation bits) provably safe?

        Serving with *fewer* activation bits than certified only shrinks
        the admissible code range, so narrower act_bits stay covered.
        """
        sc = self.site(name)
        return (
            sc is not None
            and sc.acc_bits_safe <= acc_bits
            and act_bits <= sc.act_bits
        )

    def verify(self, params: Any) -> None:
        """Raise CertificateError unless params carry the certified weights.

        Sites present in params but absent from the certificate are simply
        uncertified (they keep the censused path); a certified site whose
        integer weights changed is a hard error.
        """
        sites = _site_leaves(params)
        bad = []
        for sc in self.sites:
            leaves = sites.get(sc.site)
            if leaves is None:
                bad.append(f"{sc.site}: missing from params")
                continue
            now = _combined_hash([_leaf_hash(leaf) for leaf in leaves])
            if now != sc.weight_hash:
                bad.append(f"{sc.site}: weight hash mismatch")
        if bad:
            raise CertificateError(
                "certificate does not match parameters — " + "; ".join(bad)
            )

    def summary(self) -> str:
        lines = [f"certificate: target acc_bits={self.acc_bits}"]
        for sc in self.sites:
            ok = "ok" if sc.acc_bits_safe <= self.acc_bits else "UNCOVERED"
            lines.append(
                f"  {sc.site}: acc_bits_safe={sc.acc_bits_safe} "
                f"slack={sc.slack:+.3f} act_bits={sc.act_bits} [{ok}]"
            )
        return "\n".join(lines)

    # -- checkpoint riding: one uint8 blob leaf, like the fleet's meta --
    def to_leaf(self) -> np.ndarray:
        return np.frombuffer(pickle.dumps(self), dtype=np.uint8)

    @staticmethod
    def from_leaf(leaf) -> "Certificate":
        cert = pickle.loads(np.asarray(leaf, dtype=np.uint8).tobytes())
        if not isinstance(cert, Certificate):
            raise CertificateError("blob does not decode to a Certificate")
        return cert


def certify_params(
    params: Any, acc_bits: int, act_bits: int = 8
) -> Certificate:
    """Compute exact per-site accumulation bounds for a quantized tree.

    ``act_bits`` is the serving activation bitwidth for leaves without
    frozen act_qparams; leaves that carry frozen params certify against
    their own (frozen) bitwidth. Every QTensor/SparseQTensor leaf is
    certified — `Certificate.covers` then decides per site whether the
    proof reaches the width a config actually serves at.
    """
    cap_pos, cap_neg = acc_caps(acc_bits)
    site_certs = []
    for name, leaves in sorted(_site_leaves(params).items()):
        pos_max = neg_max = 0
        safe = 2
        bits = act_bits
        hashes = []
        for leaf in leaves:
            aq = leaf.act_qparams
            leaf_bits = int(aq.bits) if aq is not None else act_bits
            bits = max(bits, leaf_bits)
            pos, neg = row_excursions(_leaf_rows(leaf), leaf_bits)
            pos_max = max(pos_max, int(np.max(pos, initial=0)))
            neg_max = max(neg_max, int(np.max(neg, initial=0)))
            safe = max(safe, min_acc_bits(pos, neg))
            hashes.append(_leaf_hash(leaf))
        slack = 1.0 - max(pos_max / cap_pos, neg_max / cap_neg)
        site_certs.append(SiteCertificate(
            site=name, acc_bits_safe=safe, bound_pos=pos_max,
            bound_neg=neg_max, slack=slack, act_bits=bits,
            weight_hash=_combined_hash(hashes),
        ))
    return Certificate(sites=tuple(site_certs), acc_bits=acc_bits)


def truncate_rows(
    wq: np.ndarray, acc_bits: int, act_bits: int = 8
) -> np.ndarray:
    """Truncate integer rows toward zero until the bound holds. (R, K)->.

    The integer-domain counterpart of `a2q_quantize_project`'s shrink:
    |trunc(w * f)| <= f * |w| elementwise with signs preserved, so both
    sign-split sums contract by at least f and the result is provably
    inside the caps. Exact int64/f64 host arithmetic.
    """
    cap_pos, cap_neg = acc_caps(acc_bits)
    w = np.asarray(wq, dtype=np.int64)
    pos, neg = row_excursions(w, act_bits)
    factor = np.minimum(
        1.0,
        np.minimum(cap_pos / np.maximum(pos, 1), cap_neg / np.maximum(neg, 1)),
    )
    out = np.trunc(w.astype(np.float64) * factor[..., None]).astype(np.int64)
    return out.astype(np.asarray(wq).dtype)


def enforce_acc_bounds(params: Any, acc_bits: int, act_bits: int = 8) -> Any:
    """Project every quantized leaf inside the certifiable region.

    Post-QAT belt-and-suspenders: re-quantization rounding can leave a row
    marginally over the bound even after STE-projected training, so this
    pass truncates offending rows in the integer domain (most rows are
    untouched when QAT did its job). act_corr is recomputed for leaves
    that already carry frozen asymmetric qparams.
    """

    def conv(leaf):
        if not isinstance(leaf, (QTensor, SparseQTensor)):
            return leaf
        bits = int(leaf.act_qparams.bits) if leaf.act_qparams is not None \
            else act_bits
        v = np.asarray(jax.device_get(leaf.values))
        if isinstance(leaf, SparseQTensor):
            rows = v.reshape(-1, v.shape[-2] * v.shape[-1])
            new_v = truncate_rows(rows, acc_bits, bits).reshape(v.shape)
            corr = leaf.act_corr
            if corr is not None:
                wsum = new_v.astype(np.int64).sum(axis=(-2, -1))
                corr = np.asarray(jax.device_get(leaf.act_qparams.offset))[
                    ..., None] * wsum.astype(np.int32)
            return SparseQTensor(
                jax.numpy.asarray(new_v), leaf.indices, leaf.scale,
                leaf.m_group, leaf.k_dim, leaf.act_qparams,
                None if corr is None else jax.numpy.asarray(corr),
            )
        rows = np.swapaxes(v, -1, -2).reshape(-1, v.shape[-2])
        new_v = truncate_rows(rows, acc_bits, bits)
        new_v = np.swapaxes(
            new_v.reshape(v.shape[:-2] + (v.shape[-1], v.shape[-2])), -1, -2
        )
        corr = leaf.act_corr
        if corr is not None:
            wsum = new_v.astype(np.int64).sum(axis=-2)
            corr = np.asarray(jax.device_get(leaf.act_qparams.offset))[
                ..., None] * wsum.astype(np.int32)
        return QTensor(
            jax.numpy.asarray(new_v), leaf.scale, leaf.act_qparams,
            None if corr is None else jax.numpy.asarray(corr),
        )

    return jax.tree_util.tree_map(
        conv, params,
        is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor)),
    )
