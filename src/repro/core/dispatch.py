"""Unified accumulation-policy execution: one entry point for every
quantized dot product in the framework.

``pqs_dot(x, w, ...)`` runs any of the six accumulation policies

    wide | clip | wrap | sorted | sorted_tiled | sorted_tiled_seq

on either execution backend:

  - ``jnp``    — the pure-jnp reference semantics (core.overflow /
                 core.sorted_accum), exact on any platform;
  - ``pallas`` — the TPU kernels (kernels/ops.py), interpret-mode on CPU,
                 compiled on TPU.

The backend is selected automatically by platform (TPU -> pallas,
otherwise jnp) with an explicit override, and the two are bit-identical
for every policy (tests/test_dispatch.py sweeps the matrix). Arbitrary
shapes are handled here once — K is zero-padded to the policy's required
length (a whole number of k_tile tiles, or a power of two for the global
sort) for BOTH backends, so order-sensitive policies see the same
permutation; M is batch-chunked to bound the (chunk, N, K) partial
products tensor of the jnp backend.

The optional census output classifies natural-order overflow behavior
(persistent vs transient, paper Fig 2a) from the same partial products
the jnp backend accumulates — the analysis path no longer re-derives
them.

``qtensor_dot`` + ``integer_lin`` put the serving stack on this path:
inside the context, every ``models.layers.lin`` whose weight is a
QTensor executes as a true integer dot product under the configured
policy instead of dequantize-then-float-matmul.

Sparse storage: ``pqs_dot(..., storage="nm")`` accepts N:M-compressed
weights (``core.qtensor.SparseQTensor`` or a raw (values, indices)
pair) and runs every policy directly on the compressed form —
bit-identical, census included, to decompressing first (see
``kernels.ops.nm_policy_matmul``). This is the P of PQS composed with
the Q+S: pruning shortens the effective dot-product length the narrow
accumulator sees, and the compressed slabs cut weight HBM traffic by
~n_keep/m on the serving path.

Distributed execution: ``pqs_dot(..., mesh=...)`` runs the same dot
under ``shard_map`` on a named mesh — output channels (N) sharded on
the tensor-parallel axis, rows (M) on the data axes, and the full K
accumulation performed *inside* each shard under the configured policy,
so every output element is produced by exactly the single-device
routine and results stay bit-identical at any mesh shape. Specs are
``sanitize``-degraded (non-dividing axes dropped), so ragged shapes
lower everywhere.

K-sharded accumulation: ``pqs_dot(..., k_shards=S)`` (and its mesh form
``mesh= + k_axis=``) partitions the REDUCTION axis instead of keeping
it whole: each shard accumulates its contiguous, policy-padded K/S
slice under the configured policy with the unchanged kernel bodies, and
the per-shard partials merge up the shared static combine tree
(``core.sorted_accum.combine_schedule`` / ``tree_combine``) with
stepwise saturation. On a mesh the tree runs as log2(S) pairwise
``ppermute`` exchanges along ``k_axis`` — one (M, N) int32 register per
step instead of all-gathering all S partials — and
``defer_combine=True`` exposes the exchange as an async-dispatchable
tail (``PendingCombine``) so independent compute overlaps it. The
census counts every shard's local dot and reports combine-step
overflows separately (``Census.n_combine``). This is what carries a
single dot past the compiled sort kernels' per-device
``ops.MAX_STREAM_K`` bound: per-device K footprint is K/S.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.overflow import (
    Census,
    accumulate,
    census,
    kshard_partials,
    nm_partial_products,
    partial_products,
)
from repro.core.pruning import nm_decompress_jax
from repro.core.quant import qrange
from repro.core.sorted_accum import (
    combine_schedule,
    combine_step,
    tree_combine,
)
from repro.kernels import ops

POLICIES = ops.POLICIES  # derived from the kernel modules — one list
BACKENDS = ("jnp", "pallas")
STORAGES = ("dense", "nm")

# Cap on the HBM tile-sum + permutation statistic of the two-pass
# sorted_tiled kernel (per M-chunk: 2 * 4 * N * K/k_tile bytes/row);
# pqs_dot defaults batch_chunk to stay under it.
_SORT_STATS_BUDGET = 256 * 1024 * 1024


def default_backend() -> str:
    """pallas on real TPUs (compiled kernels); jnp reference elsewhere.

    Interpret-mode pallas is semantically identical but far slower than
    jnp on CPU, so it is opt-in via backend="pallas"."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _validate(policy: str, backend: Optional[str], acc_bits: int,
              k_tile: int, storage: str = "dense") -> None:
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected {POLICIES}")
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    if storage not in STORAGES:
        raise ValueError(f"unknown storage {storage!r}; expected {STORAGES}")
    if not 2 <= acc_bits <= 30:
        raise ValueError(f"acc_bits={acc_bits} outside the int32-carrier "
                         "range [2, 30]")
    if policy in ("sorted_tiled", "sorted_tiled_seq") and (
        k_tile <= 0 or k_tile & (k_tile - 1)
    ):
        raise ValueError(f"k_tile must be a power of 2, got {k_tile}")


def _unpack_nm(w: Any, m_group: Optional[int]):
    """(values, indices, m_group, logical K) from a storage="nm" weight.

    Accepts a ``core.qtensor.SparseQTensor`` (m_group/k_dim ride along)
    or a bare ``(values, indices)`` pair plus an explicit ``m_group``.
    """
    from repro.core.qtensor import SparseQTensor

    if isinstance(w, SparseQTensor):
        if w.values.ndim != 3:
            raise ValueError(
                "pqs_dot needs an unstacked (out, G, n_keep) SparseQTensor; "
                f"got values {w.values.shape} (slice the layer axis first)"
            )
        return w.values, w.indices, w.m_group, w.k_dim
    if isinstance(w, (tuple, list)) and len(w) == 2:
        values, indices = w
        if m_group is None:
            raise ValueError(
                "storage='nm' with a bare (values, indices) pair needs an "
                "explicit m_group="
            )
        if values.ndim != 3 or values.shape != indices.shape:
            raise ValueError(
                f"expected matching (N, G, n_keep) slabs, got "
                f"{values.shape} / {indices.shape}"
            )
        return values, indices, m_group, values.shape[1] * m_group
    raise ValueError(
        "storage='nm' expects w to be a SparseQTensor or a "
        f"(values, indices) pair, got {type(w).__name__}"
    )


def _local_dot(
    x2: jax.Array,  # (M, Kp) — K already padded by the shared rule
    w: Any,  # (N, Kp) dense, or (values, indices) compressed slabs
    *,
    acc_bits: int,
    policy: str,
    k_tile: int,
    rounds: int,
    backend: str,
    interpret: Optional[bool],
    block_m: Optional[int],
    block_n: Optional[int],
    sort_impl: str,
    batch_chunk: Optional[int],
    with_census: bool,
    storage: str = "dense",
    m_group: Optional[int] = None,
    nm_impl: Optional[str] = None,
    certified: bool = False,
) -> tuple[jax.Array, Optional[Census]]:
    """Single-device policy matmul on pre-padded operands (+census).

    storage="nm": ``w`` is the compressed (values, indices) pair. The
    jnp backend decompresses to the dense reference semantics (padded
    to the same Kp the dense path would use — zero columns are inert);
    the pallas backend runs ``ops.nm_policy_matmul`` directly on the
    compressed slabs (``nm_impl`` selecting expand vs fused gather —
    bit-identical either way). The census is computed from the
    KEPT-ONLY partial products (``overflow.nm_partial_products``) for
    both backends and both impls — bit-identical counts at n_keep/m of
    the unrolled memory.

    certified=True: a `core.certify` proof says no partial sum can reach
    the acc_bits caps, so the stepwise saturate bookkeeping is dead code
    — the jnp backend accumulates wide (bit-identical to the narrow
    result by the proof), the pallas backend takes the kernels'
    census-free route (``ops.policy_matmul(census=False)``).
    """
    if certified:
        with_census = False
    jnp_policy = "wide" if certified else policy
    m = x2.shape[0]
    chunk = m if (batch_chunk is None or batch_chunk >= m) else batch_chunk
    outs = []
    tot: Optional[Census] = None
    wd = None
    if storage == "nm" and backend == "jnp":
        values, indices = w
        wd = nm_decompress_jax(values, indices, m_group)  # (N, G*m)
        kp = ops.padded_k(wd.shape[-1], policy, k_tile)
        if kp != wd.shape[-1]:
            wd = jnp.pad(wd, ((0, 0), (0, kp - wd.shape[-1])))
    for i in range(0, m, max(chunk, 1)):
        xc = x2[i : i + chunk]
        prods = None
        if storage == "nm" and backend == "jnp":
            xcp = jnp.pad(
                xc, ((0, 0), (0, wd.shape[-1] - xc.shape[-1]))
            ) if wd.shape[-1] != xc.shape[-1] else xc
            prods = partial_products(wd, xcp)  # (c, N, Kp)
            outs.append(
                accumulate(prods, acc_bits, jnp_policy, k_tile, rounds))
        elif storage == "nm":
            outs.append(
                ops.nm_policy_matmul(
                    xc, w[0], w[1], m_group=m_group, policy=policy,
                    acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                    bm=block_m, bn=block_n, sort_impl=sort_impl,
                    nm_impl=nm_impl, interpret=interpret,
                    census=not certified,
                )
            )
        elif backend == "jnp":
            prods = partial_products(w, xc)  # (c, N, Kp)
            outs.append(
                accumulate(prods, acc_bits, jnp_policy, k_tile, rounds))
        else:
            outs.append(
                ops.policy_matmul(
                    xc, w, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
                    rounds=rounds, bm=block_m, bn=block_n,
                    sort_impl=sort_impl, interpret=interpret,
                    census=not certified,
                )
            )
        if with_census:
            if prods is None:
                # backends that already materialized a cube reuse it
                # (zero products are census-inert); the nm pallas path,
                # which never builds one, pays only the kept-only gather
                prods = (
                    nm_partial_products(w[0], w[1], xc, m_group)
                    if storage == "nm"
                    else partial_products(w, xc)
                )
            tot = _merge_census(tot, census(prods, acc_bits))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return out, tot


def _merge_census(tot: Optional[Census], c: Census) -> Census:
    return c if tot is None else Census(*(a + b for a, b in zip(tot, c)))


@dataclasses.dataclass
class PendingCombine:
    """A K-sharded dot whose cross-shard combine is still pending.

    The async-dispatchable tail of ``pqs_dot(..., defer_combine=True)``:
    ``partials`` holds every shard's policy-accumulated int32 register —
    (M, N, S) on a single device, or a global (S, M, N) array laid out
    along ``k_axis`` on a mesh, where each member owns exactly its own
    register (O(1) per-member footprint, never the gathered S). Nothing
    has crossed the interconnect yet.

    ``combine()`` merges the registers up the shared static combine tree
    (``core.sorted_accum.combine_schedule``) and returns what the
    non-deferred call would have — ``out`` or ``(out, Census)`` — bit
    for bit. Because dispatching the exchange is separated from
    consuming its result, a caller tracing both phases into one jitted
    step lets XLA's latency-hiding scheduler run the log2(S) ppermute
    steps concurrently with any compute that does not depend on the
    combined value: issue pass 1 of the next dot, then combine the
    previous one (double-buffered partials in the serving step).
    """

    partials: Any
    _finish: Any  # partials -> out | (out, Census)

    def combine(self):
        """Run the combine tail; returns ``out`` or ``(out, Census)``."""
        return self._finish(self.partials)


def _kshard_dot(
    x2: jax.Array,  # (M, k_shards * k_local) — pre-padded by pqs_dot
    w: Any,  # (N, k_shards * k_local) dense, or pre-padded nm slabs
    *,
    k_shards: int,
    with_census: bool,
    acc_bits: int,
    policy: str,
    k_tile: int,
    rounds: int,
    backend: str,
    interpret: Optional[bool],
    block_m: Optional[int],
    block_n: Optional[int],
    sort_impl: str,
    batch_chunk: Optional[int],
    storage: str = "dense",
    m_group: Optional[int] = None,
    nm_impl: Optional[str] = None,
    certified: bool = False,
    defer: bool = False,
):
    """Single-device hierarchical K-sharded dot (and the mesh oracle).

    K (pre-padded into ``k_shards`` equal, policy-padded contiguous
    slices) is partitioned; every shard accumulates its local slice
    under the unmodified policy — the jnp backend through
    ``overflow.kshard_partials``, the pallas backend through the
    per-shard kernel entry points (``ops.partial_policy_matmul`` /
    ``ops.nm_partial_policy_matmul``) — and the per-shard partials merge
    up the shared static combine tree
    (``core.sorted_accum.tree_combine``).

    Census: every shard's local dot is an examined dot (n_dots =
    k_shards * M * N; per-shard natural-order classification), and
    combine-step overflows are reported separately in ``n_combine`` —
    the total census is exactly sum(per-shard) + combine steps.

    certified=True: per-shard partials AND every combine step are subset
    sums of the row's products, so the certificate covers the whole
    hierarchy — shards and the combine run census-free/saturation-free.

    defer=True returns a ``PendingCombine`` over the stacked (M, N, S)
    registers instead; its finish runs ``tree_combine`` and yields
    ``(out, census)`` exactly as the eager path would.
    """
    if certified:
        with_census = False
    jnp_policy = "wide" if certified else policy
    m = x2.shape[0]
    n = (w[0] if storage == "nm" else w).shape[0]
    chunk = m if (batch_chunk is None or batch_chunk >= m) else batch_chunk
    wd = None
    if storage == "nm" and backend == "jnp":
        # G is pre-padded to a k_shards multiple, so the decompressed
        # matrix is (N, kp) and shard slices fall on group boundaries
        wd = nm_decompress_jax(w[0], w[1], m_group)
    parts_all = []
    tot: Optional[Census] = None
    for i in range(0, m, max(chunk, 1)):
        xc = x2[i : i + chunk]
        prods = None
        if backend == "jnp":
            prods = partial_products(wd if storage == "nm" else w, xc)
            parts = kshard_partials(
                prods, acc_bits, jnp_policy, k_shards, k_tile, rounds
            )
        elif storage == "nm":
            parts = ops.nm_partial_policy_matmul(
                xc, w[0], w[1], m_group=m_group, k_shards=k_shards,
                policy=policy, acc_bits=acc_bits, k_tile=k_tile,
                rounds=rounds, bm=block_m, bn=block_n,
                sort_impl=sort_impl, nm_impl=nm_impl,
                interpret=interpret, census=not certified,
            )
        else:
            parts = ops.partial_policy_matmul(
                xc, w, k_shards=k_shards, policy=policy,
                acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                bm=block_m, bn=block_n, sort_impl=sort_impl,
                interpret=interpret, census=not certified,
            )
        parts_all.append(parts)
        if with_census:
            if prods is None:
                prods = (
                    nm_partial_products(w[0], w[1], xc, m_group)
                    if storage == "nm"
                    else partial_products(w, xc)
                )
            sh = prods.reshape(
                xc.shape[0], n, k_shards, prods.shape[-1] // k_shards
            )
            tot = _merge_census(tot, census(sh, acc_bits))
    parts = (
        parts_all[0] if len(parts_all) == 1
        else jnp.concatenate(parts_all, axis=0)
    )

    def finish(p):
        out, novf = tree_combine(p, acc_bits, jnp_policy)
        t = tot
        if with_census:
            t = t._replace(
                n_combine=t.n_combine + jnp.sum(novf).astype(jnp.int32)
            )
        return out, t

    if defer:
        return PendingCombine(parts, finish)
    return finish(parts)


def _exchange_combine(
    val: jax.Array, k_axis: str, k_size: int, acc_bits: int, policy: str
) -> tuple[jax.Array, jax.Array]:
    """Pairwise-exchange combine along ``k_axis`` (inside shard_map).

    Walks ``core.sorted_accum.combine_schedule(k_size)``: log2(S)
    ``ppermute`` steps, each exchanging this member's (M, N) int32
    register with the level's partner and merging through
    ``combine_step``. Every member ends holding the root of the same
    balanced tree ``tree_combine`` computes locally (the two realize one
    schedule — that is the bit-identity argument), with per-member
    interconnect volume of log2(S) registers instead of the S an
    all-gather moves. Non-power-of-two axis sizes fall back to
    all-gather + ``tree_combine`` — still bit-identical, the gathered
    vector just walks the identical tree on every member.

    Returns ``(combined, novf_local)``: the combined registers
    (replicated along ``k_axis``) and this member's share of the
    combine-overflow count. Every tree merge is computed redundantly by
    all members of its block, so it is counted only on the block's
    lowest-index member — ``psum`` over ``k_axis`` then reconstructs
    exactly ``tree_combine``'s per-tree count.
    """
    if k_size & (k_size - 1):
        parts = jnp.moveaxis(jax.lax.all_gather(val, k_axis), 0, -1)
        out, novf = tree_combine(parts, acc_bits, policy)
        keep = jax.lax.axis_index(k_axis) == 0
        return out, jnp.where(keep, novf, 0)
    idx = jax.lax.axis_index(k_axis)
    novf = jnp.zeros(val.shape, jnp.int32)
    for level, perm in enumerate(combine_schedule(k_size)):
        other = jax.lax.ppermute(val, k_axis, perm)
        val, hit = combine_step(val, other, acc_bits, policy)
        own = idx % (1 << (level + 1)) == 0
        novf = novf + jnp.where(own, hit.astype(jnp.int32), 0)
    return val, novf


def _sharded_dot(
    x2: jax.Array,  # (M, Kp)
    w: jax.Array,  # (N, Kp)
    mesh,
    m_axes: Optional[tuple[str, ...]],
    n_axis: str,
    with_census: bool,
    k_axis: Optional[str] = None,
    defer: bool = False,
    **kw,
):
    """shard_map wrapper: M on the data axes, N on the TP axis, K whole
    per shard — or, with ``k_axis``, K partitioned across that mesh axis.

    Without ``k_axis`` every shard runs the unmodified single-device
    routine over its (M_shard, N_shard) block with the FULL (padded) K
    axis resident, so the narrow-accumulation order — and therefore the
    result — is bit-identical to the single-device reference. Specs
    degrade through ``sanitize`` when a dimension does not divide its
    axes, so any shape lowers (at worst fully replicated).

    With ``k_axis`` each device accumulates its contiguous K/S slice
    under the policy (still the unmodified local routine) and the
    per-shard registers merge through the pairwise exchange
    (``_exchange_combine``): log2(S) ``ppermute`` steps along the K
    axis, one (M, N) int32 register each, realizing the same static
    combine schedule as the single-device ``k_shards=S`` hierarchy —
    bit-identical to it, at O(1) resident partials per member. The
    census is psummed over the K axis too (every shard's dot is an
    examined dot), and the per-member combine-count shares are psummed
    over ``k_axis`` as well to reconstruct the exact per-tree total.

    ``defer=True`` splits the dot into two shard_maps: phase 1 returns
    the global (S, M, N) register array laid out on ``k_axis`` wrapped
    in a ``PendingCombine``; its finish runs the exchange. Tracing both
    phases into one jitted step lets XLA overlap the exchange with any
    compute independent of the combined value.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import data_axes
    from repro.launch.sharding import sanitize

    if m_axes is None:
        m_axes = data_axes(mesh)
    m_axes = tuple(
        a for a in m_axes if a in mesh.axis_names and a != k_axis
    )
    x_spec = sanitize(mesh, P(m_axes if m_axes else None, k_axis), x2.shape)
    n_entry = n_axis if n_axis in mesh.axis_names else None
    if isinstance(w, tuple):  # compressed (values, indices): N rows shard
        vspec = sanitize(mesh, P(n_entry, k_axis, None), w[0].shape)
        w_spec = (vspec, vspec)
        w_row = vspec[0]
        w_k = vspec[1]
    else:
        w_spec = sanitize(mesh, P(n_entry, k_axis), w.shape)
        w_row = w_spec[0]
        w_k = w_spec[1]
    if k_axis is not None and (x_spec[1] != k_axis or w_k != k_axis):
        # cannot happen: pqs_dot pads K (and G) to k_shards multiples,
        # so sanitize never drops the K entry — guard the invariant the
        # combine below depends on rather than silently mis-combining
        raise AssertionError(
            f"K axis {k_axis!r} was degraded from the operand specs "
            f"({x_spec}, {w_spec}) despite pre-padding"
        )
    out_spec = P(x_spec[0], w_row)
    # census counters must be summed only over axes that actually
    # partition the dots; replicated axes would multiply-count
    used: list[str] = []
    for entry in (x_spec[0], w_row):
        if entry is not None:
            used.extend(entry if isinstance(entry, tuple) else (entry,))
    k_size = int(mesh.shape[k_axis]) if k_axis is not None else 1
    acc_bits = kw["acc_bits"]
    combine_policy = "wide" if kw.get("certified") else kw["policy"]
    cns_specs = Census(P(), P(), P(), P(), P())

    if not defer:

        def body(xl, wl):
            out, cns = _local_dot(xl, wl, with_census=with_census, **kw)
            novf = None
            if k_axis is not None:
                out, novf = _exchange_combine(
                    out, k_axis, k_size, acc_bits, combine_policy
                )
            if with_census:
                axes = tuple(used) + (
                    (k_axis,) if k_axis is not None else ()
                )
                if axes:
                    cns = jax.tree_util.tree_map(
                        lambda a: jax.lax.psum(a, axes), cns
                    )
                if novf is not None:
                    nc = jnp.sum(novf).astype(jnp.int32)
                    nc = jax.lax.psum(nc, tuple(used) + (k_axis,))
                    cns = cns._replace(n_combine=cns.n_combine + nc)
            return (out, cns) if with_census else out

        out_specs = (out_spec, cns_specs) if with_census else out_spec
        return shard_map(
            body, mesh, in_specs=(x_spec, w_spec), out_specs=out_specs,
            check_rep=False,
        )(x2, w)

    # deferred: phase 1 materializes each member's register as its slot
    # of a global (S, M, N) array laid out along k_axis; phase 2 — the
    # exchange — dispatches when the caller consumes the PendingCombine
    part_spec = P(k_axis, *out_spec)

    def body1(xl, wl):
        out, cns = _local_dot(xl, wl, with_census=with_census, **kw)
        if with_census:
            axes = tuple(used) + (k_axis,)
            cns = jax.tree_util.tree_map(
                lambda a: jax.lax.psum(a, axes), cns
            )
            return out[None], cns
        return out[None]

    out_specs1 = (part_spec, cns_specs) if with_census else part_spec
    res1 = shard_map(
        body1, mesh, in_specs=(x_spec, w_spec), out_specs=out_specs1,
        check_rep=False,
    )(x2, w)
    parts, cns1 = res1 if with_census else (res1, None)

    def body2(pl):
        out, novf = _exchange_combine(
            pl[0], k_axis, k_size, acc_bits, combine_policy
        )
        nc = jnp.sum(novf).astype(jnp.int32)
        nc = jax.lax.psum(nc, tuple(used) + (k_axis,))
        return out, nc

    combine_fn = shard_map(
        body2, mesh, in_specs=(part_spec,), out_specs=(out_spec, P()),
        check_rep=False,
    )

    def finish(p):
        out, nc = combine_fn(p)
        t = cns1
        if with_census:
            t = t._replace(n_combine=t.n_combine + nc)
        return out, t

    return PendingCombine(parts, finish)


def pqs_dot(
    x: jax.Array,  # (..., K) integer carrier (int8 or int32 holding int8)
    w: Any,  # (N, K) integer carrier; rows = output channels — or, with
    # storage="nm", a SparseQTensor / (values, indices) compressed pair
    *,
    acc_bits: int = 16,
    policy: str = "wide",
    k_tile: int = 256,
    rounds: int = 1,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    sort_impl: str = "auto",
    batch_chunk: Optional[int] = None,
    with_census: bool = False,
    mesh=None,
    m_axes: Optional[tuple[str, ...]] = None,
    n_axis: str = "model",
    k_shards: Optional[int] = None,
    k_axis: Optional[str] = None,
    storage: str = "dense",
    m_group: Optional[int] = None,
    nm_impl: Optional[str] = None,
    certified: bool = False,
    defer_combine: bool = False,
):
    """Quantized dot products with simulated narrow accumulation.

    Returns (..., N) int32 — each element a dot product accumulated into
    an acc_bits register under ``policy``. With ``with_census=True``
    returns ``(out, Census)`` where the census classifies natural-order
    overflows of the same dot products (persistent / transient, Fig 2a).

    Any M/N/K works: padding and batch chunking happen here, not at call
    sites. ``backend`` overrides the platform default; both backends are
    bit-identical per policy. ``block_m``/``block_n`` default to the
    measured-autotune winner when REPRO_PQS_AUTOTUNE is enabled, else
    the per-platform table in ``kernels.ops`` (env-overridable).
    ``sort_impl`` picks the Pallas kernel for the global-sort policies:
    ``auto`` (one-pass K-resident up to ``ops.MAX_RESIDENT_K``, two-pass
    streaming above), ``onepass``, or ``twopass``.

    ``storage="nm"`` composes every policy with N:M compressed weight
    storage: ``w`` is a ``core.qtensor.SparseQTensor`` (or a bare
    ``(values, indices)`` pair plus ``m_group=``) and the pallas backend
    runs the policy directly on the compressed slabs
    (``kernels.ops.nm_policy_matmul`` — G is padded instead of K); the
    jnp backend decompresses to the dense reference. ``nm_impl``
    (default ``REPRO_PQS_NM_IMPL``, then ``auto``) selects the Pallas
    implementation: ``expand`` (one-hot expand to dense in VMEM, the
    oracle) or ``gather`` (contract only the kept products — n_keep/m
    of the FLOPs); ``auto`` picks gather wherever it saves work.
    Results — census included (counted over the KEPT partial products
    only) — are bit-identical to ``nm_decompress`` followed by this
    function on the dense matrix, for either implementation.

    With ``mesh`` (a ``jax.sharding.Mesh``), the dot executes under
    ``shard_map``: M sharded over ``m_axes`` (default: the mesh's data
    axes), N over ``n_axis`` ("model"), K accumulated whole inside each
    shard — bit-identical to the single-device result (compressed
    weights shard their N rows the same way).

    ``k_shards=S`` (without a mesh) partitions K into S contiguous,
    equal, policy-padded slices accumulated independently under the
    policy, then merged up the shared static combine tree
    (``core.sorted_accum.combine_schedule`` / ``tree_combine`` —
    stepwise saturation; the census reports combine-step overflows
    separately in ``Census.n_combine``, and every shard's local dot
    counts as an examined dot). With ``mesh`` + ``k_axis`` the same
    hierarchy runs distributed: K is partitioned across that mesh axis,
    each device accumulates only its K/S slice (per-device K footprint
    drops by S — past ``ops.MAX_STREAM_K`` total K for the compiled
    sort kernels), and the per-shard registers merge through log2(S)
    pairwise ``ppermute`` exchanges realizing the identical schedule —
    bit-identical to ``k_shards=S`` on one device, at one (M, N)
    register per exchange instead of an S-partial all-gather. Note the
    hierarchy intentionally changes the accumulation ORDER vs the
    full-K dot for the saturating policies (docs/accumulation.md,
    "K-sharded accumulation"); ``wide``/``wrap`` are exactly
    order-invariant.

    ``defer_combine=True`` (K-sharded paths only) returns a
    ``PendingCombine`` instead of the result: the per-shard registers
    with the cross-shard exchange still pending. ``.combine()`` yields
    exactly what the eager call would have returned; dispatching both
    phases inside one jitted step lets XLA overlap the exchange with
    independent compute (see ``PendingCombine``).

    ``certified=True`` declares that a `core.certify.Certificate` proves
    no partial sum of these operands can reach the acc_bits caps — the
    stepwise saturate/census bookkeeping is then provably dead code and
    is skipped (kernels take the census-free wide-safe route; the jnp
    backend accumulates wide). By the subset-sum bound the result is
    bit-identical to the censused narrow path under every policy,
    k-sharding and storage included. The caller is responsible for the
    proof actually covering (weights, act range, acc_bits); serving
    checks it per site via ``IntegerLinConfig.certificate``. Mutually
    exclusive with ``with_census`` — a certified dot has no census.
    """
    _validate(policy, backend, acc_bits, k_tile, storage)
    if certified and with_census:
        raise ValueError(
            "certified=True removes the census from the path entirely; "
            "with_census=True contradicts it"
        )
    if nm_impl is not None:
        if storage != "nm":
            raise ValueError("nm_impl= is only meaningful with storage='nm'")
        if nm_impl not in ops.NM_IMPLS:
            raise ValueError(
                f"nm_impl must be one of {ops.NM_IMPLS}, got {nm_impl!r}")
    if k_axis is not None:
        if mesh is None:
            raise ValueError("k_axis= needs mesh= (the axis lives on it)")
        if k_axis not in mesh.axis_names:
            raise ValueError(
                f"k_axis={k_axis!r} not on the mesh {mesh.axis_names}")
        if k_axis == n_axis:
            raise ValueError(
                f"k_axis and n_axis must differ, both are {k_axis!r}")
        if k_shards is None:
            k_shards = mesh.shape[k_axis]
        elif int(k_shards) != mesh.shape[k_axis]:
            raise ValueError(
                f"k_shards={k_shards} != mesh.shape[{k_axis!r}]="
                f"{mesh.shape[k_axis]}")
    elif k_shards is not None and mesh is not None:
        raise ValueError(
            "k_shards on a mesh needs k_axis= naming the mesh axis the "
            "K shards live on")
    k_shards = 1 if k_shards is None else int(k_shards)
    if k_shards < 1:
        raise ValueError(f"k_shards must be >= 1, got {k_shards}")
    backend = backend or default_backend()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)

    if storage == "nm":
        values, indices, m_group, k_logical = _unpack_nm(w, m_group)
        n = values.shape[0]
        k_dense = values.shape[1] * m_group
        if k not in (k_logical, k_dense):
            raise ValueError(
                f"contraction mismatch: x has K={k} but the compressed "
                f"weights cover {k_logical} (logical) / {k_dense} (padded)"
            )
        if policy in ("sorted_tiled", "sorted_tiled_seq") and (
            k_tile % m_group != 0
        ):
            raise ValueError(
                f"tiled policies on storage='nm' need k_tile % m_group == "
                f"0 (tile boundaries must align with the compressed "
                f"groups); got k_tile={k_tile}, m_group={m_group}"
            )
        if k_shards > 1:
            # shard K in units of whole groups: pad G so every shard
            # holds g_local groups whose span is a policy-padded length
            # (padded groups expand to zero columns — inert everywhere)
            g = values.shape[1]
            k_local = ops.padded_k(
                -(-g // k_shards) * m_group, policy, k_tile)
            if k_local % m_group:
                raise ValueError(
                    f"k_shards={k_shards} with storage='nm' and policy="
                    f"{policy!r} needs the per-shard padded K ({k_local}) "
                    f"divisible by m_group={m_group}"
                )
            gp = k_shards * (k_local // m_group)
            if gp != g:
                pad3 = ((0, 0), (0, gp - g), (0, 0))
                values = jnp.pad(values, pad3)
                indices = jnp.pad(indices, pad3)
            kp = gp * m_group
            if x2.shape[-1] != kp:
                x2 = jnp.pad(x2, ((0, 0), (0, kp - x2.shape[-1])))
        else:
            if k_dense != k:
                x2 = jnp.pad(x2, ((0, 0), (0, k_dense - k)))
            kp = ops.padded_k(k_dense, policy, k_tile)
        w = (values, indices)
    else:
        if x.shape[-1] != w.shape[-1]:
            raise ValueError(f"contraction mismatch: {x.shape} vs {w.shape}")
        n = w.shape[0]
        # one K-padding rule for both backends: order-sensitive policies
        # must see the same (padded) permutation domain to be bit-identical
        if k_shards > 1:
            # every shard sees the same policy-padded local length, so
            # per-shard kernels and the jnp oracle share one permutation
            # domain — and the mesh path's equal-block partitioning
            # slices at exactly these boundaries
            kp = k_shards * ops.padded_k(-(-k // k_shards), policy, k_tile)
        else:
            kp = ops.padded_k(k, policy, k_tile)
        if kp != k:
            x2 = jnp.pad(x2, ((0, 0), (0, kp - k)))
            w = jnp.pad(w, ((0, 0), (0, kp - k)))

    if (batch_chunk is None and backend == "pallas"
            and policy == "sorted_tiled" and sort_impl != "onepass"):
        # the two-pass kernel's pass 1 materializes (chunk, N, K/k_tile)
        # int32 tile sums (+ a same-shape permutation) in HBM; chunk M so
        # that statistic stays bounded instead of scaling with the full
        # batch. Chunking M is exact — every dot is element-independent.
        # (K-sharded: the statistic exists per shard at K_local/k_tile.)
        per_row = 2 * 4 * n * max(kp // k_shards // k_tile, 1)
        batch_chunk = max(_SORT_STATS_BUDGET // per_row, 1)

    kw = dict(
        acc_bits=acc_bits, policy=policy, k_tile=k_tile, rounds=rounds,
        backend=backend, interpret=interpret, block_m=block_m,
        block_n=block_n, sort_impl=sort_impl, batch_chunk=batch_chunk,
        storage=storage, m_group=m_group if storage == "nm" else None,
        nm_impl=nm_impl if storage == "nm" else None, certified=certified,
    )
    if defer_combine:
        if mesh is not None and k_axis is not None:
            pending = _sharded_dot(
                x2, w, mesh, m_axes, n_axis, with_census, k_axis=k_axis,
                defer=True, **kw
            )
        elif mesh is None and k_shards > 1:
            pending = _kshard_dot(
                x2, w, k_shards=k_shards, with_census=with_census,
                defer=True, **kw
            )
        else:
            raise ValueError(
                "defer_combine=True needs a K-sharded dot "
                "(k_shards > 1, or mesh= with k_axis=)"
            )

        def finish_full(p):
            o, tot = pending._finish(p)
            o = o.reshape(*lead, n)
            return (o, tot) if with_census else o

        return PendingCombine(pending.partials, finish_full)

    if mesh is not None:
        res = _sharded_dot(
            x2, w, mesh, m_axes, n_axis, with_census, k_axis=k_axis, **kw
        )
        out, tot = res if with_census else (res, None)
    elif k_shards > 1:
        out, tot = _kshard_dot(
            x2, w, k_shards=k_shards, with_census=with_census, **kw
        )
    else:
        out, tot = _local_dot(x2, w, with_census=with_census, **kw)
    out = out.reshape(*lead, n)
    if with_census:
        return out, tot
    return out


# ---------------------------------------------------------------------------
# integer execution of QTensor projections (serving path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IntegerLinConfig:
    """How ``models.layers.lin`` should execute QTensor weights.

    ``mesh`` (+ ``m_axes``/``n_axis``) distributes every integer
    projection via the sharded ``pqs_dot`` path. ``use_static_acts``
    selects the calibrated static activation QParams a QTensor carries
    (``QTensor.act_qparams``, see ``core.qtensor.attach_act_qparams``)
    over the dynamic per-call absmax reduction whenever present.

    ``k_shards`` opts long-K projections into hierarchical K-sharded
    accumulation (per-shard policy partials + the shared static combine
    tree): only layers whose contraction dim is >= ``k_shard_min_k``
    take the hierarchy — shorter projections keep the bit-identical
    full-K path. With a mesh, ``k_axis`` names the mesh axis the K
    shards live on (K-sharded weight placement:
    ``launch.sharding.params_shardings`` with the same
    ``k_axis``/``k_shard_min_k``). ``overlap_combine`` dispatches each
    K-sharded projection through the deferred two-phase path
    (``pqs_dot(defer_combine=True)`` + immediate ``combine()``): bit
    for bit the same result, but the pass-1 registers and the exchange
    tail lower as separate collectives, so XLA's latency-hiding
    scheduler can overlap one site's log2(S) exchange with another
    site's pass-1 compute inside the same jitted serving step
    (double-buffered partials; see docs/accumulation.md).

    ``certificate`` (a ``core.certify.Certificate``) turns on the
    certified serving fast path: sites whose proof reaches this config's
    effective (acc_bits, act_bits) dispatch census-free and
    saturation-free (``pqs_dot(certified=True)``) and are invisible to
    any ``census_monitor`` — bit-identical to the censused path by the
    certificate's subset-sum bound. Sites without a covering proof keep
    the full census + degradation behavior. The engine verifies the
    certificate's weight hashes against the served params at
    construction (``ServingEngine``).
    """

    policy: str = "sorted_tiled_seq"
    acc_bits: int = 16
    k_tile: int = 256
    rounds: int = 1
    act_bits: int = 8
    backend: Optional[str] = None  # None = platform default
    mesh: Any = None  # jax.sharding.Mesh -> distributed pqs_dot
    m_axes: Optional[tuple[str, ...]] = None  # default: mesh data axes
    n_axis: str = "model"
    use_static_acts: bool = True
    k_shards: Optional[int] = None  # K-sharded accumulation (opt-in)
    k_axis: Optional[str] = None  # mesh axis carrying the K shards
    k_shard_min_k: int = 0  # only layers with K >= this take the hierarchy
    overlap_combine: bool = False  # deferred two-phase K-shard combine
    nm_impl: Optional[str] = None  # sparse kernel impl: expand|gather|auto
    # per-site overrides, ((site, value), ...) — the census-degradation
    # hot-swap path: one saturating layer widens without touching the rest
    site_policies: tuple = ()
    site_acc_bits: tuple = ()
    certificate: Any = None  # core.certify.Certificate -> certified path

    def policy_for(self, site: Optional[str]) -> str:
        return dict(self.site_policies).get(site, self.policy)

    def acc_bits_for(self, site: Optional[str]) -> int:
        return dict(self.site_acc_bits).get(site, self.acc_bits)

    def certified_for(self, site: Optional[str], act_bits: int) -> bool:
        """Does the attached certificate prove this site safe as served?"""
        return (
            self.certificate is not None
            and site is not None
            and self.certificate.covers(
                site, self.acc_bits_for(site), act_bits
            )
        )

    def with_site_policy(self, site: str, policy: str) -> "IntegerLinConfig":
        over = dict(self.site_policies)
        over[site] = policy
        return dataclasses.replace(
            self, site_policies=tuple(sorted(over.items()))
        )

    def with_site_acc_bits(self, site: str, bits: int) -> "IntegerLinConfig":
        over = dict(self.site_acc_bits)
        over[site] = int(bits)
        return dataclasses.replace(
            self, site_acc_bits=tuple(sorted(over.items()))
        )

    def without_site(self, site: str) -> "IntegerLinConfig":
        """Drop every per-site override for ``site`` (un-degrade path)."""
        return dataclasses.replace(
            self,
            site_policies=tuple(
                (s, p) for s, p in self.site_policies if s != site
            ),
            site_acc_bits=tuple(
                (s, b) for s, b in self.site_acc_bits if s != site
            ),
        )


_INT_LIN: list[IntegerLinConfig] = []


def integer_lin_config() -> Optional[IntegerLinConfig]:
    return _INT_LIN[-1] if _INT_LIN else None


@contextlib.contextmanager
def integer_lin(cfg: Optional[IntegerLinConfig] = None, **kw):
    """Enable true integer dot products for QTensor projections.

    Inside the context (including jit *tracing* that happens inside it),
    ``lin(x, QTensor)`` quantizes activations dynamically and runs
    ``pqs_dot`` under the configured policy instead of dequantizing the
    weights to float.
    """
    _INT_LIN.append(cfg or IntegerLinConfig(**kw))
    try:
        yield _INT_LIN[-1]
    finally:
        _INT_LIN.pop()


_CALIBRATION: list = []


def calibration_store():
    """Active ``core.quant.ActCalibrator``, or None outside calibration."""
    return _CALIBRATION[-1] if _CALIBRATION else None


@contextlib.contextmanager
def calibration(store):
    """Collect activation ranges at QTensor projection sites.

    Inside the context, ``models.layers.lin`` reports each QTensor
    input's (min, max) to ``store`` (an ``ActCalibrator``) through
    ``jax.debug.callback`` — the execution stays the float dequant path,
    and the callback fires at runtime even from inside scanned layer
    loops. Freeze the result with ``store.freeze()`` +
    ``core.qtensor.attach_act_qparams``.
    """
    _CALIBRATION.append(store)
    try:
        yield store
    finally:
        _CALIBRATION.pop()


class CensusMonitor:
    """Per-site overflow-census accumulator (the runtime guardrail input).

    ``qtensor_dot`` reports, for every named projection site executed
    under a ``census_monitor`` context, the number of dot products and
    the number of overflow events (persistent-or-transient + combine)
    via ``jax.debug.callback`` — counts land here at runtime, including
    from inside jitted/scanned decode steps. ``wide``-policy sites
    report zero events by construction, so a degraded layer's rate
    measurably drops to 0.0. The serving engine drains this window by
    window (``ServingEngine._check_census``).
    """

    def __init__(self):
        self._dots: dict[str, int] = {}
        self._events: dict[str, int] = {}

    def observe(self, site, n_dots, n_events) -> None:
        site = str(site)
        self._dots[site] = self._dots.get(site, 0) + int(n_dots)
        self._events[site] = self._events.get(site, 0) + int(n_events)

    def totals(self) -> dict[str, tuple[int, int]]:
        return {s: (self._dots[s], self._events[s]) for s in self._dots}

    def rates(self) -> dict[str, float]:
        return {
            s: (self._events[s] / self._dots[s] if self._dots[s] else 0.0)
            for s in self._dots
        }

    def drain(self) -> dict[str, tuple[int, int]]:
        out = self.totals()
        self._dots.clear()
        self._events.clear()
        return out


_CENSUS_MON: list[CensusMonitor] = []


def census_monitor_store() -> Optional[CensusMonitor]:
    """Active ``CensusMonitor``, or None when monitoring is off."""
    return _CENSUS_MON[-1] if _CENSUS_MON else None


@contextlib.contextmanager
def census_monitor(mon: Optional[CensusMonitor] = None):
    """Count overflow events per projection site inside the context.

    Like ``calibration``, the context must wrap *tracing*: sites traced
    inside it carry the census callback permanently (for that jitted
    function), sites traced outside never report. Costs one extra
    census reduction per projection — serving enables it only when a
    ``CensusWatch`` is configured.
    """
    mon = mon or CensusMonitor()
    _CENSUS_MON.append(mon)
    try:
        yield mon
    finally:
        _CENSUS_MON.pop()


@dataclasses.dataclass(frozen=True)
class QATQuantConfig:
    """Accumulator-aware QAT at float linear sites (``a2q_qat`` context).

    Inside the context every named ``models.layers.lin`` whose weight is
    still a float 2-D matrix (with min(shape) >= ``min_dim``) runs
    `core.a2q.a2q_fake_quant`: per-channel quantize + accumulator
    projection + dequantize under a straight-through estimator, against
    the sign-split bound for (``acc_bits``, ``act_bits``). Gradients see
    the projected weights, so training co-adapts to the certifiable
    region — the "train" of train→certify→serve.

    ``census_rows`` > 0 adds the overflow census as a *training signal*:
    a stop-gradient sample of that many activation rows is quantized and
    pushed through `core.overflow.census` against the projected integer
    weights, reported per site to any active ``census_monitor`` — the
    same plumbing serving uses, so the QAT signal and the serving watch
    read identically.
    """

    weight_bits: int = 8
    acc_bits: int = 16
    act_bits: int = 8
    min_dim: int = 16
    census_rows: int = 4


_A2Q_QAT: list[QATQuantConfig] = []


def a2q_qat_config() -> Optional[QATQuantConfig]:
    """Active QAT config, or None outside ``a2q_qat``."""
    return _A2Q_QAT[-1] if _A2Q_QAT else None


@contextlib.contextmanager
def a2q_qat(cfg: Optional[QATQuantConfig] = None, **kw):
    """Enable accumulator-aware fake quantization for float lin weights.

    Like ``integer_lin``/``census_monitor``, the context must wrap
    *tracing*: jitted train steps traced inside it carry the STE
    projection (and census callbacks) permanently.
    """
    _A2Q_QAT.append(cfg or QATQuantConfig(**kw))
    try:
        yield _A2Q_QAT[-1]
    finally:
        _A2Q_QAT.pop()


def a2q_qat_lin(
    x: jax.Array, w: jax.Array, qcfg: QATQuantConfig,
    site: Optional[str] = None,
) -> jax.Array:
    """x (..., in) @ w (in, out) with A2Q-projected fake-quant weights."""
    from repro.core.a2q import a2q_fake_quant, a2q_quantize_project

    w_fq = a2q_fake_quant(
        w.T.astype(jnp.float32), qcfg.weight_bits, qcfg.acc_bits,
        act_bits=qcfg.act_bits,
    ).T
    mon = census_monitor_store()
    if mon is not None and site is not None and qcfg.census_rows > 0:
        wq, _ = a2q_quantize_project(
            w.T.astype(jnp.float32), qcfg.weight_bits, qcfg.acc_bits,
            act_bits=qcfg.act_bits,
        )
        xs = jax.lax.stop_gradient(
            x.reshape(-1, x.shape[-1])[: qcfg.census_rows]
        ).astype(jnp.float32)
        qmax = 2 ** (qcfg.act_bits - 1) - 1
        s_x = jnp.maximum(jnp.max(jnp.abs(xs)), 1e-8) / qmax
        xq = jnp.clip(
            jnp.round(xs / s_x), -qmax - 1, qmax
        ).astype(jnp.int32)
        cns = census(partial_products(wq, xq), qcfg.acc_bits)
        jax.debug.callback(
            functools.partial(mon.observe, site), cns.n_dots, cns.n_any
        )
    return (x.astype(jnp.float32) @ w_fq).astype(x.dtype)


def qtensor_dot(
    x: jax.Array, qt, cfg: IntegerLinConfig, site: Optional[str] = None
) -> jax.Array:
    """x (..., in) float @ QTensor (in, out) as an integer PQS dot.

    Activation quantization is dynamic symmetric per-tensor (absmax at
    act_bits) unless the QTensor carries calibrated static
    ``act_qparams`` and ``cfg.use_static_acts`` — then the frozen
    scale/offset is used and decode skips the data-dependent absmax
    reduction entirely (paper §2.1 setup). The integer matmul
    accumulates under cfg.policy at cfg.acc_bits (sharded over
    ``cfg.mesh`` when set); output is rescaled by the activation scale
    and the QTensor's per-channel weight scales.
    """
    from repro.core.qtensor import SparseQTensor

    sparse = isinstance(qt, SparseQTensor)
    if sparse:
        wq, storage = qt, "nm"  # compressed slabs flow straight through
    else:
        wq, storage = qt.values.T.astype(jnp.int32), "dense"  # (out, in)
    aq = getattr(qt, "act_qparams", None)
    if cfg.use_static_acts and aq is not None:
        qmin, qmax = qrange(aq.bits)
        s_x = aq.scale.astype(jnp.float32)
        xq = jnp.clip(
            jnp.round(x.astype(jnp.float32) / s_x) + aq.offset, qmin, qmax
        ).astype(jnp.int32)
    else:
        qmax = 2 ** (cfg.act_bits - 1) - 1
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
        s_x = (amax / qmax).astype(jnp.float32)
        xq = jnp.clip(
            jnp.round(x.astype(jnp.float32) / s_x), -qmax - 1, qmax
        ).astype(jnp.int32)
    ks, ka = cfg.k_shards, cfg.k_axis
    if (ks is not None or ka is not None) and (
        x.shape[-1] < cfg.k_shard_min_k
    ):
        # short-K layers keep the full-K path — also when the shard
        # count is implied by the mesh axis (k_axis= with k_shards=None)
        ks, ka = None, None
    policy = cfg.policy_for(site)
    acc_bits = cfg.acc_bits_for(site)
    # the activation code range actually admissible on this path — the
    # quantity the certificate's bound was taken over
    act_bits_used = int(aq.bits) if (cfg.use_static_acts and aq is not None) \
        else cfg.act_bits
    certified = cfg.certified_for(site, act_bits_used)
    mon = census_monitor_store()
    want_census = (
        mon is not None and site is not None and policy != "wide"
        and not certified
    )
    kshard_active = (
        (cfg.mesh is not None and ka is not None)
        or (cfg.mesh is None and ks is not None and int(ks) > 1)
    )
    defer = bool(cfg.overlap_combine) and kshard_active
    res = pqs_dot(
        xq, wq, acc_bits=acc_bits,
        policy=policy, k_tile=cfg.k_tile, rounds=cfg.rounds,
        backend=cfg.backend, mesh=cfg.mesh, m_axes=cfg.m_axes,
        n_axis=cfg.n_axis, k_shards=ks,
        k_axis=ka if cfg.mesh is not None else None, storage=storage,
        nm_impl=cfg.nm_impl if sparse else None,
        with_census=want_census, certified=certified,
        defer_combine=defer,
    )
    if defer:
        # two-phase dispatch: the exchange tail lowers as its own
        # collective, overlappable with independent compute traced into
        # the same step — the result is bit-identical either way
        res = res.combine()
    if want_census:
        z, cns = res
        jax.debug.callback(
            functools.partial(mon.observe, site),
            cns.n_dots, cns.n_any + cns.n_combine,
        )
    else:
        z = res
        if mon is not None and site is not None and not certified:
            # wide accumulates in int32 — overflow-free by construction;
            # report the dots so a degraded site's rate reads 0.0
            # (certified sites report nothing at all: CensusWatch must
            # never see them, they are provably overflow-free)
            jax.debug.callback(
                functools.partial(mon.observe, site), z.size, 0
            )
    if cfg.use_static_acts and aq is not None and not aq.symmetric:
        # Eq. (3) offset correction — precomputed at freeze time
        # (qtensor.attach_act_qparams), a per-weight constant
        z = z - qt.act_corr
    zf = z.astype(jnp.float32) * (s_x * qt.scale)
    return zf.astype(x.dtype)
