"""Unified accumulation-policy execution: one entry point for every
quantized dot product in the framework.

``pqs_dot(x, w, ...)`` runs any of the six accumulation policies

    wide | clip | wrap | sorted | sorted_tiled | sorted_tiled_seq

on either execution backend:

  - ``jnp``    — the pure-jnp reference semantics (core.overflow /
                 core.sorted_accum), exact on any platform;
  - ``pallas`` — the TPU kernels (kernels/ops.py), interpret-mode on CPU,
                 compiled on TPU.

The backend is selected automatically by platform (TPU -> pallas,
otherwise jnp) with an explicit override, and the two are bit-identical
for every policy (tests/test_dispatch.py sweeps the matrix). Arbitrary
shapes are handled here once — K is zero-padded to the policy's required
length (a whole number of k_tile tiles, or a power of two for the global
sort) for BOTH backends, so order-sensitive policies see the same
permutation; M is batch-chunked to bound the (chunk, N, K) partial
products tensor of the jnp backend.

The optional census output classifies natural-order overflow behavior
(persistent vs transient, paper Fig 2a) from the same partial products
the jnp backend accumulates — the analysis path no longer re-derives
them.

``qtensor_dot`` + ``integer_lin`` put the serving stack on this path:
inside the context, every ``models.layers.lin`` whose weight is a
QTensor executes as a true integer dot product under the configured
policy instead of dequantize-then-float-matmul.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.overflow import Census, accumulate, census, partial_products
from repro.kernels import ops

POLICIES = ops.POLICIES  # derived from the kernel modules — one list
BACKENDS = ("jnp", "pallas")


def default_backend() -> str:
    """pallas on real TPUs (compiled kernels); jnp reference elsewhere.

    Interpret-mode pallas is semantically identical but far slower than
    jnp on CPU, so it is opt-in via backend="pallas"."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _validate(policy: str, backend: Optional[str], acc_bits: int,
              k_tile: int) -> None:
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected {POLICIES}")
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    if not 2 <= acc_bits <= 30:
        raise ValueError(f"acc_bits={acc_bits} outside the int32-carrier "
                         "range [2, 30]")
    if policy in ("sorted_tiled", "sorted_tiled_seq") and (
        k_tile <= 0 or k_tile & (k_tile - 1)
    ):
        raise ValueError(f"k_tile must be a power of 2, got {k_tile}")


def pqs_dot(
    x: jax.Array,  # (..., K) integer carrier (int8 or int32 holding int8)
    w: jax.Array,  # (N, K) integer carrier; rows = output channels
    *,
    acc_bits: int = 16,
    policy: str = "wide",
    k_tile: int = 256,
    rounds: int = 1,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_m: int = 8,
    block_n: int = 128,
    batch_chunk: Optional[int] = None,
    with_census: bool = False,
):
    """Quantized dot products with simulated narrow accumulation.

    Returns (..., N) int32 — each element a dot product accumulated into
    an acc_bits register under ``policy``. With ``with_census=True``
    returns ``(out, Census)`` where the census classifies natural-order
    overflows of the same dot products (persistent / transient, Fig 2a).

    Any M/N/K works: padding and batch chunking happen here, not at call
    sites. ``backend`` overrides the platform default; both backends are
    bit-identical per policy.
    """
    _validate(policy, backend, acc_bits, k_tile)
    backend = backend or default_backend()
    if x.shape[-1] != w.shape[-1]:
        raise ValueError(f"contraction mismatch: {x.shape} vs {w.shape}")
    lead = x.shape[:-1]
    k, n = x.shape[-1], w.shape[0]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    # one K-padding rule for both backends: order-sensitive policies must
    # see the same (padded) permutation domain to be bit-identical
    kp = ops.padded_k(k, policy, k_tile)
    if kp != k:
        x2 = jnp.pad(x2, ((0, 0), (0, kp - k)))
        w = jnp.pad(w, ((0, 0), (0, kp - k)))

    chunk = m if (batch_chunk is None or batch_chunk >= m) else batch_chunk
    outs = []
    tot: Optional[Census] = None
    for i in range(0, m, max(chunk, 1)):
        xc = x2[i : i + chunk]
        prods = None
        if backend == "jnp":
            prods = partial_products(w, xc)  # (c, N, Kp)
            outs.append(accumulate(prods, acc_bits, policy, k_tile, rounds))
        else:
            outs.append(
                ops.policy_matmul(
                    xc, w, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
                    rounds=rounds, bm=block_m, bn=block_n,
                    interpret=interpret,
                )
            )
        if with_census:
            if prods is None:
                prods = partial_products(w, xc)
            c = census(prods, acc_bits)
            tot = c if tot is None else Census(
                *(a + b for a, b in zip(tot, c))
            )
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    out = out.reshape(*lead, n)
    if with_census:
        return out, tot
    return out


# ---------------------------------------------------------------------------
# integer execution of QTensor projections (serving path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IntegerLinConfig:
    """How ``models.layers.lin`` should execute QTensor weights."""

    policy: str = "sorted_tiled_seq"
    acc_bits: int = 16
    k_tile: int = 256
    rounds: int = 1
    act_bits: int = 8
    backend: Optional[str] = None  # None = platform default


_INT_LIN: list[IntegerLinConfig] = []


def integer_lin_config() -> Optional[IntegerLinConfig]:
    return _INT_LIN[-1] if _INT_LIN else None


@contextlib.contextmanager
def integer_lin(cfg: Optional[IntegerLinConfig] = None, **kw):
    """Enable true integer dot products for QTensor projections.

    Inside the context (including jit *tracing* that happens inside it),
    ``lin(x, QTensor)`` quantizes activations dynamically and runs
    ``pqs_dot`` under the configured policy instead of dequantizing the
    weights to float.
    """
    _INT_LIN.append(cfg or IntegerLinConfig(**kw))
    try:
        yield _INT_LIN[-1]
    finally:
        _INT_LIN.pop()


def qtensor_dot(x: jax.Array, qt, cfg: IntegerLinConfig) -> jax.Array:
    """x (..., in) float @ QTensor (in, out) as an integer PQS dot.

    Activations get dynamic symmetric per-tensor quantization (absmax at
    act_bits); the integer matmul accumulates under cfg.policy at
    cfg.acc_bits; output is rescaled by the activation scale and the
    QTensor's per-channel weight scales.
    """
    qmax = 2 ** (cfg.act_bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    s_x = (amax / qmax).astype(jnp.float32)
    xq = jnp.clip(
        jnp.round(x.astype(jnp.float32) / s_x), -qmax - 1, qmax
    ).astype(jnp.int32)
    z = pqs_dot(
        xq, qt.values.T.astype(jnp.int32), acc_bits=cfg.acc_bits,
        policy=cfg.policy, k_tile=cfg.k_tile, rounds=cfg.rounds,
        backend=cfg.backend,
    )
    zf = z.astype(jnp.float32) * (s_x * qt.scale)
    return zf.astype(x.dtype)
