"""Overflow-analysis library (paper §3.1, §5.0.1).

The paper extends PyTorch with custom layers that fully unroll quantized dot
products so persistent/transient overflows can be counted and different
accumulator policies compared. This module is the JAX equivalent: it exposes
every dot product in a quantized matmul as an explicit partial-products
tensor and provides

- a **census** of overflows: persistent (final result exceeds the p-bit
  range) vs transient (an intermediate partial sum exceeds it although the
  final result fits), under a given accumulation order;
- narrow-accumulator **simulation** under the policies
  ``wide | clip | wrap | sorted | sorted_tiled`` — the object the Fig-2/5
  benchmarks and kernels/ref.py share.

Everything is int32-carrier exact (see sorted_accum.monotone_accumulate).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quant import qrange
from repro.core.sorted_accum import (
    monotone_accumulate,
    sorted_order,
    tiled_seq_order,
    tiled_sorted_order,
    tree_combine,
)

Policy = str  # wide | clip | wrap | sorted | sorted_tiled | sorted_tiled_seq


class Census(NamedTuple):
    """Overflow counts over a batch of dot products.

    On the K-sharded path every shard's local dot is an examined dot
    (``n_dots = k_shards * M * N``) and the cross-shard merge reports
    its own events in ``n_combine`` — kept separate because a combine
    step saturates a *partial result*, not a raw partial product.
    """

    n_dots: jax.Array  # total dot products examined
    n_persistent: jax.Array  # final result out of range
    n_transient: jax.Array  # intermediate out of range, final in range
    n_any: jax.Array  # dots with any overflow event
    n_combine: jax.Array = 0  # K-sharded combine steps out of range


def partial_products(wq: jax.Array, xq: jax.Array) -> jax.Array:
    """Explicit partial products of a quantized matmul.

    wq: (out, K) int, xq: (batch, K) int -> (batch, out, K) int32. This is
    the fully-unrolled view the paper's library exposes; memory is
    batch*out*K*4 bytes, so callers chunk the batch for large layers.
    """
    return wq.astype(jnp.int32)[None, :, :] * xq.astype(jnp.int32)[:, None, :]


def nm_partial_products(
    values: jax.Array,  # (N, G, n_keep) int8 compressed weights
    indices: jax.Array,  # (N, G, n_keep) int32 in-group positions
    xq: jax.Array,  # (batch, K) int with K <= G * m_group (tail padded)
    m_group: int,
) -> jax.Array:
    """Kept-only partial products of an N:M-compressed matmul.

    Returns (batch, N, G*n_keep) int32 — the nonzero subsequence of the
    dense ``partial_products`` in ascending-K order (indices are stored
    ascending per group). Pruned positions contribute zero products,
    which are additively inert in every running sum, so a ``census``
    over the kept-only view is bit-identical to the dense census while
    the unrolled tensor shrinks by n_keep/m — the memory form of the
    paper's pruning payoff (§2.2): shorter effective dot products.
    """
    n, g, n_keep = values.shape
    k = g * m_group
    x = xq.astype(jnp.int32)
    if x.shape[-1] < k:
        x = jnp.pad(x, ((0, 0), (0, k - x.shape[-1])))
    xg = x.reshape(x.shape[0], 1, g, m_group)
    idx = jnp.broadcast_to(indices[None], (x.shape[0], n, g, n_keep))
    xk = jnp.take_along_axis(xg, idx, axis=-1)  # (batch, N, G, n_keep)
    prods = xk * values.astype(jnp.int32)[None]
    return prods.reshape(x.shape[0], n, g * n_keep)


@partial(jax.jit, static_argnames=("acc_bits",))
def census(prods: jax.Array, acc_bits: int) -> Census:
    """Classify overflows for natural-order accumulation (paper Fig 2a).

    prods: (..., K) int32 partial products. Natural order is index order —
    what a conventional inner-product loop would do.
    """
    qmin, qmax = qrange(acc_bits)
    run = jnp.cumsum(prods, axis=-1)
    out_of_range = jnp.logical_or(run > qmax, run < qmin)
    any_ovf = jnp.any(out_of_range, axis=-1)
    final = run[..., -1]
    persistent = jnp.logical_or(final > qmax, final < qmin)
    transient = jnp.logical_and(any_ovf, jnp.logical_not(persistent))
    n = jnp.prod(jnp.asarray(prods.shape[:-1]))
    return Census(
        n_dots=n,
        n_persistent=jnp.sum(persistent),
        n_transient=jnp.sum(transient),
        n_any=jnp.sum(any_ovf),
        n_combine=jnp.asarray(0),
    )


@partial(jax.jit, static_argnames=("acc_bits", "policy", "k_tile", "rounds"))
def accumulate(
    prods: jax.Array,
    acc_bits: int,
    policy: Policy = "clip",
    k_tile: int = 256,
    rounds: int = 2,
) -> jax.Array:
    """Accumulate partial products under a narrow-accumulator policy.

    Returns the accumulated value (int32), reproducing what the target
    hardware would compute:
      wide         — exact sum (reference; accumulator wide enough)
      clip         — saturation arithmetic at every add (natural order)
      wrap         — two's-complement wraparound at p bits (natural order)
      sorted       — single-round sorted order (PQS), then saturating adds
      sorted_tiled — per-k_tile single-round sort (paper §6 / TPU kernels)
    """
    if policy == "wide":
        return jnp.sum(prods, axis=-1)
    if policy == "clip":
        acc, _ = monotone_accumulate(prods, acc_bits, saturate=True)
        return acc
    if policy == "wrap":
        acc, _ = monotone_accumulate(prods, acc_bits, saturate=False)
        return acc
    if policy == "sorted":
        ordered = sorted_order(prods, rounds)
        acc, _ = monotone_accumulate(ordered, acc_bits, saturate=True)
        return acc
    if policy == "sorted_tiled":
        ordered = tiled_sorted_order(prods, k_tile, rounds)
        acc, _ = monotone_accumulate(ordered, acc_bits, saturate=True)
        return acc
    if policy == "sorted_tiled_seq":
        ordered = tiled_seq_order(prods, k_tile, rounds)
        acc, _ = monotone_accumulate(ordered, acc_bits, saturate=True)
        return acc
    raise ValueError(f"unknown policy {policy!r}")


@partial(
    jax.jit,
    static_argnames=("acc_bits", "policy", "k_shards", "k_tile", "rounds"),
)
def kshard_partials(
    prods: jax.Array,
    acc_bits: int,
    policy: Policy = "clip",
    k_shards: int = 1,
    k_tile: int = 256,
    rounds: int = 1,
) -> jax.Array:
    """Per-shard policy partials — phase 1 of ``kshard_accumulate``.

    ``prods`` is (..., K) with K divisible by ``k_shards``: each
    contiguous K/k_shards slice accumulates independently under
    ``policy`` (exactly ``accumulate`` on the slice — the same order a
    shard's kernel realizes on its local K). Returns the (..., S) int32
    per-shard registers still awaiting the cross-shard combine — what a
    deferred-combine ``pqs_dot`` holds while the exchange is in flight.
    """
    k = prods.shape[-1]
    if k % k_shards:
        raise ValueError(f"K={k} not divisible by k_shards={k_shards}")
    sh = prods.reshape(*prods.shape[:-1], k_shards, k // k_shards)
    return accumulate(sh, acc_bits, policy, k_tile, rounds)


@partial(
    jax.jit,
    static_argnames=("acc_bits", "policy", "k_shards", "k_tile", "rounds"),
)
def kshard_accumulate(
    prods: jax.Array,
    acc_bits: int,
    policy: Policy = "clip",
    k_shards: int = 1,
    k_tile: int = 256,
    rounds: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Hierarchical K-sharded accumulation — the jnp oracle of the
    K-sharded ``pqs_dot`` path.

    Phase 1 (``kshard_partials``) accumulates each contiguous K/k_shards
    slice independently under ``policy``; phase 2 merges the per-shard
    registers up the shared static combine tree
    (``sorted_accum.tree_combine`` — the same ``combine_schedule`` the
    mesh realizes with ppermute exchanges). Returns
    ``(value, n_combine_overflows)`` where the second output counts, per
    dot, the combine steps whose exact pairwise sum left the acc_bits
    range (see ``tree_combine``; for ``wide`` it counts int32 carrier
    wraps instead — zero in every valid regime).
    """
    parts = kshard_partials(prods, acc_bits, policy, k_shards, k_tile, rounds)
    return tree_combine(parts, acc_bits, policy)


@partial(jax.jit, static_argnames=("acc_bits", "policy", "k_tile", "rounds"))
def transient_survivors(
    prods: jax.Array,
    acc_bits: int,
    policy: Policy = "sorted",
    k_tile: int = 256,
    rounds: int = 2,
) -> jax.Array:
    """Count dot products whose *transient* overflow a policy fails to fix.

    A dot product is a transient case if its exact result fits p bits but
    natural-order accumulation overflows. Under the given policy's order we
    re-check whether any intermediate still leaves the range. Used for the
    99.8 % / 99 % single-round and tiled-sort claims (paper §3.2, §6).
    """
    qmin, qmax = qrange(acc_bits)
    final = jnp.sum(prods, axis=-1)
    fits = jnp.logical_and(final <= qmax, final >= qmin)
    if policy == "sorted":
        ordered = sorted_order(prods, rounds)
    elif policy == "sorted_tiled":
        ordered = tiled_sorted_order(prods, k_tile, rounds)
    elif policy == "sorted_tiled_seq":
        ordered = tiled_seq_order(prods, k_tile, rounds)
    elif policy == "natural":
        ordered = prods
    else:
        raise ValueError(f"unknown policy {policy!r}")
    run = jnp.cumsum(ordered, axis=-1)
    ovf = jnp.any(jnp.logical_or(run > qmax, run < qmin), axis=-1)
    return jnp.sum(jnp.logical_and(fits, ovf))


def quantized_matmul_sim(
    wq: jax.Array,
    xq: jax.Array,
    acc_bits: int,
    policy: Policy = "clip",
    k_tile: int = 256,
    batch_chunk: int | None = None,
    rounds: int = 2,
) -> jax.Array:
    """Full quantized matmul with simulated narrow accumulation.

    wq: (out, K), xq: (batch, K) -> (batch, out) int32, each output element
    accumulated under ``policy``. Thin wrapper over the unified dispatch
    layer (jnp reference backend) — kept for the analysis tooling's
    (weights, activations) argument order.
    """
    from repro.core.dispatch import pqs_dot  # dispatch builds on this module

    return pqs_dot(
        xq, wq, acc_bits=acc_bits, policy=policy, k_tile=k_tile,
        rounds=rounds, backend="jnp", batch_chunk=batch_chunk,
    )


def matmul_census(
    wq: jax.Array,
    xq: jax.Array,
    acc_bits: int,
    batch_chunk: int = 128,
) -> Census:
    """Census over every dot product of a quantized matmul (Fig 2a data)."""
    tot = dict(n_dots=0, n_persistent=0, n_transient=0, n_any=0)
    for i in range(0, xq.shape[0], batch_chunk):
        prods = partial_products(wq, xq[i : i + batch_chunk])
        c = census(prods, acc_bits)
        for k in tot:
            tot[k] += int(getattr(c, k))
    return Census(**{k: jnp.asarray(v) for k, v in tot.items()})
