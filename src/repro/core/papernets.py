"""The paper's own evaluation networks + P->Q / Q->P training harness.

Models (configs/paper.py):
  mlp1    : Linear(784 -> 10)                      — Fig 2 overflow census
  mlp2    : 784x784 hidden + 784x10 head           — Fig 3 low-rank study
  convnet : 2 stride-2 3x3 conv layers (as im2col + QuantLinear) + head
            — the CIFAR-scale stand-in for Fig 4/5 trends

All layers are ``core.pqs.QuantLinear`` instances, so the trained nets
drop straight into the overflow library and the narrow-accumulator
evaluation paths. Training is plain SGD+momentum on softmax CE with the
paper's epoch-indexed prune/quantize schedules (core.pqs.build_schedule).

Offline container note: datasets are the synthetic stand-ins from
repro.data; trends (clip-vs-sort, P->Q-vs-Q->P, pareto shape) are the
reproduced claims, not absolute MNIST/CIFAR numbers (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import PaperNetConfig
from repro.core import overflow
from repro.core.a2q import a2q_fake_quant
from repro.core.pqs import (
    PQSConfig,
    apply_prune_phase,
    build_schedule,
    quant_linear_census,
    quant_linear_freeze,
    quant_linear_init,
    quant_linear_int_fwd,
    quant_linear_train_fwd,
)
from repro.core.pruning import low_rank_approx
from repro.data.pipeline import ClassificationDataset


# ---------------------------------------------------------------------------
# model definitions (lists of QuantLinear layers + structure fns)
# ---------------------------------------------------------------------------


def _img_patches(x: jax.Array, hw: int, cin: int, stride: int = 2):
    """im2col: (B, hw*hw*cin) -> (B, oh*ow, 3*3*cin) patches."""
    b = x.shape[0]
    img = x.reshape(b, hw, hw, cin)
    patches = jax.lax.conv_general_dilated_patches(
        jnp.moveaxis(img, -1, 1), (3, 3), (stride, stride), "SAME"
    )  # (B, cin*9, oh, ow)
    _, f, oh, ow = patches.shape
    return jnp.moveaxis(patches, 1, -1).reshape(b, oh * ow, f), oh, ow


def init_papernet(key, cfg: PaperNetConfig) -> list[dict[str, Any]]:
    ks = jax.random.split(key, 4)
    if cfg.kind == "mlp1":
        return [quant_linear_init(ks[0], cfg.in_dim, cfg.num_classes)]
    if cfg.kind == "mlp2":
        return [
            quant_linear_init(ks[0], cfg.in_dim, cfg.hidden),
            quant_linear_init(ks[1], cfg.hidden, cfg.num_classes),
        ]
    if cfg.kind == "convnet":
        c1, c2 = cfg.channels
        cin = cfg.in_dim // (cfg.img_hw * cfg.img_hw)
        oh1 = (cfg.img_hw + 1) // 2  # stride-2 SAME conv output size
        oh2 = (oh1 + 1) // 2
        return [
            quant_linear_init(ks[0], 9 * cin, c1),  # conv1 as im2col matmul
            quant_linear_init(ks[1], 9 * c1, c2),  # conv2
            quant_linear_init(ks[2], oh2 * oh2 * c2, cfg.num_classes),
        ]
    raise ValueError(cfg.kind)


# which layers are pruned/quantized: paper §5.0.2 skips the first conv and
# the final classifier head of CNNs; MLPs prune their hidden layer only.
def pqs_layer_mask(cfg: PaperNetConfig) -> list[bool]:
    if cfg.kind == "mlp1":
        return [True]
    if cfg.kind == "mlp2":
        return [True, False]
    return [False, True, False]


def papernet_fwd(
    layers: list[dict],
    x: jax.Array,
    cfg: PaperNetConfig,
    pqs: PQSConfig,
    quantizing: bool,
    int_path: bool = False,
    frozen: Optional[list] = None,
    policy: Optional[str] = None,
    acc_bits: Optional[int] = None,
) -> tuple[jax.Array, list[dict]]:
    """Forward through the net. Training path updates act ranges; int path
    consumes frozen layers under (policy, acc_bits)."""

    def layer(i, h):
        nonlocal layers
        if int_path:
            c = dataclasses.replace(
                pqs,
                policy=policy or pqs.policy,
                acc_bits=acc_bits or pqs.acc_bits,
            )
            return quant_linear_int_fwd(frozen[i], h, c)
        out, new_p = quant_linear_train_fwd(layers[i], h, pqs, quantizing)
        layers = layers[:i] + [new_p] + layers[i + 1:]
        return out

    if cfg.kind in ("mlp1", "mlp2"):
        h = x
        for i in range(len(layers)):
            h = layer(i, h)
            if i < len(layers) - 1:
                h = jax.nn.relu(h)
        return h, layers

    # convnet: conv-as-im2col stride 2 twice, then flatten + head
    cin = cfg.in_dim // (cfg.img_hw * cfg.img_hw)
    p1, oh, ow = _img_patches(x, cfg.img_hw, cin)
    h = jax.nn.relu(layer(0, p1))  # (B, oh*ow, c1)
    h2, oh2, ow2 = _img_patches(
        h.reshape(h.shape[0], -1), oh, cfg.channels[0]
    )
    h = jax.nn.relu(layer(1, h2))  # (B, oh2*ow2, c2)
    h = h.reshape(h.shape[0], -1)
    return layer(2, h), layers


def ce_loss(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


# ---------------------------------------------------------------------------
# training harness
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    layers: list[dict]
    fp32_acc: float
    history: list[tuple[int, float]]


def train_papernet(
    cfg: PaperNetConfig,
    pqs: PQSConfig,
    data: ClassificationDataset,
    epochs: int = 30,
    batch: int = 128,
    lr: float = 0.05,
    momentum: float = 0.9,
    prune_every: int = 5,
    fp32_frac: float = 0.7,
    low_rank: Optional[int] = None,
    a2q_acc_bits: Optional[int] = None,
    prune_kind: str = "nm",  # "nm" | "filter" (Fig 4 magenta baseline)
    seed: int = 0,
) -> TrainResult:
    """Run a full P->Q or Q->P schedule (pqs.order) on a paper net.

    low_rank: apply a rank-k approximation at each prune event (Fig 3).
    a2q_acc_bits: replace PQS with the A2Q weight constraint (baseline).
    prune_kind: N:M (paper) or whole-filter structured pruning baseline.
    """
    train, test = data.split(0.9)
    key = jax.random.PRNGKey(seed)
    layers = init_papernet(key, cfg)
    mask = pqs_layer_mask(cfg)
    vel = [jax.tree_util.tree_map(
        lambda a: jnp.zeros_like(a) if a.dtype == jnp.float32 else None,
        {"w": l["w"], "b": l["b"]}) for l in layers]
    schedule = build_schedule(pqs, epochs, prune_every, fp32_frac)

    @partial(jax.jit, static_argnames=("quantizing",))
    def step(layers, vel, xb, yb, quantizing):
        def loss_fn(ls):
            logits, new_ls = papernet_fwd(ls, xb, cfg, pqs, quantizing)
            if a2q_acc_bits is not None:
                # A2Q regime: constrain weights instead of pruning
                new_ls = [
                    dict(l, w=a2q_fake_quant(l["w"], pqs.weight_bits,
                                             a2q_acc_bits))
                    for l in new_ls
                ]
            return ce_loss(logits, yb), new_ls

        (loss, new_layers), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(layers)
        out_l, out_v = [], []
        for l, nl, g, v in zip(layers, new_layers, grads, vel):
            nv = {k: momentum * v[k] + g[k] for k in ("w", "b")}
            upd = dict(nl)
            upd["w"] = nl["w"] - lr * nv["w"]
            upd["b"] = nl["b"] - lr * nv["b"]
            out_l.append(upd)
            out_v.append(nv)
        return out_l, out_v, loss

    history = []
    for ph in schedule:
        # prune/low-rank events
        if ph.n_keep is not None:
            new_layers = []
            for i, l in enumerate(layers):
                if not mask[i]:
                    new_layers.append(l)
                    continue
                if low_rank is not None:
                    l = dict(l, w=low_rank_approx(l["w"], low_rank))
                if prune_kind == "filter":
                    from repro.core.pruning import filter_prune_mask

                    keep_frac = ph.n_keep / pqs.m
                    l = dict(l, mask=filter_prune_mask(l["w"], keep_frac))
                    new_layers.append(l)
                else:
                    new_layers.append(
                        apply_prune_phase(
                            l, ph, pqs, quantized_signal=(pqs.order == "qp")
                        )
                    )
            layers = new_layers
        for xb, yb in train.batches(batch, seed=seed * 997 + ph.epoch):
            layers, vel, loss = step(
                layers, vel, jnp.asarray(xb), jnp.asarray(yb),
                quantizing=ph.quantizing,
            )
        history.append((ph.epoch, float(loss)))

    acc = evaluate_fp32(layers, cfg, pqs, test)
    return TrainResult(layers, acc, history)


def evaluate_fp32(layers, cfg, pqs: PQSConfig,
                  data: ClassificationDataset) -> float:
    logits, _ = papernet_fwd(layers, jnp.asarray(data.x), cfg, pqs,
                             quantizing=False)
    return float((jnp.argmax(logits, -1) == jnp.asarray(data.y)).mean())


def freeze_net(layers, cfg, pqs: PQSConfig) -> list[dict]:
    mask = pqs_layer_mask(cfg)
    out = []
    for i, l in enumerate(layers):
        out.append(quant_linear_freeze(l, pqs if mask[i] else
                                       dataclasses.replace(pqs, n_keep=pqs.m)))
    return out


def evaluate_int(
    layers, cfg, pqs: PQSConfig, data: ClassificationDataset,
    policy: str, acc_bits: int, limit: int = 1024,
) -> float:
    """Accuracy with true integer matmuls under a narrow-accum policy."""
    frozen = freeze_net(layers, cfg, pqs)
    x = jnp.asarray(data.x[:limit])
    y = np.asarray(data.y[:limit])
    logits, _ = papernet_fwd(
        layers, x, cfg, pqs, quantizing=False, int_path=True,
        frozen=frozen, policy=policy, acc_bits=acc_bits,
    )
    return float((np.argmax(np.asarray(logits), -1) == y).mean())


def overflow_profile(
    layers, cfg, pqs: PQSConfig, data: ClassificationDataset,
    acc_bits: int, limit: int = 512,
) -> overflow.Census:
    """Aggregate persistent/transient census over all PQS layers (Fig 2a)."""
    frozen = freeze_net(layers, cfg, pqs)
    mask = pqs_layer_mask(cfg)
    tot = dict(n_dots=0, n_persistent=0, n_transient=0, n_any=0)
    x = jnp.asarray(data.x[:limit])
    h = x
    for i in range(len(layers)):
        if cfg.kind in ("mlp1", "mlp2"):
            if mask[i]:
                c = quant_linear_census(frozen[i], h, dataclasses.replace(
                    pqs, acc_bits=acc_bits))
                for k in tot:
                    tot[k] += int(getattr(c, k))
            h_out, _ = papernet_fwd(
                layers[: i + 1], x, cfg, pqs, quantizing=False
            )
            h = jax.nn.relu(h_out) if i < len(layers) - 1 else h_out
    return overflow.Census(**{k: jnp.asarray(v) for k, v in tot.items()})
