"""PQS orchestration: config, quantized layers, and P->Q / Q->P schedules.

This is the paper's contribution packaged as a composable JAX module:

- ``PQSConfig`` — the knobs of the design space swept in paper §5.2
  (weight/activation/accumulator bitwidths, N:M sparsity, accumulation
  policy, K-tile for tiled sorting).
- ``QuantLinear`` — a functional linear layer with three execution paths:
  * ``train``  : FP32 matmul with N:M mask + QAT fake-quant (STE),
  * ``int``    : true integer dot products with simulated narrow
                 accumulation (the overflow library / kernels semantics),
  * ``analyze``: integer path that additionally returns the overflow census.
- Schedule builders for P->Q (FP32 prune epochs, then QAT) and Q->P (QAT
  throughout, prune quantized weights) — paper §4/§5.1.

The layer is deliberately framework-free (params and state are plain dicts)
so the same code runs inside the MLP/CNN paper benchmarks and inside the LM
model zoo's quantized projections.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import dispatch, overflow
from repro.core.pruning import iterative_nm_schedule, nm_prune_mask
from repro.core.quant import (
    EmaRange,
    activation_qparams,
    fake_quant,
    quantize,
    weight_qparams,
)


@dataclasses.dataclass(frozen=True)
class PQSConfig:
    """Design-space point for PQS (paper §5.2 sweeps all of these)."""

    weight_bits: int = 8
    act_bits: int = 8
    acc_bits: int = 16
    n_keep: int = 8  # keep n_keep of every m (sparsity = 1 - n_keep/m)
    m: int = 16
    policy: overflow.Policy = "sorted_tiled"  # inference accumulation policy
    k_tile: int = 256
    rounds: int = 2  # split/sort/pair rounds per sorting stage
    # training schedule: "pq" = prune-then-quantize (paper's winner),
    # "qp" = quantize-then-prune baseline.
    order: str = "pq"

    @property
    def sparsity(self) -> float:
        return 1.0 - self.n_keep / self.m

    def validate(self) -> None:
        assert 2 <= self.weight_bits <= 8 and 2 <= self.act_bits <= 8
        assert 8 <= self.acc_bits <= 30
        assert 0 < self.n_keep <= self.m
        assert self.policy in (
            "wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq",
        )
        assert self.order in ("pq", "qp")
        assert self.rounds >= 1


# ---------------------------------------------------------------------------
# QuantLinear — functional quantized linear layer
# ---------------------------------------------------------------------------


def quant_linear_init(
    key: jax.Array, in_dim: int, out_dim: int, dtype=jnp.float32
) -> dict[str, Any]:
    """He-initialized params + PQS state for one linear layer."""
    wkey, _ = jax.random.split(key)
    w = jax.random.normal(wkey, (out_dim, in_dim), dtype) * jnp.sqrt(
        2.0 / in_dim
    )
    return {
        "w": w,
        "b": jnp.zeros((out_dim,), dtype),
        "mask": jnp.ones((out_dim, in_dim), dtype),
        "act_range": EmaRange.init(),
    }


def quant_linear_train_fwd(
    params: dict[str, Any],
    x: jax.Array,
    cfg: PQSConfig,
    quantizing: bool,
) -> tuple[jax.Array, dict[str, Any]]:
    """Training forward: masked weights, optional fake-quant (QAT phase).

    Returns (output, new_params) — new_params carries the updated activation
    range observer. During the FP32 pruning phase (quantizing=False) this is
    a plain masked linear; during QAT both weights and activations pass
    through STE fake-quant, so gradients see quantization error.
    """
    w = params["w"] * params["mask"]
    rng: EmaRange = params["act_range"]
    rng = rng.update(x)
    if quantizing:
        w_qp = weight_qparams(w, cfg.weight_bits)
        w = fake_quant(w, w_qp)
        lo, hi = rng.bounds()
        x_qp = activation_qparams(lo, hi, cfg.act_bits)
        x = fake_quant(x, x_qp)
    y = x @ w.T + params["b"]
    new_params = dict(params)
    new_params["act_range"] = rng
    return y, new_params


def quant_linear_freeze(params: dict[str, Any], cfg: PQSConfig) -> dict[str, Any]:
    """Convert trained FP32 params to the deployable integer form.

    Returns {wq, w_qp, x_qp, bq} where wq is the int32-carrier N:M-masked
    quantized weight matrix and bq the bias folded into the accumulator
    scale (bias is accumulated in the wide domain, standard practice — the
    paper's narrow accumulation concerns the dot product itself, Eq. 4).
    """
    w = params["w"] * params["mask"]
    w_qp = weight_qparams(w, cfg.weight_bits)
    wq = quantize(w, w_qp)
    rng: EmaRange = params["act_range"]
    lo, hi = rng.bounds()
    x_qp = activation_qparams(lo, hi, cfg.act_bits)
    return {"wq": wq, "w_qp": w_qp, "x_qp": x_qp, "b": params["b"]}


def quant_linear_int_fwd(
    frozen: dict[str, Any],
    x: jax.Array,
    cfg: PQSConfig,
    batch_chunk: int | None = 128,
) -> jax.Array:
    """Integer inference with simulated narrow accumulation (Eq. 3/4).

    x is FP32; it is quantized with the calibrated activation params, the
    integer dot product is accumulated under cfg.policy at cfg.acc_bits,
    the activation-offset correction (a weight-only constant) is applied in
    the wide domain, and the result is dequantized back to FP32.
    """
    wq, w_qp, x_qp = frozen["wq"], frozen["w_qp"], frozen["x_qp"]
    xq = quantize(x, x_qp)
    lead = x.shape[:-1]
    xq2 = xq.reshape(-1, xq.shape[-1])
    z = dispatch.pqs_dot(
        xq2, wq, acc_bits=cfg.acc_bits, policy=cfg.policy,
        k_tile=cfg.k_tile, rounds=cfg.rounds, batch_chunk=batch_chunk,
    )
    # offset correction: o_x * sum_i w_i^q per output neuron (wide domain)
    corr = x_qp.offset.astype(jnp.int32) * jnp.sum(wq, axis=-1)
    z = z - corr[None, :]
    zf = z.astype(jnp.float32) * (w_qp.scale * x_qp.scale)
    zf = zf + frozen["b"][None, :]
    return zf.reshape(*lead, -1)


def quant_linear_census(
    frozen: dict[str, Any], x: jax.Array, cfg: PQSConfig
) -> overflow.Census:
    """Overflow census for this layer on a batch (analysis path).

    Uses the census oracle directly — ``pqs_dot(..., with_census=True)``
    is for callers that need the accumulated output *and* the census
    from one partial-products pass."""
    xq = quantize(x, frozen["x_qp"]).reshape(-1, x.shape[-1])
    return overflow.matmul_census(frozen["wq"], xq, cfg.acc_bits)


# ---------------------------------------------------------------------------
# Training schedules (paper §4, §5.0.2, §5.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Phase:
    """One epoch's directives for the schedule driver."""

    epoch: int
    quantizing: bool  # QAT fake-quant active this epoch?
    n_keep: Optional[int]  # if set, re-prune to keep n_keep of every m


def pq_schedule(
    cfg: PQSConfig, total_epochs: int, prune_every: int, fp32_epochs: int
) -> list[Phase]:
    """P->Q: FP32 training with iterative pruning, then QAT on survivors.

    Mirrors paper §5.1: e.g. 180 FP32 epochs (pruning every 10) + 20 QAT.
    """
    prunes = dict(
        iterative_nm_schedule(
            max(fp32_epochs - 1, 1), prune_every, cfg.m, cfg.sparsity
        )
    )
    return [
        Phase(e, quantizing=(e >= fp32_epochs), n_keep=prunes.get(e))
        for e in range(total_epochs)
    ]


def qp_schedule(
    cfg: PQSConfig, total_epochs: int, prune_every: int
) -> list[Phase]:
    """Q->P: QAT for all epochs; prune the (fake-)quantized weights."""
    prunes = dict(
        iterative_nm_schedule(total_epochs, prune_every, cfg.m, cfg.sparsity)
    )
    return [
        Phase(e, quantizing=True, n_keep=prunes.get(e))
        for e in range(total_epochs)
    ]


def build_schedule(
    cfg: PQSConfig,
    total_epochs: int,
    prune_every: int = 10,
    fp32_frac: float = 0.9,
) -> list[Phase]:
    cfg.validate()
    if cfg.order == "pq":
        return pq_schedule(
            cfg, total_epochs, prune_every, int(total_epochs * fp32_frac)
        )
    return qp_schedule(cfg, total_epochs, prune_every)


def apply_prune_phase(
    params: dict[str, Any], phase: Phase, cfg: PQSConfig, quantized_signal: bool
) -> dict[str, Any]:
    """Re-prune a layer per the phase directive.

    quantized_signal selects the pruning signal: FP32 master weights (P->Q)
    or their fake-quantized image (Q->P) — the comparison at the heart of
    paper §4.
    """
    if phase.n_keep is None:
        return params
    w = params["w"]
    if quantized_signal:
        qp = weight_qparams(w, cfg.weight_bits)
        w = fake_quant(w, qp)
    new = dict(params)
    new["mask"] = nm_prune_mask(w, phase.n_keep, cfg.m)
    return new
