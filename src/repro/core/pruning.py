"""N:M semi-structured pruning, filter pruning, and low-rank approximation.

Implements the paper's pruning substrate (§2.2, §4, §5.0.2):

- ``nm_prune_mask``: keep the largest (M - n_prune) of every M consecutive
  weights along the last axis — the N:M scheme (paper prunes the *smallest N
  of every M*; we parameterize by number pruned for clarity).
- Iterative schedules: prune 10 % of each M-group every 10 epochs until the
  target sparsity is reached (paper §5.0.2).
- Filter pruning baseline (paper Fig 4 magenta).
- Low-rank (SVD) approximation used by the Fig-3 experiment.

Masks are computed functionally and applied multiplicatively so they compose
with QAT fake-quant and with any model definition in the zoo.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def nm_prune_mask(w: jax.Array, n_keep: int, m: int) -> jax.Array:
    """Binary mask keeping the ``n_keep`` largest-|w| of every ``m`` along axis -1.

    The trailing dimension must be divisible by m (configs in this repo pad
    to multiples of m where needed). Ties broken by index (stable top-k).
    """
    if w.shape[-1] % m != 0:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by M={m}")
    if not (0 <= n_keep <= m):
        raise ValueError(f"n_keep={n_keep} out of range for M={m}")
    groups = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    mag = jnp.abs(groups)
    # Rank within each group; keep the n_keep largest magnitudes.
    # argsort of -mag gives descending order positions.
    order = jnp.argsort(-mag, axis=-1)
    ranks = jnp.argsort(order, axis=-1)  # rank of each element (0 = largest)
    mask = (ranks < n_keep).astype(w.dtype)
    return mask.reshape(w.shape)


def sparsity(mask: jax.Array) -> jax.Array:
    """Fraction of zeros in a mask/tensor."""
    return 1.0 - jnp.mean((mask != 0).astype(jnp.float32))


def iterative_nm_schedule(
    total_epochs: int,
    prune_every: int,
    m: int,
    target_sparsity: float,
) -> list[tuple[int, int]]:
    """Paper §5.0.2 schedule: every ``prune_every`` epochs prune ~10 % more.

    Returns [(epoch, n_keep), ...] — at ``epoch``, re-prune to keep
    ``n_keep`` of every m. E.g. m=16, target 30 %: epochs 10/20/30 keep
    14/13/11 (approx 10/20/30 % pruned).
    """
    steps = []
    frac_per_step = 0.10
    spars = 0.0
    epoch = prune_every
    while spars + 1e-9 < target_sparsity and epoch <= total_epochs:
        spars = min(spars + frac_per_step, target_sparsity)
        if epoch + prune_every > total_epochs:
            spars = target_sparsity  # last chance: jump to target
        n_keep = int(round(m * (1.0 - spars)))
        n_keep = max(n_keep, 0)
        steps.append((epoch, n_keep))
        epoch += prune_every
    return steps


def filter_prune_mask(w: jax.Array, keep_frac: float) -> jax.Array:
    """Structured filter pruning baseline (paper Fig 4): zero whole output
    rows (filters) with the smallest L2 norm. w has shape (out, in...)."""
    flat = w.reshape(w.shape[0], -1)
    norms = jnp.linalg.norm(flat, axis=1)
    k = max(int(round(w.shape[0] * keep_frac)), 1)
    thresh = jnp.sort(norms)[-k]
    mask_rows = (norms >= thresh).astype(w.dtype)
    return mask_rows.reshape((-1,) + (1,) * (w.ndim - 1)) * jnp.ones_like(w)


def low_rank_approx(w: jax.Array, rank: int) -> jax.Array:
    """Rank-k SVD approximation of a 2-D weight matrix (paper Fig 3)."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    k = min(rank, s.shape[0])
    return (u[:, :k] * s[:k]) @ vt[:k, :]


def nm_compress(w: np.ndarray, n_keep: int, m: int):
    """Pack an N:M-pruned matrix into (values, indices) compressed form.

    w: (rows, K) with K % m == 0 and at most n_keep nonzeros per m-group.
    Returns values (rows, K//m, n_keep) and indices (rows, K//m, n_keep)
    int8/int32 — the storage format consumed by kernels/nm_spmm.py. Groups
    with fewer than n_keep nonzeros are padded with (value 0, index 0).
    """
    w = np.asarray(w)
    rows, K = w.shape
    g = K // m
    grouped = w.reshape(rows, g, m)
    # Indices of the n_keep largest |values| per group (matching the mask).
    order = np.argsort(-np.abs(grouped), axis=-1, kind="stable")[..., :n_keep]
    order = np.sort(order, axis=-1)  # ascending position for locality
    vals = np.take_along_axis(grouped, order, axis=-1)
    return vals, order.astype(np.int32)


def nm_decompress(vals: np.ndarray, idx: np.ndarray, m: int) -> np.ndarray:
    """Inverse of nm_compress (oracle for kernel tests)."""
    rows, g, n_keep = vals.shape
    out = np.zeros((rows, g, m), dtype=vals.dtype)
    np.put_along_axis(out, idx, vals, axis=-1)
    return out.reshape(rows, g * m)
