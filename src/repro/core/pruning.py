"""N:M semi-structured pruning, filter pruning, and low-rank approximation.

Implements the paper's pruning substrate (§2.2, §4, §5.0.2):

- ``nm_prune_mask``: keep the largest (M - n_prune) of every M consecutive
  weights along the last axis — the N:M scheme (paper prunes the *smallest N
  of every M*; we parameterize by number pruned for clarity).
- Iterative schedules: prune 10 % of each M-group every 10 epochs until the
  target sparsity is reached (paper §5.0.2).
- Filter pruning baseline (paper Fig 4 magenta).
- Low-rank (SVD) approximation used by the Fig-3 experiment.

Masks are computed functionally and applied multiplicatively so they compose
with QAT fake-quant and with any model definition in the zoo.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def nm_prune_mask(w: jax.Array, n_keep: int, m: int) -> jax.Array:
    """Binary mask keeping the ``n_keep`` largest-|w| of every ``m`` along axis -1.

    The trailing dimension must be divisible by m (configs in this repo pad
    to multiples of m where needed). Ties broken by index (stable top-k).
    """
    if w.shape[-1] % m != 0:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by M={m}")
    if not (0 <= n_keep <= m):
        raise ValueError(f"n_keep={n_keep} out of range for M={m}")
    groups = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    mag = jnp.abs(groups)
    # Rank within each group; keep the n_keep largest magnitudes.
    # argsort of -mag gives descending order positions.
    order = jnp.argsort(-mag, axis=-1)
    ranks = jnp.argsort(order, axis=-1)  # rank of each element (0 = largest)
    mask = (ranks < n_keep).astype(w.dtype)
    return mask.reshape(w.shape)


def sparsity(mask: jax.Array) -> jax.Array:
    """Fraction of zeros in a mask/tensor."""
    return 1.0 - jnp.mean((mask != 0).astype(jnp.float32))


def iterative_nm_schedule(
    total_epochs: int,
    prune_every: int,
    m: int,
    target_sparsity: float,
) -> list[tuple[int, int]]:
    """Paper §5.0.2 schedule: every ``prune_every`` epochs prune ~10 % more.

    Returns [(epoch, n_keep), ...] — at ``epoch``, re-prune to keep
    ``n_keep`` of every m. E.g. m=16, target 30 %: epochs 10/20/30 keep
    14/13/11 (approx 10/20/30 % pruned).
    """
    steps = []
    frac_per_step = 0.10
    spars = 0.0
    epoch = prune_every
    while spars + 1e-9 < target_sparsity and epoch <= total_epochs:
        spars = min(spars + frac_per_step, target_sparsity)
        if epoch + prune_every > total_epochs:
            spars = target_sparsity  # last chance: jump to target
        n_keep = int(round(m * (1.0 - spars)))
        n_keep = max(n_keep, 0)
        steps.append((epoch, n_keep))
        epoch += prune_every
    return steps


def filter_prune_mask(w: jax.Array, keep_frac: float) -> jax.Array:
    """Structured filter pruning baseline (paper Fig 4): zero whole output
    rows (filters) with the smallest L2 norm. w has shape (out, in...)."""
    flat = w.reshape(w.shape[0], -1)
    norms = jnp.linalg.norm(flat, axis=1)
    k = max(int(round(w.shape[0] * keep_frac)), 1)
    thresh = jnp.sort(norms)[-k]
    mask_rows = (norms >= thresh).astype(w.dtype)
    return mask_rows.reshape((-1,) + (1,) * (w.ndim - 1)) * jnp.ones_like(w)


def low_rank_approx(w: jax.Array, rank: int) -> jax.Array:
    """Rank-k SVD approximation of a 2-D weight matrix (paper Fig 3)."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    k = min(rank, s.shape[0])
    return (u[:, :k] * s[:k]) @ vt[:k, :]


def _check_nm_args(K: int, n_keep: int, m: int) -> None:
    if m < 1:
        raise ValueError(f"m_group must be >= 1, got {m}")
    if not 1 <= n_keep <= m:
        raise ValueError(f"n_keep={n_keep} out of range [1, {m}] for M={m}")
    if K < 1:
        raise ValueError(f"cannot compress an empty K axis (K={K})")


def nm_compress(w: np.ndarray, n_keep: int, m: int):
    """Pack an N:M-pruned matrix into (values, indices) compressed form.

    w: (rows, K) with at most n_keep nonzeros per m-group along K. A K
    that is not divisible by m is handled by zero-padding the tail group
    (the padding never survives ``nm_decompress(..., k=K)``). Returns
    values (rows, G, n_keep) and indices (rows, G, n_keep) with
    G = ceil(K / m) — the storage format consumed by kernels/nm_spmm.py.
    Groups with fewer than n_keep nonzeros are padded with (value 0,
    index 0); ``n_keep == m`` stores the matrix dense-as-sparse (exact
    round-trip, no pruning assumption). A group holding MORE than
    n_keep nonzeros would compress lossily, so it raises instead.

    Canonical-form invariant (established HERE, once, at compress time —
    never re-validated per kernel call): every index lies in [0, m) and
    indices ascend within each group; every slot whose dense position
    holds no kept weight — group padding beyond the group's nonzeros AND
    every tail-group position past the original K — carries value 0.
    The fused gather kernels (``kernels.nm_spmm.gather_nm_products``)
    depend on this to skip tail/pad masking entirely: a gathered pad
    slot multiplies to a zero product, inert through every accumulation
    policy, whether ``K % m == 0`` (no tail group) or not.
    ``nm_assert_canonical`` re-checks the invariant on demand (tests,
    debugging slabs from foreign packers).
    """
    w = np.asarray(w)
    if w.ndim != 2:
        raise ValueError(f"expected a 2-D (rows, K) matrix, got {w.shape}")
    rows, K = w.shape
    _check_nm_args(K, n_keep, m)
    g = -(-K // m)  # ceil: tail group zero-padded below
    if g * m != K:
        w = np.pad(w, ((0, 0), (0, g * m - K)))
    grouped = w.reshape(rows, g, m)
    nnz = np.count_nonzero(grouped, axis=-1)
    if (nnz > n_keep).any():
        raise ValueError(
            f"matrix is not {n_keep}:{m} sparse — a group holds "
            f"{int(nnz.max())} nonzeros (> n_keep={n_keep}); compressing "
            "it would silently drop weights"
        )
    # Indices of the n_keep largest |values| per group (matching the mask).
    order = np.argsort(-np.abs(grouped), axis=-1, kind="stable")[..., :n_keep]
    order = np.sort(order, axis=-1)  # ascending position for locality
    vals = np.take_along_axis(grouped, order, axis=-1)
    return vals, order.astype(np.int32)


def nm_assert_canonical(
    vals: np.ndarray, idx: np.ndarray, m: int, k: int | None = None
) -> None:
    """Assert the compress-time canonical-form invariant of an N:M slab.

    The gather kernels trust — without per-call masks — that a slab
    satisfies: indices in [0, m), ascending within each group, and value
    0 in every slot addressing a dense position that holds no kept
    weight (including, with ``k``, all tail-group positions >= k). This
    helper is the one place that re-checks it; it is meant for tests and
    for validating slabs produced outside ``nm_compress`` /
    ``nm_compress_jax``, NOT for per-call use on hot paths (the packers
    establish the invariant by construction).
    """
    vals = np.asarray(vals)
    idx = np.asarray(idx)
    if vals.shape != idx.shape or vals.ndim < 2:
        raise ValueError(
            f"expected matching (..., G, n_keep) slabs, got {vals.shape} "
            f"vs {idx.shape}"
        )
    g = vals.shape[-2]
    if idx.size and (idx.min() < 0 or idx.max() >= m):
        raise AssertionError(
            f"indices out of range [0, {m}): [{idx.min()}, {idx.max()}]")
    if idx.shape[-1] > 1:
        d = np.diff(idx, axis=-1)
        dup = d == 0
        # padded (value 0, index 0) slots legitimately repeat index 0;
        # a duplicated index is only canonical if its value slot is 0
        if (d < 0).any() or (dup & (np.take(vals, range(1, idx.shape[-1]),
                                            axis=-1) != 0)).any():
            raise AssertionError(
                "indices must ascend within each group (padded slots "
                "carry value 0)")
    if k is not None:
        k_dense = g * m
        if not 0 < k <= k_dense:
            raise ValueError(f"k={k} out of range (0, {k_dense}]")
        base = (np.arange(g, dtype=np.int64) * m).reshape(
            (1,) * (idx.ndim - 2) + (g, 1))
        dense_pos = idx.astype(np.int64) + base
        beyond = dense_pos >= k
        if (np.asarray(vals)[beyond] != 0).any():
            raise AssertionError(
                f"tail positions >= k={k} must carry value 0 (the "
                "ragged-tail zero-pad invariant)")


def nm_decompress(
    vals: np.ndarray, idx: np.ndarray, m: int, k: int | None = None
) -> np.ndarray:
    """Inverse of nm_compress (oracle for kernel tests).

    ``k`` trims the zero-padded tail group back to the original K, so
    a K not divisible by m round-trips exactly.
    """
    rows, g, n_keep = vals.shape
    out = np.zeros((rows, g, m), dtype=vals.dtype)
    np.put_along_axis(out, idx, vals, axis=-1)
    out = out.reshape(rows, g * m)
    return out if k is None else out[:, :k]


def nm_compress_jax(w: jax.Array, n_keep: int, m: int):
    """``nm_compress`` on device arrays, with arbitrary leading dims.

    w: (..., rows, K). Returns (values, indices) shaped
    (..., rows, G, n_keep) with G = ceil(K / m). The lossiness check of
    the numpy packer runs only on concrete (non-traced) inputs.
    """
    K = w.shape[-1]
    _check_nm_args(K, n_keep, m)
    g = -(-K // m)
    if g * m != K:
        pad = [(0, 0)] * (w.ndim - 1) + [(0, g * m - K)]
        w = jnp.pad(w, pad)
    grouped = w.reshape(*w.shape[:-1], g, m)
    if not isinstance(w, jax.core.Tracer):
        nnz = int(jnp.max(jnp.sum(grouped != 0, axis=-1)))
        if nnz > n_keep:
            raise ValueError(
                f"matrix is not {n_keep}:{m} sparse — a group holds "
                f"{nnz} nonzeros (> n_keep={n_keep})"
            )
    order = jnp.argsort(-jnp.abs(grouped), axis=-1)[..., :n_keep]
    order = jnp.sort(order, axis=-1)
    vals = jnp.take_along_axis(grouped, order, axis=-1)
    return vals, order.astype(jnp.int32)


def nm_onehot_expand(vals: jax.Array, idx: jax.Array, m: int) -> jax.Array:
    """THE one compressed->dense expansion: (..., G, n_keep) -> (..., G*m).

    dense[..., g*m + p] = sum_j vals[..., g, j] * [idx[..., g, j] == p].
    Each dense position receives at most one kept value (index-0 padding
    carries value 0), so the sum never collides and stays exact in any
    dtype. ``broadcasted_iota`` keeps it Mosaic-lowerable, so this single
    definition serves both the jnp decompress oracle
    (``nm_decompress_jax``) and the Pallas kernels' in-VMEM expand
    (``kernels.nm_spmm.expand_nm_slab``) — the two storage backends
    cannot desynchronize.
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (m,), idx.ndim)
    onehot = (idx[..., None] == iota).astype(vals.dtype)
    dense = jnp.sum(vals[..., None] * onehot, axis=-2)  # (..., G, m)
    return dense.reshape(*vals.shape[:-2], vals.shape[-2] * m)


def nm_decompress_jax(
    vals: jax.Array, idx: jax.Array, m: int, k: int | None = None
) -> jax.Array:
    """``nm_decompress`` on device arrays, with arbitrary leading dims.

    vals/idx: (..., rows, G, n_keep) -> dense (..., rows, G*m) (trimmed
    to ``k`` when given).
    """
    dense = nm_onehot_expand(vals, idx, m)
    return dense if k is None else dense[..., :k]
