"""QTensor: int8 N:M-pruned weight carrier for the LM model zoo.

This is how PQS becomes a *first-class serving feature* of the framework:
any 2-D weight matrix in the zoo can be swapped for a ``QTensor`` — int8
values (symmetric per-output-channel scales) with an N:M mask already
applied — and every matmul in ``models/layers.py`` transparently
dequantizes on the fly. On TPU the int8(+sparse) weights cut HBM traffic
4-8x vs bf16, which is the dominant roofline term for decode (DESIGN.md §2).

The *numerics* of narrow accumulation (clip / sorted, paper §3) live in
``core/overflow.py`` and ``kernels/``; QTensor is the storage/bandwidth
half of the story. ``quantize_tree`` converts a trained pytree of params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.pruning import nm_compress_jax, nm_decompress_jax, nm_prune_mask
from repro.core.quant import QParams, qrange


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Per-output-channel symmetric int8 weight + fp32 scale.

    values: (in_dim, out_dim) int8 (same layout as the fp weight it replaces)
    scale:  (out_dim,) f32 — column scales (output channels)
    act_qparams: optional calibrated STATIC input-activation QParams
        (scale/offset shaped like values.shape[:-2] so layer-stacked
        QTensors scan cleanly). When present, ``integer_lin`` execution
        quantizes activations with these frozen params instead of the
        dynamic per-call absmax reduction — the calibrate→freeze→serve
        decode path.
    act_corr: with ASYMMETRIC act_qparams, the Eq. (3) offset
        correction o_x * sum_k w_k^q per output channel
        (values.shape[:-2] + (out,)) — a weight-only constant, so it is
        precomputed at freeze time rather than re-reduced every decode
        step. None for symmetric params (o_x = 0).
    """

    values: jax.Array
    scale: jax.Array
    act_qparams: Optional[QParams] = None
    act_corr: Optional[jax.Array] = None

    @property
    def shape(self):
        return self.values.shape

    @property
    def ndim(self):
        return self.values.ndim

    def dequant(self, dtype=jnp.bfloat16) -> jax.Array:
        return (self.values.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.values, self.scale, self.act_qparams, self.act_corr), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseQTensor:
    """N:M-compressed int8 weight: the P of PQS as a storage format.

    The compressed leaves are what ``kernels/nm_spmm.py`` streams from
    HBM (an m_group/n_keep bandwidth saving over the dense int8 matrix):

    values:  (..., out, G, n_keep) int8 — kept weights, G = ceil(in/m)
    indices: (..., out, G, n_keep) int32 — position of each kept value
             inside its m-group (padded slots: index 0, value 0)
    scale:   (..., out) f32 per-output-channel symmetric scales
    m_group / k_dim: static aux — group size and the LOGICAL contraction
             length (k_dim <= G*m_group; a tail group is zero-padded)
    act_qparams / act_corr: calibrated static activation QParams and the
             Eq. (3) offset correction, exactly as on ``QTensor``.

    Layout note: dense ``QTensor.values`` is (in, out); the compressed
    form is output-channel-major (out, G, n_keep) because that is the
    orientation every policy kernel consumes (rows = output channels) —
    no transpose on the serving path. ``pqs_dot(..., storage="nm")``
    accepts a SparseQTensor directly, and every accumulation policy runs
    on the compressed form bit-identically to decompress-then-dense.
    """

    values: jax.Array
    indices: jax.Array
    scale: jax.Array
    m_group: int
    k_dim: int
    act_qparams: Optional[QParams] = None
    act_corr: Optional[jax.Array] = None

    @property
    def shape(self):
        """Logical dense (..., in, out) shape — what the float weight had."""
        lead = self.values.shape[:-3]
        return (*lead, self.k_dim, self.values.shape[-3])

    @property
    def ndim(self):
        return self.values.ndim - 1

    def dequant(self, dtype=jnp.bfloat16) -> jax.Array:
        dense = nm_decompress_jax(
            self.values.astype(jnp.float32), self.indices, self.m_group,
            self.k_dim,
        )  # (..., out, in)
        dense = jnp.swapaxes(dense, -1, -2)  # (..., in, out)
        return (dense * self.scale[..., None, :]).astype(dtype)

    def tree_flatten(self):
        return (
            (self.values, self.indices, self.scale, self.act_qparams,
             self.act_corr),
            (self.m_group, self.k_dim),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, indices, scale, aq, corr = children
        return cls(values, indices, scale, aux[0], aux[1], aq, corr)


def qtensor_nm_compress(qt: QTensor, n_keep: int, m_group: int
                        ) -> SparseQTensor:
    """Pack an N:M-pruned ``QTensor`` into compressed ``SparseQTensor`` form.

    The dense int8 ``values`` (..., in, out) must carry at most n_keep
    nonzeros per m-group along the contraction (in) axis — i.e. come
    from ``quantize_weight(..., n_keep=, m=)`` or an equivalent pruning
    pass; a denser matrix raises (lossy compression). Calibrated
    ``act_qparams``/``act_corr`` ride along unchanged — the kept-only
    sum equals the dense sum, so the Eq. (3) correction is identical.
    """
    wt = jnp.swapaxes(qt.values, -1, -2)  # (..., out, in)
    vals, idx = nm_compress_jax(wt, n_keep, m_group)
    return SparseQTensor(
        vals.astype(qt.values.dtype), idx, qt.scale, m_group,
        qt.values.shape[-2], qt.act_qparams, qt.act_corr,
    )


def nm_compress_tree(params: Any, n_keep: int, m: int = 16) -> Any:
    """Convert every N:M-sparse QTensor leaf to compressed storage.

    Leaves whose dense values are not actually n_keep:m sparse are left
    as dense QTensors (a mixed tree is fine — ``models.layers.lin``
    handles both), so the tree conversion composes with
    ``quantize_tree``'s own skip rules (ragged in_dims quantize dense).
    The fallback must never mask a mistake as "tree had no sparse
    leaves": invalid (n_keep, m) arguments raise up front, and a tree
    where NO QTensor leaf matched the pattern (e.g. pruned 2:8 but
    compressed with (2, 16)) raises instead of silently serving dense.
    """
    if m < 1:
        raise ValueError(f"m_group must be >= 1, got {m}")
    if not 1 <= n_keep <= m:
        raise ValueError(f"n_keep={n_keep} out of range [1, {m}] for M={m}")
    counts = {"dense": 0, "converted": 0}

    def conv(leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        counts["dense"] += 1
        try:
            out = qtensor_nm_compress(leaf, n_keep, m)
        except ValueError:
            return leaf  # not n_keep:m sparse — keep the dense form
        counts["converted"] += 1
        return out

    out = jax.tree_util.tree_map(
        conv, params,
        is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor)),
    )
    if counts["dense"] and not counts["converted"]:
        raise ValueError(
            f"no QTensor leaf ({counts['dense']} seen) is {n_keep}:{m} "
            "sparse — the tree was pruned with a different (n_keep, m) "
            "pattern (or not pruned at all); compressing would silently "
            "serve fully dense"
        )
    return out


def quantize_weight(
    w: jax.Array,
    bits: int = 8,
    n_keep: Optional[int] = None,
    m: int = 16,
) -> QTensor:
    """Symmetric per-column quantization with optional N:M pruning.

    w: (in_dim, out_dim). N:M groups run along the *contraction* (in) axis —
    the direction a dot product accumulates — matching the paper's pruning
    of dot-product terms.
    """
    w = w.astype(jnp.float32)
    if n_keep is not None:
        # mask along axis -1 groups => transpose so groups lie on in_dim
        mask = nm_prune_mask(w.T, n_keep, m).T
        w = w * mask
    _, qmax = qrange(bits)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)  # (out,)
    scale = amax / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def is_qtensor(x: Any) -> bool:
    return isinstance(x, (QTensor, SparseQTensor))


def asarray(w: Any, dtype) -> jax.Array:
    """Uniform accessor used by every matmul in the zoo."""
    if isinstance(w, (QTensor, SparseQTensor)):
        return w.dequant(dtype)
    return w.astype(dtype)


def quantize_tree(
    params: Any,
    bits: int = 8,
    n_keep: Optional[int] = None,
    m: int = 16,
    min_size: int = 1 << 16,
    min_dim: int = 128,
) -> Any:
    """Replace every large >=2-D float leaf with a QTensor.

    Leaves smaller than ``min_size`` elements and leaves whose trailing
    two dims are not both >= ``min_dim`` (norm scales, biases — including
    layer-STACKED biases (L, out), which must not be mistaken for
    matrices) are left untouched. Works on stacked (L, in, out) scan
    params by folding leading axes into vmapped per-matrix quantization.
    """

    def conv(leaf):
        if isinstance(leaf, (QTensor, SparseQTensor)):
            return leaf
        if not isinstance(leaf, jax.Array) and not hasattr(leaf, "dtype"):
            return leaf
        if leaf.ndim < 2 or leaf.size < min_size:
            return leaf
        if min(leaf.shape[-2:]) < min_dim:
            return leaf  # (stacked) bias / tiny table, not a matmul weight
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        qfn = lambda x: quantize_weight(x, bits, n_keep, m)  # noqa: E731
        for _ in range(leaf.ndim - 2):
            qfn = jax.vmap(qfn)
        # N:M needs in_dim % m == 0 on the contraction axis; skip otherwise.
        if n_keep is not None and leaf.shape[-2] % m != 0:
            qfn = lambda x, _q=bits: quantize_weight(x, _q, None, m)  # noqa: E731
            for _ in range(leaf.ndim - 2):
                qfn = jax.vmap(qfn)
        return qfn(leaf)

    return jax.tree_util.tree_map(
        conv, params,
        is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor)),
    )


def attach_act_qparams(params: Any, frozen: dict[str, QParams]) -> Any:
    """Freeze calibrated activation ranges into a quantized param tree.

    ``frozen`` maps call-site names (the last path key of a QTensor leaf:
    "wq", "w_gate", ...) to static QParams from ``ActCalibrator.freeze``.
    Each matching QTensor gets ``act_qparams`` whose scale/offset are
    broadcast to ``values.shape[:-2]`` — layer-stacked (L, in, out)
    weights carry (L,)-shaped params so ``jax.lax.scan`` slices them
    per layer alongside the weights.
    """

    def name_of(path) -> str:
        for entry in reversed(path):
            key = getattr(entry, "key", None)
            if isinstance(key, str):
                return key
        return ""

    def conv(path, leaf):
        if not isinstance(leaf, (QTensor, SparseQTensor)):
            return leaf
        qp = frozen.get(name_of(path))
        if qp is None:
            return leaf
        sparse = isinstance(leaf, SparseQTensor)
        lead = leaf.values.shape[:-3] if sparse else leaf.values.shape[:-2]
        aq = QParams(
            jnp.broadcast_to(qp.scale, lead).astype(jnp.float32),
            jnp.broadcast_to(qp.offset, lead).astype(jnp.int32),
            qp.bits,
            qp.symmetric,
        )
        corr = None
        if not qp.symmetric:
            # Eq. (3): o_x * sum_k w_k^q — weight-only, frozen here so
            # decode never re-reduces the weight matrix. For compressed
            # storage the kept-only sum IS the dense sum (pruned = 0).
            wsum = (
                jnp.sum(leaf.values.astype(jnp.int32), axis=(-2, -1))
                if sparse
                else jnp.sum(leaf.values.astype(jnp.int32), axis=-2)
            )
            corr = aq.offset[..., None] * wsum
        if sparse:
            return SparseQTensor(leaf.values, leaf.indices, leaf.scale,
                                 leaf.m_group, leaf.k_dim, aq, corr)
        return QTensor(leaf.values, leaf.scale, aq, corr)

    return jax.tree_util.tree_map_with_path(
        conv, params,
        is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor)),
    )
