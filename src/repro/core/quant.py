"""Uniform quantization primitives (paper §2.1).

Per-tensor uniform quantization of weights (symmetric, o_w = 0) and
activations (asymmetric, offset o_x) to b-bit signed integers, plus the
straight-through-estimator fake-quant used for QAT.

All functions are pure and jit-able. Integer values are carried in int32
(the "carrier" dtype) regardless of the logical bitwidth b — the logical
width is enforced by the clip bounds, matching the paper's MCU semantics.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def qrange(bits: int) -> tuple[int, int]:
    """Signed integer range [-2^(b-1), 2^(b-1)-1] for a b-bit value."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class QParams:
    """Quantization parameters for one tensor (per-tensor granularity).

    ``symmetric`` is STATIC metadata (pytree aux): True marks params whose
    offset is identically zero by construction, so integer-dot consumers
    may skip the offset-correction term without inspecting traced values.
    """

    scale: jax.Array  # f32 scalar
    offset: jax.Array  # i32 scalar (0 for symmetric/weights)
    bits: int
    symmetric: bool = False

    def tree_flatten(self):  # registered below
        return (self.scale, self.offset), (self.bits, self.symmetric)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)


jax.tree_util.register_pytree_node(
    QParams, QParams.tree_flatten, QParams.tree_unflatten
)


def weight_qparams(w: jax.Array, bits: int) -> QParams:
    """Symmetric per-tensor weight quantization params (o_w = 0, paper §2.1)."""
    amax = jnp.max(jnp.abs(w))
    # Avoid div-by-zero for all-zero tensors.
    amax = jnp.maximum(amax, 1e-8)
    _, qmax = qrange(bits)
    scale = amax / qmax
    return QParams(scale.astype(jnp.float32), jnp.zeros((), jnp.int32), bits,
                   symmetric=True)


def activation_qparams(
    lo: jax.Array, hi: jax.Array, bits: int
) -> QParams:
    """Asymmetric activation params from a calibrated range [lo, hi].

    Follows paper Eq. (1): scale s_x = R / (2^b - 1) and offset
    o_x = -2^(b-1) - round(min/s_x), guaranteeing FP32 zero maps to an
    integer (zero-point correctness for ReLU-sparse activations).
    """
    lo = jnp.minimum(lo, 0.0)  # range must include 0 so zero is representable
    hi = jnp.maximum(hi, 0.0)
    r = jnp.maximum(hi - lo, 1e-8)
    scale = r / (2**bits - 1)
    qmin, _ = qrange(bits)
    offset = qmin - jnp.round(lo / scale)
    return QParams(
        scale.astype(jnp.float32), offset.astype(jnp.int32), bits
    )


def symmetric_activation_qparams(
    lo: jax.Array, hi: jax.Array, bits: int
) -> QParams:
    """Symmetric (offset-free) activation params from a calibrated range.

    scale = max(|lo|, |hi|) / (2^(b-1) - 1); offset = 0. Costs up to one
    bit of range vs the asymmetric form but lets the integer dot skip the
    o_x * sum(w) correction entirely — the serving-latency trade the
    calibrated-static decode path defaults to.
    """
    amax = jnp.maximum(jnp.maximum(jnp.abs(lo), jnp.abs(hi)), 1e-8)
    _, qmax = qrange(bits)
    scale = amax / qmax
    return QParams(scale.astype(jnp.float32), jnp.zeros((), jnp.int32), bits,
                   symmetric=True)


def quantize(x: jax.Array, qp: QParams) -> jax.Array:
    """FP32 -> int32 carrier holding a qp.bits-bit signed value (Eq. 1)."""
    qmin, qmax = qrange(qp.bits)
    q = jnp.round(x / qp.scale) + qp.offset
    return jnp.clip(q, qmin, qmax).astype(jnp.int32)


def dequantize(q: jax.Array, qp: QParams) -> jax.Array:
    """Approximate FP32 representation x^{f*} = s (q - o) (Eq. 2)."""
    return (q.astype(jnp.float32) - qp.offset.astype(jnp.float32)) * qp.scale


def fake_quant(x: jax.Array, qp: QParams) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator (QAT).

    Forward: dequantize(quantize(x)). Backward: identity inside the
    representable range, zero outside (clipped STE).
    """
    qmin, qmax = qrange(qp.bits)
    lo = (qmin - qp.offset).astype(jnp.float32) * qp.scale
    hi = (qmax - qp.offset).astype(jnp.float32) * qp.scale
    x_c = jnp.clip(x, lo, hi)
    y = dequantize(quantize(x_c, qp), qp)
    # STE: forward y, gradient of clip(x).
    return x_c + jax.lax.stop_gradient(y - x_c)


@dataclasses.dataclass
class EmaRange:
    """Exponential-moving-average activation range observer (paper §2.1:

    activation ranges are collected during training). Functional update —
    returns the new state rather than mutating.

    ``lo``/``hi`` are the raw zero-initialized EMA; after n updates they
    underestimate the true range by a factor 1 - decay^n (for decay 0.99
    that is still ~3x off after 40 steps). ``bounds()`` applies the
    bias correction — exactly Adam's moment debiasing — and is what every
    calibration consumer must read.
    """

    lo: jax.Array
    hi: jax.Array
    decay: float = 0.99
    n: jax.Array | float = 0.0

    def update(self, x: jax.Array) -> "EmaRange":
        return self.update_bounds(jnp.min(x), jnp.max(x))

    def update_bounds(self, blo: jax.Array, bhi: jax.Array) -> "EmaRange":
        new_lo = self.decay * self.lo + (1 - self.decay) * blo
        new_hi = self.decay * self.hi + (1 - self.decay) * bhi
        # float32 counter: the observer rides inside the param pytree that
        # jax.grad differentiates, and grad rejects integer inputs.
        return EmaRange(
            new_lo, new_hi, self.decay,
            jnp.asarray(self.n, jnp.float32) + 1.0,
        )

    def bounds(self) -> tuple[jax.Array, jax.Array]:
        """Bias-corrected (lo, hi) calibrated range."""
        n = jnp.asarray(self.n, jnp.float32)
        corr = jnp.maximum(1.0 - self.decay**n, 1e-8)
        return self.lo / corr, self.hi / corr

    @staticmethod
    def init() -> "EmaRange":
        return EmaRange(jnp.zeros(()), jnp.zeros(()), n=jnp.zeros(()))


jax.tree_util.register_pytree_node(
    EmaRange,
    lambda e: ((e.lo, e.hi, e.n), (e.decay,)),
    lambda aux, ch: EmaRange(ch[0], ch[1], aux[0], ch[2]),
)


class ActCalibrator:
    """Host-side per-site activation-range collector (paper §2.1 setup).

    Sites are the named QTensor-projection call sites in the model zoo
    ("wq", "w_gate", ...). During a calibration pass the sites report
    concrete per-call (min, max) via ``jax.debug.callback`` — the only
    channel that works from inside ``jax.lax.scan`` layer loops — and
    each site's range is tracked by a bias-corrected ``EmaRange``. Layers
    that share a scanned call site therefore share one range (per-site
    granularity); ``freeze`` turns the corrected bounds into static
    ``QParams`` for the serving decode path.
    """

    def __init__(self, decay: float = 0.9):
        self.decay = decay
        self.ranges: dict[str, EmaRange] = {}

    def observe(self, site: str, lo, hi) -> None:
        er = self.ranges.get(site)
        if er is None:
            er = EmaRange(jnp.zeros(()), jnp.zeros(()), self.decay,
                          jnp.zeros(()))
        self.ranges[site] = er.update_bounds(jnp.asarray(lo, jnp.float32),
                                             jnp.asarray(hi, jnp.float32))

    def freeze(self, bits: int = 8, symmetric: bool = True
               ) -> dict[str, QParams]:
        """Bias-corrected static QParams per calibrated site."""
        out = {}
        for site, er in self.ranges.items():
            lo, hi = er.bounds()
            out[site] = (
                symmetric_activation_qparams(lo, hi, bits)
                if symmetric
                else activation_qparams(lo, hi, bits)
            )
        return out


def quantized_dot_terms(
    wq: jax.Array, xq: jax.Array, x_qp: QParams
) -> tuple[jax.Array, jax.Array]:
    """Partial products and the activation-offset correction term.

    With o_w = 0 (symmetric weights), Eq. (3) reduces to
        z_f = s_w s_x [ sum_i w_i^q x_i^q  -  o_x sum_i w_i^q ]
    The first summation is the integer dot product of Eq. (4) — the object
    PQS accumulates in a narrow register. The second is a weight-only
    constant folded at compile time. Returns (partial_products, correction)
    where partial_products[..., k] = w_k^q * x_k^q (int32) and correction is
    o_x * sum_k w_k^q.
    """
    prods = wq.astype(jnp.int32) * xq.astype(jnp.int32)
    corr = x_qp.offset.astype(jnp.int32) * jnp.sum(
        wq.astype(jnp.int32), axis=-1
    )
    return prods, corr
