"""Sorted dot product (paper Algorithm 1) and its tiled TPU-friendly variants.

The key idea: transient overflows are an artifact of accumulation *order*.
Splitting partial products into positives and negatives, sorting positives
descending and negatives ascending, and adding them pairwise cancels large
magnitudes early, making the running partial sum monotone toward the final
result. If the final result fits the accumulator, a monotone order never
overflows transiently.

Shapes are static (JAX): the shrinking arrays of the paper's pseudo-code are
represented as fixed-length arrays padded with zeros. Zeros are sign-neutral
and additively inert, so the fixed-shape formulation is exact.

Three levels of fidelity:
- ``alg1_sorted_dot``      — the paper's multi-round Algorithm 1 (oracle).
- ``pairwise_round``       — one split/sort/pair round (the practical variant:
                             one round resolves ~99.8 % of transients).
- ``tiled_pairwise_order`` — per-K-tile single-round sorting (paper §6), the
                             form our Pallas kernels implement on TPU.

All functions operate on the *partial products* array (int32 carrier) along
the last axis and vmap cleanly over leading batch dims.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import qrange

_NEG_INF = jnp.iinfo(jnp.int32).min
_POS_INF = jnp.iinfo(jnp.int32).max


def pairwise_round(prods: jax.Array) -> jax.Array:
    """One round of split / sort / pairwise-add (Alg. 1 body), fixed shape.

    Returns an array of the same length whose nonzero prefix holds the new
    partial products:
      out[i] = pos_sorted[i] + neg_sorted[i]
    where pos_sorted is positives descending (0-padded past the count) and
    neg_sorted is negatives ascending (0-padded). For i < min(#pos, #neg)
    this is the paper's pairwise sum; past that, exactly one side is nonzero
    (the unpaired leftovers); past max(#pos, #neg), both are zero.
    """
    # Positives descending: sentinel -inf sorts to the front ascending; flip
    # puts real positives first, sentinels last. (Never negate the sentinel:
    # -INT32_MIN wraps in two's complement.)
    pos = jnp.where(prods > 0, prods, _NEG_INF)
    pos = jnp.flip(jnp.sort(pos, axis=-1), axis=-1)  # descending
    pos = jnp.where(pos == _NEG_INF, 0, pos)
    # Negatives ascending: sentinel +inf pushes non-negatives to the back.
    neg = jnp.where(prods < 0, prods, _POS_INF)
    neg = jnp.sort(neg, axis=-1)  # ascending
    neg = jnp.where(neg == _POS_INF, 0, neg)
    return pos + neg


def alg1_sorted_dot(prods: jax.Array, max_rounds: int | None = None) -> jax.Array:
    """Full multi-round Algorithm 1. Returns the exact dot product value.

    Rounds repeat until one sign is exhausted (m == 0 in the paper), at which
    point the remaining same-sign values are summed (monotone by
    construction). Each round at least halves the number of mixed-sign
    values, so ceil(log2(K)) + 1 rounds always suffice; we run a fori_loop
    over that static bound with an early "both signs present?" predicate
    (rounds after exhaustion are no-ops: pairwise_round of a same-sign array
    re-sorts it and adds zeros).
    """
    k = prods.shape[-1]
    if max_rounds is None:
        max_rounds = max(k.bit_length(), 1)  # ceil(log2(k)) + 1 for k > 1

    def body(_, p):
        both = jnp.logical_and(jnp.any(p > 0), jnp.any(p < 0))
        return jnp.where(both, pairwise_round(p), p)

    out = jax.lax.fori_loop(0, max_rounds, body, prods)
    return jnp.sum(out, axis=-1)


def sorted_order(prods: jax.Array, rounds: int = 2) -> jax.Array:
    """Accumulation-ready array after ``rounds`` sorting rounds (practical PQS).

    The result is accumulated sequentially left-to-right in *pair order*:
    position i holds pos_sorted[i] + neg_sorted[i] of the last round, so the
    best-cancelling (largest-magnitude) pairs come first and the running sum
    hugs zero while values are large. Empirically (see tests and the Fig-2
    benchmark) pair order beats magnitude-ascending re-sorting, and two
    rounds resolve ~99 % of transient overflows in the regimes the paper
    studies; each extra round pairs the residuals of the previous one,
    converging to the paper's full Algorithm 1.
    """
    out = prods
    for _ in range(rounds):
        out = pairwise_round(out)
    return out


def sorted_single_round_order(prods: jax.Array) -> jax.Array:
    """One-round variant (the paper's 'single sorting round' claim)."""
    return sorted_order(prods, rounds=1)


@partial(jax.jit, static_argnames=("acc_bits", "saturate"))
def monotone_accumulate(
    vals: jax.Array, acc_bits: int, saturate: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Sequentially accumulate ``vals`` (last axis) into a p-bit accumulator.

    Returns (result, overflowed) where ``overflowed`` flags whether any
    intermediate partial sum left the representable range. With
    saturate=True the carry is clipped at every step (MCU saturation
    arithmetic); with False the carry wraps at p bits (two's complement).
    """
    qmin, qmax = qrange(acc_bits)
    # int32 carrier is exact as long as 2b-bit products summed K times stay
    # below 2^31: for b = 8 that allows K <= 2^17, far beyond the paper's
    # dot-product lengths, and acc_bits <= 30 covers the 12-24 bit sweep.
    if acc_bits > 30:
        raise ValueError("acc_bits > 30 would overflow the int32 carrier")

    def step(carry, x):
        acc, ovf = carry
        nxt = acc + x.astype(jnp.int32)
        hit = jnp.logical_or(nxt > qmax, nxt < qmin)
        if saturate:
            nxt = jnp.clip(nxt, qmin, qmax)
        else:
            span = jnp.int32(2**acc_bits)
            nxt = jnp.mod(nxt - qmin, span) + qmin
        return (nxt, jnp.logical_or(ovf, hit)), None

    moved = jnp.moveaxis(vals, -1, 0)
    init = (
        jnp.zeros(moved.shape[1:], jnp.int32),
        jnp.zeros(moved.shape[1:], bool),
    )
    (acc, ovf), _ = jax.lax.scan(step, init, moved)
    return acc, ovf


def combine_schedule(k_shards: int) -> list[tuple[tuple[int, int], ...]]:
    """Static butterfly exchange schedule of the K-shard combine tree.

    Level ``l`` pairs member ``i`` with partner ``i XOR 2**l``; the
    return value is a list of ``log2(k_shards)`` levels, each a tuple of
    ``(source, destination)`` permutation pairs in the exact form
    ``jax.lax.ppermute`` takes. Executing the schedule — every member
    merging its register with the exchanged partner value through
    ``combine_step`` — leaves every member holding the root of the SAME
    balanced combine tree ``tree_combine`` computes locally: level ``l``
    merges adjacent index blocks of size ``2**l``.

    The schedule is value-independent by construction: interconnect
    routing cannot depend on data, so the tree pairs adjacent *shard
    indices* (a per-output-element magnitude ranking would need a
    different route per (m, n) element, which no static collective can
    express). This is THE pairing rule of the K-sharded combine — the
    jnp oracle, the single-device hierarchy, and the mesh exchange all
    realize this one schedule, which keeps the three bit-identical.
    """
    if k_shards < 1 or k_shards & (k_shards - 1):
        raise ValueError(
            f"combine_schedule needs a power-of-two shard count, got "
            f"{k_shards}"
        )
    return [
        tuple((i, i ^ (1 << level)) for i in range(k_shards))
        for level in range((k_shards - 1).bit_length())
    ]


def combine_step(
    a: jax.Array, b: jax.Array, acc_bits: int, policy: str = "clip"
) -> tuple[jax.Array, jax.Array]:
    """Merge two partial-sum registers under the policy's register rule.

    One combine-tree step: saturating add for the saturating policies
    (``clip`` and every sorted variant), two's-complement wraparound at
    ``acc_bits`` for ``wrap``, exact add for ``wide``. Commutative for
    every policy (the rules post-process the exact pairwise sum), so the
    two partners of a pairwise exchange compute identical registers.

    Returns ``(merged, hit)``. For the narrow policies ``hit`` flags the
    *exact* pairwise sum leaving the acc_bits range (``wrap`` wraps and
    still counts). For ``wide`` the register is the int32 carrier itself,
    so ``hit`` instead flags a silent carrier wrap — same-sign operands
    whose two's-complement sum flipped sign — which is zero in every
    valid regime (int8 products, K <= 2**17; see
    ``monotone_accumulate``) and nonzero exactly when adversarial
    near-2**31 partials overflowed the "exact" add.
    """
    if acc_bits > 30:
        raise ValueError("acc_bits > 30 would overflow the int32 carrier")
    qmin, qmax = qrange(acc_bits)
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    exact = a + b
    if policy == "wide":
        same_sign = (a >= 0) == (b >= 0)
        wrapped = jnp.logical_and(same_sign, (exact >= 0) != (a >= 0))
        return exact, wrapped
    hit = jnp.logical_or(exact > qmax, exact < qmin)
    if policy == "wrap":
        span = jnp.int32(2**acc_bits)
        merged = jnp.mod(exact - qmin, span) + qmin
    else:
        merged = jnp.clip(exact, qmin, qmax)
    return merged, hit


def tree_combine(
    partials: jax.Array, acc_bits: int, policy: str = "clip"
) -> tuple[jax.Array, jax.Array]:
    """Merge per-K-shard partial sums up the static combine tree.

    ``partials`` is (..., S): element s is the policy-accumulated partial
    of K shard s. Level ``l`` merges adjacent index pairs of the
    surviving registers through ``combine_step`` — exactly the per-member
    result of executing ``combine_schedule(S)`` with pairwise exchanges,
    so the local walk here and the mesh ``ppermute`` butterfly are the
    same tree by construction (A2Q-style per-partial-sum reasoning: each
    merge is individually safe iff its own pairwise sum fits the
    register, so the schedule is semantics, not an implementation
    detail).

    Returns ``(value, n_overflow_steps)``: the combined (...,) int32
    results and a per-dot int32 count of combine steps whose *exact*
    pairwise sum left the acc_bits range (``wrap`` wraps and still
    counts). For ``wide`` the count flags int32 *carrier* wraps instead
    (see ``combine_step``): zero in every valid regime, and the guard —
    sibling of ``monotone_accumulate``'s static ``acc_bits`` check —
    that a combine of S near-2**31 same-sign partials can no longer wrap
    silently. S is padded up to a power of two with zeros, which are
    additively inert in every rule, so any shard count is exact.

    This is THE cross-shard rule of the K-sharded ``pqs_dot`` path: the
    jnp oracle (``overflow.kshard_accumulate``), the single-device
    ``k_shards=`` hierarchy, and the mesh execution
    (``pqs_dot(..., k_axis=...)``) all realize it, so the combine has a
    single definition and the three are bit-identical.
    """
    if acc_bits > 30:
        raise ValueError("acc_bits > 30 would overflow the int32 carrier")
    s = partials.shape[-1]
    sp = 1 if s <= 1 else 1 << (s - 1).bit_length()
    vals = partials.astype(jnp.int32)
    if sp != s:
        widths = [(0, 0)] * (vals.ndim - 1) + [(0, sp - s)]
        vals = jnp.pad(vals, widths)
    novf = jnp.zeros(vals.shape[:-1], jnp.int32)
    while vals.shape[-1] > 1:
        vals, hit = combine_step(
            vals[..., 0::2], vals[..., 1::2], acc_bits, policy
        )
        novf = novf + jnp.sum(hit, axis=-1).astype(jnp.int32)
    return vals[..., 0], novf


def pair_permutation(sums: jax.Array) -> jax.Array:
    """Rank-and-interleave tile pairing from per-tile net sums.

    ``sums`` is (..., n_tiles); the result is a permutation of tile
    indices placing positives-descending ranks into even slots and
    ascending (most negative first) ranks into odd slots —
    ``pairwise_round`` at tile granularity. desc[:half] and
    asc[:n_tiles - half] partition the ranks, so every tile appears
    exactly once.

    This is THE pairing rule of the ``sorted_tiled`` policy: the jnp
    oracle (``tiled_sorted_order``) and both Pallas kernels (one-pass
    ``sort_matmul`` and the two-pass ``kernels.sorted_stream`` pipeline)
    all call it, so the permutation has a single definition. Ties break
    like ``jnp.argsort`` (stable): equal sums order by tile index
    ascending in ``asc`` and by flipped position in ``desc``.
    """
    n_tiles = sums.shape[-1]
    desc = jnp.flip(jnp.argsort(sums, axis=-1), axis=-1)
    asc = jnp.argsort(sums, axis=-1)
    half = (n_tiles + 1) // 2
    perm = jnp.zeros(desc.shape, desc.dtype)
    perm = perm.at[..., 0::2].set(desc[..., :half])
    perm = perm.at[..., 1::2].set(asc[..., : n_tiles - half])
    return perm


def tiled_sorted_order(
    prods: jax.Array, k_tile: int, rounds: int = 2, order_fn=None
) -> jax.Array:
    """Paper §6 tiled variant, TPU-adapted: two-level sorted accumulation.

    Level 1 (intra-tile): the K axis is tiled into VMEM-sized blocks and
    each tile gets ``rounds`` of split/sort/pair — what the Pallas kernel
    does with its resident block.

    Level 2 (inter-tile): tiles are *paired* by net sum — largest
    positive-sum tile with most negative-sum tile, and so on — and each
    pair's elements are interleaved (a0, b0, a1, b1, …), so the running
    total cancels continuously through the pair instead of drifting to a
    tile's full net sum before the opposite tile arrives. A Pallas kernel
    realizes this by accumulating two VMEM-resident tiles jointly; tile
    sums are just K/k_tile scalars, so the pairing itself is cheap.

    K must be divisible by k_tile (callers pad with zeros; zeros are inert).

    ``order_fn(tiles, rounds)`` is the intra-tile sort implementation —
    defaults to the jnp ``sorted_order``; the Pallas kernels pass the
    bitonic network variant (bit-identical output, hardware-friendly ops)
    so the pairing permutation below stays one shared code path.
    """
    k = prods.shape[-1]
    if k % k_tile != 0:
        raise ValueError(f"K={k} not divisible by k_tile={k_tile}")
    n_tiles = k // k_tile
    tiles = prods.reshape(*prods.shape[:-1], n_tiles, k_tile)
    ordered = (order_fn or sorted_order)(tiles, rounds)
    if n_tiles == 1:
        return ordered.reshape(prods.shape)
    # Tile pairing: the shared rank-and-interleave rule over tile sums
    # (sorting a tile never changes its sum, so the permutation is
    # identical whether computed from raw or intra-tile-sorted products —
    # the property the two-pass kernel's pass 1 relies on).
    sums = jnp.sum(ordered, axis=-1)  # (..., n_tiles)
    perm = pair_permutation(sums)
    ordered = jnp.take_along_axis(ordered, perm[..., None], axis=-2)
    # Element-interleave each adjacent tile pair; odd leftover tile appended.
    n_pairs = n_tiles // 2
    lead = ordered.shape[:-2]
    main = ordered[..., : 2 * n_pairs, :].reshape(*lead, n_pairs, 2, k_tile)
    main = jnp.swapaxes(main, -1, -2).reshape(*lead, n_pairs * 2 * k_tile)
    if n_tiles % 2:
        tail = ordered[..., -1, :]
        return jnp.concatenate([main, tail], axis=-1)
    return main.reshape(prods.shape)


def tiled_pairwise_order(prods: jax.Array, k_tile: int) -> jax.Array:
    """Back-compat alias for the two-level tiled order (rounds=2)."""
    return tiled_sorted_order(prods, k_tile, rounds=2)


def tiled_seq_order(
    prods: jax.Array, k_tile: int, rounds: int = 1
) -> jax.Array:
    """Paper §6 tiled sorting exactly as a blocked kernel sees it: each
    K-tile is sorted/paired independently and tiles are accumulated in
    their natural order (no inter-tile pairing). This is the semantics of
    ``kernels/sorted_matmul.py``; ``tiled_sorted_order`` (with its
    sum-ranked tile interleave) is this repo's beyond-paper refinement.
    """
    k = prods.shape[-1]
    if k % k_tile != 0:
        raise ValueError(f"K={k} not divisible by k_tile={k_tile}")
    tiles = prods.reshape(*prods.shape[:-1], k // k_tile, k_tile)
    return sorted_order(tiles, rounds).reshape(prods.shape)
