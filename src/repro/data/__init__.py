from repro.data.pipeline import (  # noqa: F401
    ClassificationDataset,
    TokenStream,
    make_classification,
    synth_mnist,
)
