"""Input pipeline: deterministic synthetic datasets + sharded batching.

The container is offline (no MNIST/CIFAR/corpora), so the pipeline serves
deterministic synthetic data through the *same* interfaces a real loader
would use — the framework code paths (sharded host feeding, prefetch,
epoch shuffling, checkpointable iterator state) are all real.

- ``synth_mnist``      : 10-class Gaussian-mixture images in 784-d — the
                         stand-in for the paper's MNIST experiments
                         (Fig 2/3). Class structure is learnable but not
                         trivially separable (configurable noise).
- ``make_classification``: harder K-class mixture for CIFAR-scale trends.
- ``TokenStream``      : LM token stream with Zipf unigram statistics and
                         an order-k Markov flavor so perplexity is
                         reducible; yields (tokens, labels) next-token
                         pairs, shardable per host.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class ClassificationDataset:
    x: np.ndarray  # (N, D) f32
    y: np.ndarray  # (N,) i32
    num_classes: int

    def split(
        self, frac: float = 0.9
    ) -> tuple["ClassificationDataset", "ClassificationDataset"]:
        n = int(len(self.x) * frac)
        return (
            ClassificationDataset(self.x[:n], self.y[:n], self.num_classes),
            ClassificationDataset(self.x[n:], self.y[n:], self.num_classes),
        )

    def batches(
        self, batch_size: int, seed: int = 0, epochs: int = 1,
        drop_remainder: bool = True,
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(seed)
        n = len(self.x)
        for _ in range(epochs):
            order = rng.permutation(n)
            stop = n - n % batch_size if drop_remainder else n
            for i in range(0, stop, batch_size):
                idx = order[i : i + batch_size]
                yield self.x[idx], self.y[idx]


def make_classification(
    n: int,
    dim: int,
    num_classes: int,
    seed: int = 0,
    noise: float = 1.0,
    subspace: Optional[int] = None,
) -> ClassificationDataset:
    """K-Gaussian-mixture classification with class means on a low-dim
    subspace (makes low-rank weight approximations meaningful, Fig 3)."""
    rng = np.random.default_rng(seed)
    sub = subspace or min(dim, 64)
    basis = rng.standard_normal((sub, dim)).astype(np.float32)
    basis /= np.linalg.norm(basis, axis=1, keepdims=True)
    means = rng.standard_normal((num_classes, sub)).astype(np.float32) * 3.0
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    x = means[y] @ basis + noise * rng.standard_normal((n, dim)).astype(
        np.float32
    )
    # normalize to [0, 1]-ish like pixel data, keeps ReLU stats realistic
    x = (x - x.min()) / (x.max() - x.min())
    return ClassificationDataset(x.astype(np.float32), y, num_classes)


def synth_mnist(n: int = 12_000, seed: int = 0) -> ClassificationDataset:
    """784-d, 10-class stand-in for MNIST (paper Fig 2/3 substrate)."""
    return make_classification(n, 784, 10, seed=seed, noise=1.2, subspace=32)


@dataclasses.dataclass
class TokenStream:
    """Deterministic LM token stream with checkpointable position.

    Zipf unigram base with order-1 Markov structure: p(t | prev) mixes a
    per-prev permutation of the Zipf table, so cross-entropy is reducible
    below the unigram entropy — enough signal for the ~100M-param example
    run to show a falling loss curve.
    """

    vocab_size: int
    seq_len: int
    batch_size: int  # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0  # checkpointable iterator state

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.num_hosts + self.host_id
        )

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = self._rng_for(self.step)
        self.step += 1
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        # Zipf ranks with Markov mixing
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        base = np.minimum(ranks, v) - 1
        shift = np.arange(b)[:, None] * 7 + np.roll(base, 1, axis=1) * 31
        toks = ((base + shift) % v).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "host_id": self.host_id}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
