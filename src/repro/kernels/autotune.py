"""Measure-and-cache block-shape autotuning for ``kernels.ops``.

The static ``_BLOCK_TABLE`` in ``ops.py`` encodes one reasonable (bm, bn)
per (platform, policy); real layer shapes reward different blockings
(long-K decode projections vs wide prefill batches), so ``policy_matmul``
can instead *measure*: on the first call per (policy, platform,
pow2-bucketed padded M/N/K), time a small per-policy candidate set of
(bm, bn, bk) and persist the winner to an on-disk JSON cache. Later
calls — including in other processes — reuse the winner.

Env control (``REPRO_PQS_AUTOTUNE``):

  off       (default) never measure, never read the cache — the static
            table (and the ``REPRO_PQS_BLOCKS`` override) rules.
  tune      measure cache misses IN A BACKGROUND THREAD and persist
            winners to the cache file. The triggering call (and every
            call until the measurement lands) is served by the static
            table immediately — tune mode never pays candidate
            compile+timing latency inline on a serving path. ``drain()``
            blocks until in-flight measurements land (offline tuning
            scripts call it before exiting).
  readonly  use cached winners, fall back to the static table on a miss;
            never measure (the serving-fleet mode: tune once offline,
            ship the cache file read-only).

Cache file: ``REPRO_PQS_AUTOTUNE_CACHE`` or
``~/.cache/repro-pqs/autotune-<platform>.json``. Schema:
``{"version": 1, "entries": {"<policy>|<platform>|MxNxK": {"bm", "bn",
"bk", "us"}}}`` — ``bk`` is null for policies whose K depth is semantic
(``sorted_tiled_seq``, where bk IS the paper's k_tile) or slab-resident
(the global-sort policies). The compressed-storage families (``nm:``
expand, ``nmg:`` gather) key their shape part on the COMPRESSED
geometry instead of dense K: ``MxNxgGmMGkNK`` (bucketed group count G,
literal m_group and n_keep), because their grids and VMEM footprints
are sized by (G, n_keep) — two layers with equal dense K but different
sparsity do not share a winner. Migration: entries for nm families
written under the old dense-K key shape are silently invalid; ``_read``
drops them (with a one-time warning) so they re-tune under the new key
and vanish from disk on the next persist.

Tuning is skipped (readonly behavior) under a jit trace — timing a
tracer is meaningless — and measured times are wall-clock with
``block_until_ready``, median of ``REPS`` runs after one warmup, so the
numbers are honest on TPU and merely self-consistent in interpret mode.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
import warnings
from typing import Callable, Optional

import jax

MODES = ("off", "tune", "readonly")
REPS = 3

# Per-policy candidate (bm, bn, bk) sets. bk=None means "not tunable for
# this policy" (k_tile-bound or slab-resident); keep the sets small —
# tune mode compiles and times every candidate on first use per bucket.
CANDIDATES: dict[str, tuple[tuple[int, int, Optional[int]], ...]] = {
    "wide": ((128, 128, 512), (64, 128, 512), (128, 256, 512),
             (128, 128, 1024)),
    "clip": ((8, 128, 256), (16, 128, 256), (8, 128, 512), (8, 256, 256)),
    "wrap": ((8, 128, 256), (16, 128, 256), (8, 128, 512), (8, 256, 256)),
    "sorted": ((8, 128, None), (4, 128, None), (8, 256, None)),
    "sorted_tiled": ((8, 128, None), (4, 128, None), (8, 256, None)),
    "sorted_tiled_seq": ((8, 128, None), (16, 128, None), (8, 256, None)),
    # nm: compressed-storage family — the bk slot is the GROUP depth bg
    # (k-depth per step = bg * m_group); tiled-seq/global-sort entries
    # keep it None (k_tile-bound or slab-resident, not tunable)
    "nm:wide": ((128, 128, 32), (64, 128, 32), (128, 128, 64)),
    "nm:clip": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nm:wrap": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nm:sorted": ((8, 128, None), (4, 128, None)),
    "nm:sorted_tiled": ((8, 128, None), (4, 128, None)),
    "nm:sorted_tiled_seq": ((8, 128, None), (16, 128, None)),
    # nmg: fused-gather family — products per step shrink to bg*n_keep,
    # so deeper group blocks amortize the gather's index arithmetic
    "nmg:wide": ((128, 128, 32), (64, 128, 32), (128, 128, 64)),
    "nmg:clip": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nmg:wrap": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nmg:sorted": ((8, 128, None), (4, 128, None)),
    "nmg:sorted_tiled": ((8, 128, None), (4, 128, None)),
    "nmg:sorted_tiled_seq": ((8, 128, None), (16, 128, None)),
}

# kernel families whose autotune keys carry compressed geometry
_NM_FAMILY_PREFIXES = ("nm:", "nmg:")

_MEMO: dict[str, Optional[dict]] = {}  # key -> winning entry (in-process)
_DISK: dict[str, dict] = {}  # path -> loaded entries
_PENDING: dict[str, threading.Thread] = {}  # key -> in-flight measurement
_LOCK = threading.RLock()  # guards the three dicts above
_IO_LOCK = threading.Lock()  # serializes cache-file read-merge-write


def mode() -> str:
    m = os.environ.get("REPRO_PQS_AUTOTUNE", "off").strip().lower()
    if m not in MODES:
        raise ValueError(
            f"REPRO_PQS_AUTOTUNE must be one of {MODES}, got {m!r}")
    return m


def cache_path(platform: Optional[str] = None) -> str:
    env = os.environ.get("REPRO_PQS_AUTOTUNE_CACHE")
    if env:
        return env
    platform = platform or jax.default_backend()
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-pqs",
                        f"autotune-{platform}.json")


def reset() -> None:
    """Drop in-process memoization (tests; cache files are untouched).

    Joins any in-flight background measurement first, so a straggler
    thread from before the reset cannot repopulate the fresh state (or
    write into a cache path a test has since redirected). The join is
    BOUNDED: a wedged candidate run must not hang reset (and every
    test-fixture teardown) forever — a straggler past the timeout is a
    daemon thread and dies with the process; at worst it repopulates a
    memo entry, which the next reset drops again."""
    drain(timeout=60.0)
    with _LOCK:
        _MEMO.clear()
        _DISK.clear()


def drain(timeout: Optional[float] = None) -> None:
    """Block until every background measurement has landed (tune mode).

    Offline tuning runs (benchmarks, warmup scripts) call this before
    reading the cache or exiting; with ``timeout`` (seconds, per joined
    thread) the wait is bounded and stragglers are simply left running.
    """
    while True:
        with _LOCK:
            threads = [t for t in _PENDING.values() if t.is_alive()]
        if not threads:
            return
        for t in threads:
            t.join(timeout)
            if timeout is not None and t.is_alive():
                return


def _bucket(v: int) -> int:
    return 1 if v <= 1 else 1 << (v - 1).bit_length()


def shape_key(policy: str, platform: str, m: int, n: int, kp: int,
              nm: Optional[tuple[int, int, int]] = None) -> str:
    """Cache key for one (policy, platform, shape-bucket).

    Dense families bucket on (M, N, padded K). The compressed families
    MUST pass ``nm=(m_group, n_keep, G)``: their grids are sized by the
    group count and slab width, so the key carries ``gGmMGkNK``
    (bucketed G, literal m_group/n_keep) in place of the dense-K slot —
    equal dense K with different sparsity must not share a winner.
    """
    if nm is not None:
        m_group, n_keep, g = nm
        return (f"{policy}|{platform}|{_bucket(m)}x{_bucket(n)}x"
                f"g{_bucket(g)}m{m_group}k{n_keep}")
    return (f"{policy}|{platform}|"
            f"{_bucket(m)}x{_bucket(n)}x{_bucket(kp)}")


_WARNED_STALE = False


def _is_stale(key: str) -> bool:
    """True for nm-family entries written under the pre-gather dense-K
    key shape (no ``xg`` marker) — their blocks were tuned against a
    grid the kernel no longer launches."""
    if not key.startswith(_NM_FAMILY_PREFIXES):
        return False
    return "xg" not in key.rsplit("|", 1)[-1]


def _read(path: str) -> dict:
    global _WARNED_STALE
    try:
        with open(path) as f:
            entries = json.load(f).get("entries", {})
    except (OSError, ValueError):
        return {}
    stale = [k for k in entries if _is_stale(k)]
    if stale:
        for k in stale:
            del entries[k]
        if not _WARNED_STALE:
            _WARNED_STALE = True
            warnings.warn(
                f"autotune cache {path}: dropped {len(stale)} stale "
                "nm-family entr(ies) keyed on dense K; compressed "
                "kernels now key on (m_group, n_keep, G) and will "
                "re-tune (the stale keys disappear from disk on the "
                "next persist)",
                stacklevel=3,
            )
    return entries


def _load(path: str) -> dict:
    if path not in _DISK:
        _DISK[path] = _read(path)
    return _DISK[path]


def _persist(path: str, entries: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1,
                      sort_keys=True)
        os.replace(tmp, path)  # atomic on POSIX
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def measure_us(run: Callable[[], jax.Array], reps: int | None = None
               ) -> float:
    """Median wall-clock microseconds over ``reps`` runs (default REPS),
    after one untimed warmup (compile + cache warm). The one timing
    protocol — the tuner and benchmarks/kernel_bench.py both use it."""
    reps = REPS if reps is None else reps
    jax.block_until_ready(run())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def best_blocks(
    policy: str,
    m: int,
    n: int,
    kp: int,
    *,
    platform: Optional[str] = None,
    runner: Optional[Callable[[int, int, Optional[int]], jax.Array]] = None,
    tracing: bool = False,
    nm: Optional[tuple[int, int, int]] = None,
) -> Optional[tuple[int, int, Optional[int]]]:
    """(bm, bn, bk) for this shape bucket, or None (caller falls back).

    ``runner(bm, bn, bk)`` executes the real matmul once with those
    blocks (``ops.policy_matmul`` passes a closure over its actual
    operands, so the measurement includes its padding). Only consulted
    in tune mode; readonly mode (and tune mode under a jit trace, when
    ``tracing``) answers purely from the cache. Compressed-family
    callers pass ``nm=(m_group, n_keep, G)`` so the key reflects the
    launched grid (see ``shape_key``).

    Tune-mode misses never measure inline: the measurement is scheduled
    on a background thread and THIS call answers None immediately (the
    caller's static table serves it), so a serving path that first
    touches a cold bucket keeps its first-call latency. Calls after the
    measurement lands get the winner.
    """
    md = mode()
    if md == "off":
        return None
    platform = platform or jax.default_backend()
    key = shape_key(policy, platform, m, n, kp, nm=nm)
    with _LOCK:
        if key in _MEMO:
            e = _MEMO[key]
            return (e["bm"], e["bn"], e["bk"]) if e else None
        path = cache_path(platform)
        e = _load(path).get(key)
        if e is not None:
            _MEMO[key] = e
            return (e["bm"], e["bn"], e["bk"])
        if (md == "tune" and runner is not None and not tracing
                and key not in _PENDING):
            _spawn(policy, key, path, runner)
    # a miss due to readonly mode, an in-trace call, or an in-flight
    # background measurement is NOT memoized: a later call must still
    # see the measurement once it lands
    return None


def _spawn(policy: str, key: str, path: str, runner) -> None:
    """Measure ``key``'s candidates on a daemon thread and persist the
    winner; ``_PENDING`` dedupes so a bucket is measured once. Callers
    hold ``_LOCK``."""

    def work():
        try:
            e = _measure(policy, key, runner)
        except Exception:  # never let a tuner failure leak anywhere
            e = None
        entries = None
        if e is not None:
            # merge into a FRESH read so concurrent tuners sharing the
            # file don't clobber each other's buckets. The disk I/O
            # happens OUTSIDE _LOCK — holding it there would stall every
            # serving-path best_blocks lookup on file I/O, the exact
            # inline latency this thread exists to avoid — but UNDER the
            # dedicated _IO_LOCK: two background threads interleaving
            # read-merge-write would each replace the file with only its
            # own key merged, dropping the other's winner from disk.
            with _IO_LOCK:
                entries = _read(path)
                entries[key] = e
                _persist(path, entries)
        with _LOCK:
            if entries is not None:
                _DISK[path] = entries  # swap in the merged view
            _MEMO[key] = e  # a completed measurement (even a failed one,
            # e=None when every candidate errored) is this process's answer
            _PENDING.pop(key, None)

    t = threading.Thread(
        target=work, name=f"pqs-autotune:{key}", daemon=True
    )
    _PENDING[key] = t
    t.start()


def _measure(policy: str, key: str, runner) -> Optional[dict]:
    best = None
    for bm, bn, bk in CANDIDATES.get(policy, ()):
        try:
            us = measure_us(lambda: runner(bm, bn, bk))
        except Exception:  # candidate failed to lower/fit — skip it
            continue
        if best is None or us < best["us"]:
            best = {"bm": bm, "bn": bn, "bk": bk, "us": round(us, 1)}
    return best
