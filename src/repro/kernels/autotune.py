"""Measure-and-cache block-shape autotuning for ``kernels.ops``.

The static ``_BLOCK_TABLE`` in ``ops.py`` encodes one reasonable (bm, bn)
per (platform, policy); real layer shapes reward different blockings
(long-K decode projections vs wide prefill batches), so ``policy_matmul``
can instead *measure*: on the first call per (policy, platform,
pow2-bucketed padded M/N/K), time a small per-policy candidate set of
(bm, bn, bk) and persist the winner to an on-disk JSON cache. Later
calls — including in other processes — reuse the winner.

Env control (``REPRO_PQS_AUTOTUNE``):

  off       (default) never measure, never read the cache — the static
            table (and the ``REPRO_PQS_BLOCKS`` override) rules.
  tune      measure cache misses, persist winners to the cache file.
  readonly  use cached winners, fall back to the static table on a miss;
            never measure (the serving-fleet mode: tune once offline,
            ship the cache file read-only).

Cache file: ``REPRO_PQS_AUTOTUNE_CACHE`` or
``~/.cache/repro-pqs/autotune-<platform>.json``. Schema:
``{"version": 1, "entries": {"<policy>|<platform>|MxNxK": {"bm", "bn",
"bk", "us"}}}`` — ``bk`` is null for policies whose K depth is semantic
(``sorted_tiled_seq``, where bk IS the paper's k_tile) or slab-resident
(the global-sort policies).

Tuning is skipped (readonly behavior) under a jit trace — timing a
tracer is meaningless — and measured times are wall-clock with
``block_until_ready``, median of ``REPS`` runs after one warmup, so the
numbers are honest on TPU and merely self-consistent in interpret mode.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Optional

import jax

MODES = ("off", "tune", "readonly")
REPS = 3

# Per-policy candidate (bm, bn, bk) sets. bk=None means "not tunable for
# this policy" (k_tile-bound or slab-resident); keep the sets small —
# tune mode compiles and times every candidate on first use per bucket.
CANDIDATES: dict[str, tuple[tuple[int, int, Optional[int]], ...]] = {
    "wide": ((128, 128, 512), (64, 128, 512), (128, 256, 512),
             (128, 128, 1024)),
    "clip": ((8, 128, 256), (16, 128, 256), (8, 128, 512), (8, 256, 256)),
    "wrap": ((8, 128, 256), (16, 128, 256), (8, 128, 512), (8, 256, 256)),
    "sorted": ((8, 128, None), (4, 128, None), (8, 256, None)),
    "sorted_tiled": ((8, 128, None), (4, 128, None), (8, 256, None)),
    "sorted_tiled_seq": ((8, 128, None), (16, 128, None), (8, 256, None)),
    # nm: compressed-storage family — the bk slot is the GROUP depth bg
    # (k-depth per step = bg * m_group); tiled-seq/global-sort entries
    # keep it None (k_tile-bound or slab-resident, not tunable)
    "nm:wide": ((128, 128, 32), (64, 128, 32), (128, 128, 64)),
    "nm:clip": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nm:wrap": ((8, 128, 16), (16, 128, 16), (8, 128, 32)),
    "nm:sorted": ((8, 128, None), (4, 128, None)),
    "nm:sorted_tiled": ((8, 128, None), (4, 128, None)),
    "nm:sorted_tiled_seq": ((8, 128, None), (16, 128, None)),
}

_MEMO: dict[str, Optional[dict]] = {}  # key -> winning entry (in-process)
_DISK: dict[str, dict] = {}  # path -> loaded entries


def mode() -> str:
    m = os.environ.get("REPRO_PQS_AUTOTUNE", "off").strip().lower()
    if m not in MODES:
        raise ValueError(
            f"REPRO_PQS_AUTOTUNE must be one of {MODES}, got {m!r}")
    return m


def cache_path(platform: Optional[str] = None) -> str:
    env = os.environ.get("REPRO_PQS_AUTOTUNE_CACHE")
    if env:
        return env
    platform = platform or jax.default_backend()
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-pqs",
                        f"autotune-{platform}.json")


def reset() -> None:
    """Drop in-process memoization (tests; cache files are untouched)."""
    _MEMO.clear()
    _DISK.clear()


def _bucket(v: int) -> int:
    return 1 if v <= 1 else 1 << (v - 1).bit_length()


def shape_key(policy: str, platform: str, m: int, n: int, kp: int) -> str:
    return (f"{policy}|{platform}|"
            f"{_bucket(m)}x{_bucket(n)}x{_bucket(kp)}")


def _read(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f).get("entries", {})
    except (OSError, ValueError):
        return {}


def _load(path: str) -> dict:
    if path not in _DISK:
        _DISK[path] = _read(path)
    return _DISK[path]


def _persist(path: str, entries: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1,
                      sort_keys=True)
        os.replace(tmp, path)  # atomic on POSIX
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def measure_us(run: Callable[[], jax.Array], reps: int | None = None
               ) -> float:
    """Median wall-clock microseconds over ``reps`` runs (default REPS),
    after one untimed warmup (compile + cache warm). The one timing
    protocol — the tuner and benchmarks/kernel_bench.py both use it."""
    reps = REPS if reps is None else reps
    jax.block_until_ready(run())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def best_blocks(
    policy: str,
    m: int,
    n: int,
    kp: int,
    *,
    platform: Optional[str] = None,
    runner: Optional[Callable[[int, int, Optional[int]], jax.Array]] = None,
    tracing: bool = False,
) -> Optional[tuple[int, int, Optional[int]]]:
    """(bm, bn, bk) for this shape bucket, or None (caller falls back).

    ``runner(bm, bn, bk)`` executes the real matmul once with those
    blocks (``ops.policy_matmul`` passes a closure over its actual
    operands, so the measurement includes its padding). Only consulted
    in tune mode; readonly mode (and tune mode under a jit trace, when
    ``tracing``) answers purely from the cache.
    """
    md = mode()
    if md == "off":
        return None
    platform = platform or jax.default_backend()
    key = shape_key(policy, platform, m, n, kp)
    if key in _MEMO:
        e = _MEMO[key]
        return (e["bm"], e["bn"], e["bk"]) if e else None
    path = cache_path(platform)
    e = _load(path).get(key)
    if e is None and md == "tune" and runner is not None and not tracing:
        e = _measure(policy, key, runner)
        if e is not None:
            # merge into a FRESH read so concurrent tuners sharing the
            # file don't clobber each other's buckets, then swap the
            # in-process view to the merged state
            entries = _read(path)
            entries[key] = e
            _persist(path, entries)
            _DISK[path] = entries
        _MEMO[key] = e  # a completed measurement (even a failed one,
        # e=None when every candidate errored) is this process's answer
    elif e is not None:
        _MEMO[key] = e
    # a miss due to readonly mode or an in-trace call is NOT memoized:
    # a later eager tune-mode call must still be able to measure
    return (e["bm"], e["bn"], e["bk"]) if e else None


def _measure(policy: str, key: str, runner) -> Optional[dict]:
    best = None
    for bm, bn, bk in CANDIDATES.get(policy, ()):
        try:
            us = measure_us(lambda: runner(bm, bn, bk))
        except Exception:  # candidate failed to lower/fit — skip it
            continue
        if best is None or us < best["us"]:
            best = {"bm": bm, "bn": bn, "bk": bk, "us": round(us, 1)}
    return best
