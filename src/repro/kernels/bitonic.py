"""Bitonic sorting network as vectorized compare-exchanges (TPU/VPU-native).

A sorting *network* (paper §6: "sorting networks such as the bitonic
algorithm are popular for sorting arrays in hardware") has no
data-dependent control flow, which makes it the natural TPU mapping for the
paper's sort stage: log2(n)*(log2(n)+1)/2 stages of elementwise
min/max over lane-aligned slices.

Every partner exchange at stride j is expressed as a reshape to
(..., n/(2j), 2, j) and a flip of the middle axis — no gathers, so the
same code runs inside a Pallas kernel body and in plain jnp (the ref
oracle). Direction masks are rebuilt from broadcasted_iota inside the
trace, since Pallas kernel bodies may not capture host constants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _stages(n: int) -> list[tuple[int, int]]:
    """Static (block k, stride j) schedule for a full bitonic sort of n."""
    out = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            out.append((k, j))
            j //= 2
        k *= 2
    return out


def _take_min_mask(n: int, k: int, j: int, ascending: bool) -> jnp.ndarray:
    """(1, n) traced mask: keep min at this lane? Built from iota inside the
    trace (Pallas kernels may not capture host constants)."""
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    partner = jnp.bitwise_xor(idx, j)
    up = (jnp.bitwise_and(idx, k) == 0)  # this k-block sorts ascending
    take_min = jnp.where(idx < partner, up, jnp.logical_not(up))
    if not ascending:
        take_min = jnp.logical_not(take_min)
    return take_min


def bitonic_sort(x: jnp.ndarray, ascending: bool = True) -> jnp.ndarray:
    """Sort the last axis (length must be a power of two)."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic length must be a power of 2, got {n}")
    lead = x.shape[:-1]
    mask_shape = (1,) * max(len(lead), 1) + (n,)
    for k, j in _stages(n):
        xr = x.reshape(*lead, n // (2 * j), 2, j)
        swapped = jnp.flip(xr, axis=-2).reshape(*lead, n)
        mn = jnp.minimum(x, swapped)
        mx = jnp.maximum(x, swapped)
        take_min = _take_min_mask(n, k, j, ascending).reshape(mask_shape)
        x = jnp.where(take_min, mn, mx)
    return x


_NEG_INF = jnp.iinfo(jnp.int32).min
_POS_INF = jnp.iinfo(jnp.int32).max


def pairwise_round_bitonic(prods: jnp.ndarray) -> jnp.ndarray:
    """One split/sort/pairwise-add round (paper Alg. 1 body) built on the
    sorting network — semantically identical to
    ``core.sorted_accum.pairwise_round`` (tested bit-exact) but expressed
    entirely in reshape/min/max/where, so it runs inside Pallas kernels.
    """
    pos = jnp.where(prods > 0, prods, _NEG_INF)
    pos = bitonic_sort(pos, ascending=False)  # positives first, descending
    pos = jnp.where(pos == _NEG_INF, 0, pos)
    neg = jnp.where(prods < 0, prods, _POS_INF)
    neg = bitonic_sort(neg, ascending=True)  # most-negative first
    neg = jnp.where(neg == _POS_INF, 0, neg)
    return pos + neg


def sorted_order_bitonic(prods: jnp.ndarray, rounds: int = 1) -> jnp.ndarray:
    out = prods
    for _ in range(rounds):
        out = pairwise_round_bitonic(out)
    return out
