"""N:M compressed-weight matmul kernel (gather-expand in VMEM).

Weights pruned to keep n of every m along K are stored compressed:
    values  (N, K//m, n_keep) int8
    indices (N, K//m, n_keep) int32   (position of each kept value in its
                                       m-group; padded groups use idx 0,
                                       value 0)
The kernel streams the *compressed* form from HBM — an m/n_keep bandwidth
saving, which is the term that matters for decode (DESIGN.md §2) — and
expands each (bn, bg, n_keep) slab to a dense (bn, bg*m) block in VMEM via
an iota-compare one-hot einsum (MXU-friendly, no gathers), then runs the
dense int8 dot against the activation slab with wide int32 accumulation.

Expansion cost is n_keep*m multiply-adds per weight — negligible next to
the bm-deep matmul it feeds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, v_ref, i_ref, o_ref, *, m_group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...].astype(jnp.int32)  # (bn, bg, n_keep)
    idx = i_ref[...]  # (bn, bg, n_keep) int32
    # one-hot expand: dense[b, g, p] = sum_k vals[b,g,k] * [idx[b,g,k] == p]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (m_group,), 3)
    onehot = (idx[..., None] == iota).astype(jnp.int32)
    dense = jnp.sum(vals[..., None] * onehot, axis=2)  # (bn, bg, m)
    bn = dense.shape[0]
    wb = dense.reshape(bn, -1)  # (bn, bg*m)

    xb = x_ref[...].astype(jnp.int32)  # (bm, bg*m)
    o_ref[...] += jax.lax.dot_general(
        xb, wb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit,
    static_argnames=("m_group", "bm", "bn", "bg", "interpret"),
)
def nm_spmm(
    x: jax.Array,  # (M, K) int8, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    m_group: int = 16,
    bm: int = 128,
    bn: int = 128,
    bg: int = 32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (k, g, m_group)
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_kernel, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, bg * m_group), lambda i, j, kk: (i, kk)
            ),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)
