"""N:M compressed-weight matmul kernels (gather-expand in VMEM).

Weights pruned to keep n of every m along K are stored compressed:
    values  (N, K//m, n_keep) int8
    indices (N, K//m, n_keep) int32   (position of each kept value in its
                                       m-group; padded groups use idx 0,
                                       value 0)
The kernels stream the *compressed* form from HBM — an m/n_keep bandwidth
saving, which is the term that matters for decode (DESIGN.md §2) — and
expand each (bn, bg, n_keep) slab to a dense (bn, bg*m) block in VMEM via
an iota-compare one-hot einsum (MXU-friendly, no gathers).

``nm_spmm`` is the original wide-int32 form. ``nm_seq_policy_matmul``
and ``nm_sort_matmul`` extend it to EVERY accumulation policy: the
expanded slab is bit-identical to the dense weight block (pruned
positions expand to zero, and zero partial products are sign-neutral
and additively inert through sort, saturation, and wraparound), so
feeding it to the exact ``sorted_matmul``-style kernel bodies yields
results bit-identical to decompress-then-dense — the policy x
sparse-storage composition of ``kernels.ops.nm_policy_matmul``.

Expansion cost is n_keep*m multiply-adds per weight — negligible next to
the bm-deep matmul it feeds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pruning import nm_onehot_expand
from repro.kernels.sorted_matmul import (
    SEQ_POLICIES,
    SORT_POLICIES,
    _seq_body,
    _sort_body,
)


def expand_nm_slab(vals: jax.Array, idx: jax.Array, m_group: int
                   ) -> jax.Array:
    """(bn, bg, n_keep) compressed slab -> dense (bn, bg*m_group) int32.

    Delegates to ``core.pruning.nm_onehot_expand`` — the single
    definition of compressed->dense shared with the jnp decompress
    oracle, so both storage backends realize identical dense blocks.
    Padded slots (value 0, index 0) and zero-padded groups expand to
    zeros, equal to the dense weight block exactly.
    """
    return nm_onehot_expand(vals.astype(jnp.int32), idx, m_group)


def _kernel(x_ref, v_ref, i_ref, o_ref, *, m_group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, bg*m)
    xb = x_ref[...].astype(jnp.int32)  # (bm, bg*m)
    o_ref[...] += jax.lax.dot_general(
        xb, wb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit,
    static_argnames=("m_group", "bm", "bn", "bg", "interpret"),
)
def nm_spmm(
    x: jax.Array,  # (M, K) int8, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    m_group: int = 16,
    bm: int = 128,
    bn: int = 128,
    bg: int = 32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (k, g, m_group)
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_kernel, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, bg * m_group), lambda i, j, kk: (i, kk)
            ),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


# ---------------------------------------------------------------------------
# policy x sparse-storage composition kernels
# ---------------------------------------------------------------------------


def _nm_seq_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                   acc_bits: int, rounds: int, m_group: int):
    """``sorted_matmul._seq_body`` fed by the one-hot expand slab."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, bg*m)
    _seq_body(x_ref[...].astype(jnp.int32), wb, o_ref, policy=policy,
              acc_bits=acc_bits, rounds=rounds)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "rounds", "m_group", "bm", "bn",
                     "bg", "interpret"),
)
def nm_seq_policy_matmul(
    x: jax.Array,  # (M, K) int carrier, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "clip",
    acc_bits: int = 16,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    bg: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """K-streaming policies on compressed storage: wide|clip|wrap|
    sorted_tiled_seq. For sorted_tiled_seq, ``bg * m_group`` IS the
    paper's k_tile (and must be a power of two for the bitonic network),
    so tile boundaries coincide with the dense kernel's."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (x.shape, values.shape, m_group)
    assert policy in SEQ_POLICIES, policy
    if policy == "sorted_tiled_seq":
        bk = bg * m_group
        assert bk & (bk - 1) == 0, f"bg*m_group must be a power of 2: {bk}"
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_nm_seq_kernel, policy=policy,
                             acc_bits=acc_bits, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bg * m_group), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _nm_sort_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                    acc_bits: int, k_tile: int, rounds: int, m_group: int):
    """``sorted_matmul._sort_body`` with the w slab expanded in VMEM.

    x arrives pre-padded to the dense padded K (kp); the expanded slab
    covers G*m <= kp columns and is zero-extended to kp in-kernel (the
    ``sorted`` power-of-two pad) — zeros sort inertly, so the product
    cube equals the dense kernel's exactly.
    """
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp)
    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, G*m)
    kp = xb.shape[1]
    if kp > wb.shape[1]:
        wb = jnp.pad(wb, ((0, 0), (0, kp - wb.shape[1])))
    _sort_body(xb, wb, o_ref, policy=policy, acc_bits=acc_bits,
               k_tile=k_tile, rounds=rounds)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "k_tile", "rounds", "m_group",
                     "bm", "bn", "interpret"),
)
def nm_sort_matmul(
    x: jax.Array,  # (M, kp) int — pre-padded to the dense padded K
    values: jax.Array,  # (N, G, n_keep) int8, G*m_group <= kp
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Global-permutation policies on compressed storage (one-pass,
    full-K-resident — same contract as ``sorted_matmul.sort_matmul``)."""
    m, kp = x.shape
    n, g, n_keep = values.shape
    assert g * m_group <= kp, (values.shape, m_group, kp)
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        assert kp & (kp - 1) == 0, f"K must be a power of 2, got {kp}"
    else:
        assert k_tile & (k_tile - 1) == 0 and kp % k_tile == 0, (kp, k_tile)
        assert g * m_group == kp, "tiled policies pre-pad G to kp/m groups"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_nm_sort_kernel, policy=policy,
                             acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)
