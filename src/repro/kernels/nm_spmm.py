"""N:M compressed-weight matmul kernels: expand-in-VMEM and fused gather.

Weights pruned to keep n of every m along K are stored compressed:
    values  (N, K//m, n_keep) int8
    indices (N, K//m, n_keep) int32   (position of each kept value in its
                                       m-group; padded groups use idx 0,
                                       value 0)
The kernels stream the *compressed* form from HBM — an m/n_keep bandwidth
saving, which is the term that matters for decode (DESIGN.md §2).

Two implementations of every policy x sparse-storage composition
(selected by ``kernels.ops.nm_policy_matmul`` via ``nm_impl`` /
``REPRO_PQS_NM_IMPL``):

expand (``nm_seq_policy_matmul`` / ``nm_sort_matmul``) — expand each
  (bn, bg, n_keep) slab to a dense (bn, bg*m) block in VMEM via an
  iota-compare one-hot einsum (MXU-friendly, no gathers) and feed the
  exact dense ``sorted_matmul`` kernel bodies. Saves bytes, not FLOPs:
  the contraction still runs over the full dense K. The expanded slab is
  bit-identical to the dense weight block (pruned positions expand to
  zero, and zero partial products are sign-neutral and additively inert
  through sort, saturation, and wraparound), so this path is the
  bit-exactness ORACLE for the gather path below.

gather (``nm_gather_seq_policy_matmul`` / ``nm_gather_sort_matmul``) —
  never build the dense block: per m-group, gather the n_keep KEPT
  activation entries through the index slab (``gather_nm_products``) and
  contract only the (bm, bn, G*n_keep) kept products — n_keep/m of the
  dense work, which is the PQS paper's actual pruning payoff (2:4 ⇒ ~2x
  fewer products formed and accumulated). Bit-exactness relies on the
  zero-product prefix property: the dense product stream of a dot equals
  its kept-product stream plus zeros at the pruned positions, and zeros
  are inert through every policy stage (a bitonic pairwise round maps a
  stream-with-extra-zeros to the same output with the zeros still inert,
  so per-tile/global sorted orders agree on their nonzero prefix; clip
  keeps the register in range so clip(acc+0) == acc; wrap is a mod
  identity on in-range values). The bitonic network needs a power-of-two
  length, so gathered tiles pad L = bg*n_keep up to next_pow2(L) <=
  bg*m — still at most the dense tile, usually far below it.

Expansion cost is n_keep*m multiply-adds per weight; the gather is one
dynamic-index load per kept product (same per-element ``take_along_axis``
idiom as ``sorted_stream._gather_tile`` — the standing Mosaic-on-real-TPU
caveat applies, interpret mode is exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pruning import nm_onehot_expand
from repro.core.sorted_accum import tiled_sorted_order
from repro.kernels.bitonic import sorted_order_bitonic
from repro.kernels.sorted_matmul import (
    SEQ_POLICIES,
    SORT_POLICIES,
    _seq_body,
    _sort_body,
    _stepwise,
)


def expand_nm_slab(vals: jax.Array, idx: jax.Array, m_group: int
                   ) -> jax.Array:
    """(bn, bg, n_keep) compressed slab -> dense (bn, bg*m_group) int32.

    Delegates to ``core.pruning.nm_onehot_expand`` — the single
    definition of compressed->dense shared with the jnp decompress
    oracle, so both storage backends realize identical dense blocks.
    Padded slots (value 0, index 0) and zero-padded groups expand to
    zeros, equal to the dense weight block exactly.
    """
    return nm_onehot_expand(vals.astype(jnp.int32), idx, m_group)


def _kernel(x_ref, v_ref, i_ref, o_ref, *, m_group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, bg*m)
    xb = x_ref[...].astype(jnp.int32)  # (bm, bg*m)
    o_ref[...] += jax.lax.dot_general(
        xb, wb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit,
    static_argnames=("m_group", "bm", "bn", "bg", "interpret"),
)
def nm_spmm(
    x: jax.Array,  # (M, K) int8, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    m_group: int = 16,
    bm: int = 128,
    bn: int = 128,
    bg: int = 32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (k, g, m_group)
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_kernel, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, bg * m_group), lambda i, j, kk: (i, kk)
            ),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


# ---------------------------------------------------------------------------
# policy x sparse-storage composition kernels
# ---------------------------------------------------------------------------


def _nm_seq_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                   acc_bits: int, rounds: int, m_group: int):
    """``sorted_matmul._seq_body`` fed by the one-hot expand slab."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, bg*m)
    _seq_body(x_ref[...].astype(jnp.int32), wb, o_ref, policy=policy,
              acc_bits=acc_bits, rounds=rounds)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "rounds", "m_group", "bm", "bn",
                     "bg", "interpret"),
)
def nm_seq_policy_matmul(
    x: jax.Array,  # (M, K) int carrier, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "clip",
    acc_bits: int = 16,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    bg: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """K-streaming policies on compressed storage: wide|clip|wrap|
    sorted_tiled_seq. For sorted_tiled_seq, ``bg * m_group`` IS the
    paper's k_tile (and must be a power of two for the bitonic network),
    so tile boundaries coincide with the dense kernel's."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (x.shape, values.shape, m_group)
    assert policy in SEQ_POLICIES, policy
    if policy == "sorted_tiled_seq":
        bk = bg * m_group
        assert bk & (bk - 1) == 0, f"bg*m_group must be a power of 2: {bk}"
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_nm_seq_kernel, policy=policy,
                             acc_bits=acc_bits, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bg * m_group), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _nm_sort_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                    acc_bits: int, k_tile: int, rounds: int, m_group: int):
    """``sorted_matmul._sort_body`` with the w slab expanded in VMEM.

    x arrives pre-padded to the dense padded K (kp); the expanded slab
    covers G*m <= kp columns and is zero-extended to kp in-kernel (the
    ``sorted`` power-of-two pad) — zeros sort inertly, so the product
    cube equals the dense kernel's exactly.
    """
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp)
    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, G*m)
    kp = xb.shape[1]
    if kp > wb.shape[1]:
        wb = jnp.pad(wb, ((0, 0), (0, kp - wb.shape[1])))
    _sort_body(xb, wb, o_ref, policy=policy, acc_bits=acc_bits,
               k_tile=k_tile, rounds=rounds)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "k_tile", "rounds", "m_group",
                     "bm", "bn", "interpret"),
)
def nm_sort_matmul(
    x: jax.Array,  # (M, kp) int — pre-padded to the dense padded K
    values: jax.Array,  # (N, G, n_keep) int8, G*m_group <= kp
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Global-permutation policies on compressed storage (one-pass,
    full-K-resident — same contract as ``sorted_matmul.sort_matmul``)."""
    m, kp = x.shape
    n, g, n_keep = values.shape
    assert g * m_group <= kp, (values.shape, m_group, kp)
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        assert kp & (kp - 1) == 0, f"K must be a power of 2, got {kp}"
    else:
        assert k_tile & (k_tile - 1) == 0 and kp % k_tile == 0, (kp, k_tile)
        assert g * m_group == kp, "tiled policies pre-pad G to kp/m groups"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_nm_sort_kernel, policy=policy,
                             acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


# ---------------------------------------------------------------------------
# fused activation-gather kernels: contract ONLY the kept products
# ---------------------------------------------------------------------------


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pad_last_pow2(a: jax.Array) -> jax.Array:
    """Zero-pad the last axis up to a power of two (bitonic-sortable).

    Zero products are sign-neutral and additively inert, so the pad is
    exact through sort, saturation, and wraparound.
    """
    n = a.shape[-1]
    p = _next_pow2(n)
    if p == n:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, p - n)]
    return jnp.pad(a, widths)


def gather_nm_products(xb: jax.Array, vals: jax.Array, idx: jax.Array,
                       m_group: int) -> jax.Array:
    """Kept-only partial products via activation gather.

    xb (bm, Kblk >= bg*m_group) int32, vals/idx (bn, bg, n_keep) ->
    (bm, bn, bg*n_keep) int32: product j of row pair (i, o) is
    xb[i, g*m_group + idx[o, g, j]] * vals[o, g, j]. Compared to
    expand-then-dense this forms n_keep/m_group of the products — the
    pruned positions' zero products are never materialized.

    Correctness needs no tail/pad masking by construction: ``nm_compress``
    guarantees indices lie in [0, m_group) (so every gathered position is
    inside the zero-padded xb block) and that padded slots — group
    padding, ragged-K tail positions — carry value 0, making their
    products zero and inert.
    """
    bn, bg, n_keep = vals.shape
    base = jax.lax.broadcasted_iota(
        jnp.int32, (bn, bg, n_keep), 1) * m_group
    pos = (idx.astype(jnp.int32) + base).reshape(bn, bg * n_keep)
    vflat = vals.reshape(bn, bg * n_keep).astype(jnp.int32)
    bm = xb.shape[0]
    xg = jnp.take_along_axis(
        xb[:, None, :],
        jnp.broadcast_to(pos[None, :, :], (bm, bn, bg * n_keep)),
        axis=-1,
    )
    return xg * vflat[None, :, :]


def _nm_gather_seq_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                          acc_bits: int, rounds: int, m_group: int):
    """K-streaming policies on the gathered kept products only.

    Parity with ``_nm_seq_kernel`` (and hence the dense ``_seq_body``):
    wide sums the same nonzero multiset (int32 addition is exact and
    order-free); clip/wrap accumulate the kept products in the same
    ascending-position order the dense stream visits its nonzeros
    (``nm_compress`` stores indices ascending), and the skipped zero
    products are stepwise-inert; sorted_tiled_seq sorts the pow2-padded
    kept tile, whose ordered stream is the dense ordered tile's nonzero
    prefix (the pairwise-round prefix property) followed by zeros.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prods = gather_nm_products(x_ref[...].astype(jnp.int32), v_ref[...],
                               i_ref[...], m_group)
    if policy == "wide":
        o_ref[...] += jnp.sum(prods, axis=-1)
        return
    if policy == "sorted_tiled_seq":
        prods = sorted_order_bitonic(pad_last_pow2(prods), rounds)
    o_ref[...] = _stepwise(prods, o_ref[...], acc_bits,
                           saturate=(policy != "wrap"))


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "rounds", "m_group", "bm", "bn",
                     "bg", "interpret"),
)
def nm_gather_seq_policy_matmul(
    x: jax.Array,  # (M, K) int carrier, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "clip",
    acc_bits: int = 16,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    bg: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Gather twin of ``nm_seq_policy_matmul``: same grid/specs/contract,
    but each step contracts bg*n_keep gathered products instead of
    bg*m_group expanded ones. For sorted_tiled_seq, ``bg * m_group`` IS
    the paper's k_tile (power of two, same constraint as the expand
    kernel, which also bounds the pow2 pad of the gathered tile)."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (x.shape, values.shape, m_group)
    assert policy in SEQ_POLICIES, policy
    if policy == "sorted_tiled_seq":
        bk = bg * m_group
        assert bk & (bk - 1) == 0, f"bg*m_group must be a power of 2: {bk}"
    assert m % bm == 0 and n % bn == 0 and g % bg == 0, (m, n, g, bm, bn, bg)
    grid = (m // bm, n // bn, g // bg)
    kern = functools.partial(_nm_gather_seq_kernel, policy=policy,
                             acc_bits=acc_bits, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bg * m_group), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _nm_gather_sort_kernel(x_ref, v_ref, i_ref, o_ref, *, policy: str,
                           acc_bits: int, k_tile: int, rounds: int,
                           m_group: int):
    """Global-permutation policies on the gathered kept products.

    ``sorted``: one bitonic stage over the pow2-padded kept stream —
    its ordered stream is the dense ordered stream's prefix (zeros past
    the kept count on both sides), so stepwise saturation matches.
    ``sorted_tiled``: the kept products regroup into n_tiles compressed
    tiles of lc = (k_tile/m)*n_keep products, each pow2-padded; tile
    sums equal the dense tile sums exactly (zeros add nothing), so
    ``tiled_sorted_order`` realizes the SAME pairing permutation, and
    each interleaved pair stream is the dense pair stream with its
    inert zeros dropped.
    """
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp)
    prods = gather_nm_products(xb, v_ref[...], i_ref[...], m_group)
    if policy == "sorted":
        ordered = sorted_order_bitonic(pad_last_pow2(prods), rounds)
    else:  # sorted_tiled: caller guarantees g * m_group == kp
        bm_, bn_, total = prods.shape
        n_keep = v_ref.shape[-1]
        lc = (k_tile // m_group) * n_keep
        tiles = pad_last_pow2(prods.reshape(bm_, bn_, total // lc, lc))
        lp = tiles.shape[-1]
        ordered = tiled_sorted_order(
            tiles.reshape(bm_, bn_, -1), lp, rounds,
            order_fn=sorted_order_bitonic,
        )
    o_ref[...] = _stepwise(ordered, jnp.zeros_like(o_ref), acc_bits,
                           saturate=True)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "k_tile", "rounds", "m_group",
                     "bm", "bn", "interpret"),
)
def nm_gather_sort_matmul(
    x: jax.Array,  # (M, kp) int — pre-padded to the dense padded K
    values: jax.Array,  # (N, G, n_keep) int8, G*m_group <= kp
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gather twin of ``nm_sort_matmul`` (one-pass, kept products
    resident: (bm, bn, next_pow2(G*n_keep)) int32 instead of
    (bm, bn, kp) — n_keep/m of the dense cube)."""
    m, kp = x.shape
    n, g, n_keep = values.shape
    assert g * m_group <= kp, (values.shape, m_group, kp)
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        assert kp & (kp - 1) == 0, f"K must be a power of 2, got {kp}"
    else:
        assert k_tile & (k_tile - 1) == 0 and kp % k_tile == 0, (kp, k_tile)
        assert g * m_group == kp, "tiled policies pre-pad G to kp/m groups"
        assert k_tile % m_group == 0, (k_tile, m_group)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_nm_gather_sort_kernel, policy=policy,
                             acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)
