"""Public jit'd wrappers for the Pallas kernels: padding, dtype plumbing,
interpret-mode dispatch (CPU container -> interpret=True; real TPU ->
compiled). This is the layer ``core.dispatch.pqs_dot`` calls for its
Pallas backend — callers outside kernels/ should go through ``pqs_dot``
rather than these wrappers, so every quantized matmul shares one
padding/selection policy.

Shape handling: all entry points accept arbitrary (M, N, K); inputs are
zero-padded up to block multiples and outputs sliced back. Zero partial
products are sign-neutral and additively inert at every stage (sort,
saturation, wraparound), so padding is exact for every accumulation
policy. For the global-sort policies the *pairing permutation* is
computed over the padded tile set — dispatch pads identically for the
jnp backend, so both backends realize the same order.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import nm_compress
from repro.kernels import nm_spmm as _nm
from repro.kernels import quant_matmul as _qm
from repro.kernels import sorted_matmul as _sm

POLICIES = _sm.SEQ_POLICIES + _sm.SORT_POLICIES

# Largest K the compiled (non-interpret) global-sort kernels may keep
# VMEM-resident: 8 * 128 * 4096 * 4 B = 16 MiB for the product cube.
MAX_RESIDENT_K = 4096

# Per-platform (bm, bn) defaults for policy_matmul, keyed by
# jax.default_backend(). The sort policies keep bm small: their product
# cube is bm*bn*K VMEM-resident, so M-blocking is the lever that keeps
# the footprint under budget. On TPU, bn rides the 128-lane dim and the
# stepwise policies want a full (8, 128) f32 tile; CPU interpret mode
# favors small blocks (python-loop grid — fewer, larger steps lose).
# Override for experiments with REPRO_PQS_BLOCKS="bm,bn" (both ints).
_BLOCK_TABLE: dict[str, dict[str, tuple[int, int]]] = {
    "tpu": {
        "wide": (128, 128),  # MXU dot: full systolic tile
        "clip": (8, 128),  # VPU stepwise: min f32 tile, K-streamed
        "wrap": (8, 128),
        "sorted": (8, 128),  # K fully resident: keep bm minimal
        "sorted_tiled": (8, 128),
        "sorted_tiled_seq": (8, 128),
    },
    # CPU/GPU run interpret mode; block shape only affects grid overhead
    "cpu": {"*": (8, 128)},
    "gpu": {"*": (8, 128)},
}


def default_blocks(policy: str, platform: str | None = None
                   ) -> tuple[int, int]:
    """(bm, bn) for a policy on the current (or given) platform."""
    env = os.environ.get("REPRO_PQS_BLOCKS")
    if env:
        try:
            bm, bn = (int(v) for v in env.split(","))
            return bm, bn
        except ValueError as e:
            raise ValueError(
                f"REPRO_PQS_BLOCKS must be 'bm,bn' (two ints), got {env!r}"
            ) from e
    table = _BLOCK_TABLE.get(platform or jax.default_backend(),
                             _BLOCK_TABLE["cpu"])
    return table.get(policy) or table.get("*") or (8, 128)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def padded_k(k: int, policy: str, k_tile: int) -> int:
    """The K length a policy's kernel actually accumulates over.

    ``sorted`` runs one bitonic stage over the whole axis (power of two);
    the tiled policies pad to a whole number of k_tile tiles; the
    unsorted policies need no K padding at all.
    """
    if policy == "sorted":
        return next_pow2(k)
    if policy in ("sorted_tiled", "sorted_tiled_seq"):
        return k + ((-k) % k_tile)
    return k


def policy_matmul(
    x: jax.Array,  # (M, K) integer carrier
    w: jax.Array,  # (N, K) integer carrier
    *,
    policy: str = "wide",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int | None = None,
    bn: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(M, N) int32 under any accumulation policy, any shape.

    The single Pallas entry point behind ``core.dispatch.pqs_dot``:
    pads M/N/K to block multiples, picks the K-streaming kernel for
    order-preserving policies and the K-resident sort kernel for the
    global-permutation ones, and slices the result back. ``bm``/``bn``
    default to the per-platform ``_BLOCK_TABLE`` entry for the policy
    (env override: REPRO_PQS_BLOCKS="bm,bn").
    """
    assert policy in POLICIES, policy
    dbm, dbn = default_blocks(policy)
    bm = dbm if bm is None else bm
    bn = dbn if bn is None else bn
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[0]
    kp = padded_k(x.shape[1], policy, k_tile)
    if policy in _sm.SORT_POLICIES and not interpret and kp > MAX_RESIDENT_K:
        # compiled sort_matmul keeps the whole K axis VMEM-resident
        # (bm*bn*K*4 bytes before sort temporaries)
        raise ValueError(
            f"policy {policy!r} needs K={kp} VMEM-resident, above the "
            f"compiled-kernel bound {MAX_RESIDENT_K}; use "
            "policy='sorted_tiled_seq' (K-streaming) or backend='jnp'"
        )
    xp = _pad_to(_pad_to(x, bm, 0), kp, 1)
    wp = _pad_to(_pad_to(w, kp, 1), bn, 0)
    if policy in _sm.SORT_POLICIES:
        out = _sm.sort_matmul(
            xp, wp, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
            rounds=rounds, bm=bm, bn=bn, interpret=interpret,
        )
    else:
        # streaming block depth: the sort tile for sorted_tiled_seq, else
        # a bandwidth-friendly slab that divides the (padded) K
        bk = k_tile if policy == "sorted_tiled_seq" else min(
            512, next_pow2(kp)
        )
        xp = _pad_to(xp, bk, 1)
        wp = _pad_to(wp, bk, 1)
        out = _sm.seq_policy_matmul(
            xp, wp, policy=policy, acc_bits=acc_bits, rounds=rounds,
            bm=bm, bn=bn, bk=bk, interpret=interpret,
        )
    return out[:m, :n]


def quant_matmul(x, w, *, bm=128, bn=128, bk=512, interpret=None):
    """Padded dense int8 matmul: (M,K) x (K,N) -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    out = _qm.quant_matmul(xp, wp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def sorted_matmul(
    x, w, *, acc_bits=16, rounds=1, bm=None, bn=None, bk=256, interpret=None
):
    """PQS tiled-sort matmul: (M,K) x (N,K) -> (M,N) int32 @ acc_bits.

    Zero-padding is exact for the sort semantics: zero partial products are
    sign-neutral and additively inert at every stage.
    """
    return policy_matmul(
        x, w, policy="sorted_tiled_seq", acc_bits=acc_bits, k_tile=bk,
        rounds=rounds, bm=bm, bn=bn, interpret=interpret,
    )


def clip_matmul(x, w, *, acc_bits=16, bm=None, bn=None, bk=256,
                interpret=None):
    return policy_matmul(
        x, w, policy="clip", acc_bits=acc_bits, k_tile=bk,
        bm=bm, bn=bn, interpret=interpret,
    )


def nm_spmm(
    x, values, indices, *, m_group=16, bm=128, bn=128, bg=32, interpret=None
):
    """Compressed N:M matmul: (M,K) x [(N,G,keep) vals+idx] -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], values.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bg * m_group, 1)
    g_pad = (-values.shape[1]) % bg
    if g_pad:
        values = jnp.pad(values, ((0, 0), (0, g_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, 0), (0, g_pad), (0, 0)))
    vp = _pad_to(values, bn, 0)
    ip = _pad_to(indices, bn, 0)
    out = _nm.nm_spmm(
        xp, vp, ip, m_group=m_group, bm=bm, bn=bn, bg=bg, interpret=interpret
    )
    return out[:m, :n]


def compress_nm_weights(w: np.ndarray, n_keep: int, m: int):
    """Host-side packer: dense (N, K) -> (values, indices) for nm_spmm."""
    vals, idx = nm_compress(np.asarray(w), n_keep, m)
    return jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
