"""Public jit'd wrappers for the Pallas kernels: padding, dtype plumbing,
interpret-mode dispatch (CPU container -> interpret=True; real TPU ->
compiled). This is the layer ``core.dispatch.pqs_dot`` calls for its
Pallas backend — callers outside kernels/ should go through ``pqs_dot``
rather than these wrappers, so every quantized matmul shares one
padding/selection policy.

Shape handling: all entry points accept arbitrary (M, N, K); inputs are
zero-padded up to block multiples and outputs sliced back. Zero partial
products are sign-neutral and additively inert at every stage (sort,
saturation, wraparound), so padding is exact for every accumulation
policy. For the global-sort policies the *pairing permutation* is
computed over the padded tile set — dispatch pads identically for the
jnp backend, so both backends realize the same order.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import nm_compress
from repro.kernels import autotune
from repro.kernels import nm_spmm as _nm
from repro.kernels import quant_matmul as _qm
from repro.kernels import sorted_matmul as _sm
from repro.kernels import sorted_stream as _ss

POLICIES = _sm.SEQ_POLICIES + _sm.SORT_POLICIES
# the N:M compressed-storage kernel family tunes/blocks independently of
# the dense kernels (different VMEM mix: one-hot expand slab vs dense w)
NM_POLICIES = tuple(f"nm:{p}" for p in POLICIES)
# the fused activation-gather implementation is its own family again:
# its working set scales with G*n_keep (compressed), not G*m (dense),
# so the blocks that win differ from both the dense and expand kernels
NM_GATHER_POLICIES = tuple(f"nmg:{p}" for p in POLICIES)

# N:M kernel implementation selection (see resolve_nm_impl):
#   expand — one-hot expand the compressed slab to dense in VMEM and run
#            the dense kernel bodies (the bit-exactness oracle; full
#            dense-K MXU work, saves HBM bytes only)
#   gather — gather the kept activation entries per m-group and contract
#            only n_keep/m of the products (saves FLOPs; VPU-flavored)
#   auto   — gather wherever it can win, expand where it cannot
NM_IMPLS = ("auto", "expand", "gather")
# below this many groups the whole contraction is a handful of columns;
# expand's single dense dot beats gather's index arithmetic
GATHER_MIN_G = 8

# Largest K the compiled (non-interpret) LEGACY one-pass sort kernel may
# keep VMEM-resident: 8 * 128 * 4096 * 4 B = 16 MiB for the product cube.
# The two-pass streaming pipeline (kernels/sorted_stream.py) is bounded
# by its int8 operand slabs instead: bn * K bytes, so MAX_STREAM_K below.
MAX_RESIDENT_K = 4096
MAX_STREAM_K = 65536

SORT_IMPLS = ("auto", "onepass", "twopass")

# Per-platform (bm, bn) defaults for policy_matmul, keyed by
# jax.default_backend(). The sort policies keep bm small: their product
# cube (one-pass) or working pair (two-pass) scales with bm, so
# M-blocking is the lever that keeps the footprint under budget. On TPU,
# bn rides the 128-lane dim and the stepwise policies want a full
# (8, 128) f32 tile; CPU interpret mode favors small blocks
# (python-loop grid — fewer, larger steps lose). This table is the seed
# and fallback for the measured autotuner (kernels/autotune.py,
# REPRO_PQS_AUTOTUNE=off|tune|readonly); REPRO_PQS_BLOCKS overrides
# everything — "bm,bn" for all policies, or per-policy entries like
# "sorted:8,128;wide:128,128" (policies without an entry fall through).
_BLOCK_TABLE: dict[str, dict[str, tuple[int, int]]] = {
    "tpu": {
        "wide": (128, 128),  # MXU dot: full systolic tile
        "clip": (8, 128),  # VPU stepwise: min f32 tile, K-streamed
        "wrap": (8, 128),
        "sorted": (8, 128),  # K fully resident: keep bm minimal
        "sorted_tiled": (8, 128),
        "sorted_tiled_seq": (8, 128),
        # nm: family — compressed slabs are ~n_keep/m of the dense bytes,
        # so bn can ride larger before the w slab dominates VMEM; the
        # stepwise policies keep the dense (8, 128) working tile
        "nm:wide": (128, 128),
        "nm:clip": (8, 128),
        "nm:wrap": (8, 128),
        "nm:sorted": (8, 128),
        "nm:sorted_tiled": (8, 128),
        "nm:sorted_tiled_seq": (8, 128),
        # nmg: family — gather kernels are VPU gather-multiply bound with
        # an n_keep/m-sized product set; wide still wants the big tile
        # (its reduce is one lane-sum), the stepwise policies keep the
        # minimal f32 tile
        "nmg:wide": (128, 128),
        "nmg:clip": (8, 128),
        "nmg:wrap": (8, 128),
        "nmg:sorted": (8, 128),
        "nmg:sorted_tiled": (8, 128),
        "nmg:sorted_tiled_seq": (8, 128),
    },
    # CPU/GPU run interpret mode; block shape only affects grid overhead
    "cpu": {"*": (8, 128)},
    "gpu": {"*": (8, 128)},
}


_BLOCKS_SYNTAX = (
    "REPRO_PQS_BLOCKS must be 'bm,bn' (two ints, all policies) or "
    "';'-separated per-policy entries 'policy:bm,bn' "
    "(e.g. \"sorted:8,128;wide:128,128\")"
)


def env_blocks(policy: str) -> tuple[int, int] | None:
    """The REPRO_PQS_BLOCKS override for ``policy``, or None.

    Accepts the bare ``"bm,bn"`` form (applies to every policy) and
    per-policy entries ``"sorted:8,128;wide:128,128"``; the two forms
    may be mixed (the bare entry becomes the default for policies
    without their own). Malformed input raises with the full syntax.
    """
    env = os.environ.get("REPRO_PQS_BLOCKS")
    if not env:
        return None
    default = None
    per_policy: dict[str, tuple[int, int]] = {}
    for entry in env.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        name, _, pair = entry.rpartition(":")
        try:
            bm, bn = (int(v) for v in pair.split(","))
        except ValueError as e:
            raise ValueError(
                f"{_BLOCKS_SYNTAX}; bad entry {entry!r} in {env!r}"
            ) from e
        if name:
            known = POLICIES + NM_POLICIES + NM_GATHER_POLICIES
            if name not in known:
                raise ValueError(
                    f"{_BLOCKS_SYNTAX}; unknown policy {name!r} in {env!r} "
                    f"(expected one of {known})"
                )
            per_policy[name] = (bm, bn)
        else:
            default = (bm, bn)
    return per_policy.get(policy, default)


def default_blocks(policy: str, platform: str | None = None
                   ) -> tuple[int, int]:
    """(bm, bn) for a policy on the current (or given) platform."""
    env = env_blocks(policy)
    if env:
        return env
    table = _BLOCK_TABLE.get(platform or jax.default_backend(),
                             _BLOCK_TABLE["cpu"])
    return table.get(policy) or table.get("*") or (8, 128)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (and 1 for n <= 1: a K=1 dot is already
    bitonic-sortable — padding it to 2 would be pure waste)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def padded_k(k: int, policy: str, k_tile: int) -> int:
    """The K length a policy's kernel actually accumulates over.

    ``sorted`` runs one bitonic stage over the whole axis (power of two);
    the tiled policies pad to a whole number of k_tile tiles; the
    unsorted policies need no K padding at all.
    """
    if policy == "sorted":
        return next_pow2(k)
    if policy in ("sorted_tiled", "sorted_tiled_seq"):
        return k + ((-k) % k_tile)
    return k


def _as_int8(a: jax.Array) -> jax.Array:
    """Narrow an integer carrier to int8 for the streaming sort slabs.

    Slab VMEM is what scales with K in the two-pass pipeline, and
    carriers hold int8 values by the ``pqs_dot`` contract, so the cast
    is lossless for every legitimate caller. A silently wrapped
    out-of-contract value would diverge from the jnp backend, so on
    concrete (non-traced) operands the contract is checked loudly; the
    check is one cheap reduction next to a sort matmul. Traced calls
    (jitted serving steps, whose carriers come from int8 quantizers)
    trust the contract.
    """
    if a.dtype == jnp.int8:
        return a
    if not isinstance(a, jax.core.Tracer):
        lo, hi = int(jnp.min(a)), int(jnp.max(a))
        if lo < -128 or hi > 127:
            raise ValueError(
                f"two-pass sort carriers must hold int8 values (pqs_dot "
                f"contract); got range [{lo}, {hi}] in {a.dtype}. Use "
                "sort_impl='onepass' (K-resident) or backend='jnp' for "
                "wider products."
            )
    return a.astype(jnp.int8)


def resolve_sort_impl(kp: int, interpret: bool,
                      sort_impl: str = "auto") -> str:
    """Which global-sort kernel serves a (padded-)K request.

    ``auto`` keeps the legacy one-pass kernel where it is known-good
    (K within MAX_RESIDENT_K) and switches to the two-pass streaming
    pipeline above it. Explicit ``onepass`` above the resident bound on
    a compiled path raises — that is the one case the old hard refusal
    still covers; ``twopass`` is refused only past MAX_STREAM_K (the
    int8 slab budget), interpret mode is unbounded.
    """
    if sort_impl not in SORT_IMPLS:
        raise ValueError(
            f"sort_impl must be one of {SORT_IMPLS}, got {sort_impl!r}")
    if sort_impl == "auto":
        sort_impl = "onepass" if kp <= MAX_RESIDENT_K else "twopass"
    if interpret:
        return sort_impl
    if sort_impl == "onepass" and kp > MAX_RESIDENT_K:
        raise ValueError(
            f"one-pass sort kernel needs K={kp} VMEM-resident, above the "
            f"compiled-kernel bound {MAX_RESIDENT_K}; use "
            "sort_impl='twopass' (default above the bound)"
        )
    if sort_impl == "twopass" and kp > MAX_STREAM_K:
        raise ValueError(
            f"two-pass sort pipeline keeps (bn, K) int8 slabs resident; "
            f"K={kp} exceeds MAX_STREAM_K={MAX_STREAM_K}; use "
            "policy='sorted_tiled_seq' (fully K-streaming) or "
            "backend='jnp'"
        )
    return sort_impl


def resolve_nm_impl(policy: str, g: int, n_keep: int, m_group: int,
                    nm_impl: str | None = None) -> str:
    """Which N:M kernel implementation serves a compressed matmul.

    Explicit ``nm_impl`` (or ``REPRO_PQS_NM_IMPL``) wins; ``auto`` picks
    ``gather`` wherever the kept-product contraction can actually save
    work and falls back to ``expand`` when it cannot:

    * ``n_keep >= m_group`` — dense-as-sparse storage: every product is
      kept, gathering reorders full-dense work for no gain;
    * ``policy == "wide"`` — the exact wide sum is a single dense MXU
      dot under expand; a VPU gather-multiply-reduce over n_keep/m of
      the products does not beat the systolic array until sparsity is
      far higher than N:M configurations provide;
    * ``g < GATHER_MIN_G`` — a handful of groups: gather's index
      arithmetic costs more than the few columns it skips.
    """
    impl = nm_impl
    if impl is None:
        impl = os.environ.get("REPRO_PQS_NM_IMPL", "auto").strip().lower()
        impl = impl or "auto"
    if impl not in NM_IMPLS:
        raise ValueError(
            f"nm_impl (REPRO_PQS_NM_IMPL) must be one of {NM_IMPLS}, "
            f"got {impl!r}"
        )
    if impl != "auto":
        return impl
    if n_keep >= m_group:
        return "expand"
    if policy == "wide":
        return "expand"
    if g < GATHER_MIN_G:
        return "expand"
    return "gather"


def _blocks_for(policy, m, n, kp, runner, tracing, nm=None):
    """bm, bn, bk resolution: env override > autotune (when enabled) >
    static table. bk is only tunable for the free-depth seq policies.
    ``nm`` carries (m_group, n_keep, G) for the compressed families so
    the autotune cache keys on the work actually launched."""
    env = env_blocks(policy)
    if env:
        return env[0], env[1], None
    if autotune.mode() != "off":
        tuned = autotune.best_blocks(policy, m, n, kp, runner=runner,
                                     tracing=tracing, nm=nm)
        if tuned:
            return tuned
    dbm, dbn = default_blocks(policy)
    return dbm, dbn, None


def policy_matmul(
    x: jax.Array,  # (M, K) integer carrier
    w: jax.Array,  # (N, K) integer carrier
    *,
    policy: str = "wide",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    sort_impl: str = "auto",
    interpret: bool | None = None,
    census: bool = True,
) -> jax.Array:
    """(M, N) int32 under any accumulation policy, any shape.

    The single Pallas entry point behind ``core.dispatch.pqs_dot``:
    pads M/N/K to block multiples, picks the K-streaming kernel for
    order-preserving policies and a global-sort kernel (one-pass
    K-resident or two-pass streaming, ``sort_impl``) for the
    permutation ones, and slices the result back. ``bm``/``bn``/``bk``
    default to the measured-autotune winner when REPRO_PQS_AUTOTUNE is
    enabled, else the per-platform ``_BLOCK_TABLE`` entry
    (REPRO_PQS_BLOCKS overrides both — bare "bm,bn" or per-policy
    "sorted:8,128;wide:128,128").

    ``census=False`` is the certified route (`core.certify`): the caller
    holds a proof that no partial sum can reach the acc_bits caps, so
    the narrow policy's stepwise saturate bookkeeping — and the sort
    pipeline itself — is provably a no-op, and the request is served by
    the exact wide kernel body (one MXU dot, bit-identical BY THE PROOF
    to the stepwise narrow result). Meaningless without a certificate:
    an uncertified caller would silently lose the saturation semantics.
    """
    assert policy in POLICIES, policy
    if not census:
        policy = "wide"  # provably saturate-free -> exact wide body
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[0]
    kp = padded_k(x.shape[1], policy, k_tile)
    if bm is None and bn is None:
        # the tuner only rules when the caller pinned NEITHER dimension:
        # a winner was measured as a (bm, bn, bk) unit, so grafting one
        # of its axes onto a caller-pinned other would apply (and cache)
        # a configuration that was never timed or fit-checked
        def _runner(cbm, cbn, cbk):
            return policy_matmul(
                x, w, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
                rounds=rounds, bm=cbm, bn=cbn, bk=cbk,
                sort_impl=sort_impl, interpret=interpret,
            )

        bm, bn, abk = _blocks_for(policy, m, n, kp, _runner,
                                  tracing=isinstance(x, jax.core.Tracer))
        bk = abk if bk is None else bk
    elif bm is None or bn is None:
        dbm, dbn = default_blocks(policy)
        bm = dbm if bm is None else bm
        bn = dbn if bn is None else bn
    if policy in _sm.SORT_POLICIES:
        impl = resolve_sort_impl(kp, interpret, sort_impl)
        xp = _pad_to(_pad_to(x, bm, 0), kp, 1)
        wp = _pad_to(_pad_to(w, kp, 1), bn, 0)
        if impl == "onepass":
            out = _sm.sort_matmul(
                xp, wp, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
                rounds=rounds, bm=bm, bn=bn, interpret=interpret,
            )
        else:
            out = _ss.stream_sort_matmul(
                _as_int8(xp), _as_int8(wp), policy=policy,
                acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                bm=bm, bn=bn, interpret=interpret,
            )
    else:
        # streaming block depth: the sort tile for sorted_tiled_seq, else
        # a bandwidth-friendly slab that divides the (padded) K
        if policy == "sorted_tiled_seq":
            bk = k_tile
        elif bk is None:
            bk = min(512, next_pow2(kp))
        xp = _pad_to(_pad_to(_pad_to(x, bm, 0), kp, 1), bk, 1)
        wp = _pad_to(_pad_to(_pad_to(w, kp, 1), bk, 1), bn, 0)
        out = _sm.seq_policy_matmul(
            xp, wp, policy=policy, acc_bits=acc_bits, rounds=rounds,
            bm=bm, bn=bn, bk=bk, interpret=interpret,
        )
    return out[:m, :n]


def partial_policy_matmul(
    x: jax.Array,  # (M, k_shards * k_local) integer carrier
    w: jax.Array,  # (N, k_shards * k_local) integer carrier
    *,
    k_shards: int,
    policy: str = "wide",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int | None = None,
    bn: int | None = None,
    sort_impl: str = "auto",
    interpret: bool | None = None,
    census: bool = True,
) -> jax.Array:
    """Per-K-shard partials of a K-sharded policy matmul: (M, N, k_shards).

    The caller (``core.dispatch``) pre-pads K so it splits into
    ``k_shards`` equal, policy-padded slices; shard s's slice is then
    accumulated by the UNCHANGED local kernel body (``policy_matmul``)
    over its k_local columns only. The partials are "unsaturated"
    *across* shards — no cross-shard combine or re-clamp happens here;
    merging them (up the static combine tree, with stepwise saturation,
    counting combine-step overflows) is the dispatch layer's job through
    ``core.sorted_accum.tree_combine`` / ``combine_schedule`` — the same
    schedule whether combined locally or as pairwise mesh exchanges.
    Each shard's K footprint is K/k_shards, which is what carries the
    compiled sort kernels past ``MAX_STREAM_K`` total K.
    """
    if k_shards < 1 or x.shape[1] % k_shards:
        raise ValueError(
            f"K={x.shape[1]} does not split into k_shards={k_shards} "
            "equal slices (dispatch pads K before sharding)"
        )
    k_local = x.shape[1] // k_shards
    parts = [
        policy_matmul(
            x[:, s * k_local : (s + 1) * k_local],
            w[:, s * k_local : (s + 1) * k_local],
            policy=policy, acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
            bm=bm, bn=bn, sort_impl=sort_impl, interpret=interpret,
            census=census,
        )
        for s in range(k_shards)
    ]
    return jnp.stack(parts, axis=-1)


def nm_partial_policy_matmul(
    x: jax.Array,  # (M, k_shards * g_local * m_group) integer carrier
    values: jax.Array,  # (N, k_shards * g_local, n_keep) int8
    indices: jax.Array,  # (N, k_shards * g_local, n_keep) int32
    *,
    m_group: int,
    k_shards: int,
    policy: str = "wide",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int | None = None,
    bn: int | None = None,
    sort_impl: str = "auto",
    nm_impl: str | None = None,
    interpret: bool | None = None,
    census: bool = True,
) -> jax.Array:
    """``partial_policy_matmul`` on N:M compressed storage.

    K shards in units of whole groups (the caller pads G to a k_shards
    multiple with g_local * m_group a policy-padded length), so a
    shard's slab expand/gather never crosses a shard boundary and each
    slice runs the unchanged ``nm_policy_matmul`` body. ``nm_impl``
    selects expand vs gather per slice (``auto`` resolves against the
    LOCAL G, so very small shards may individually fall back to expand
    — bit-identical either way).
    """
    g = values.shape[1]
    if k_shards < 1 or g % k_shards:
        raise ValueError(
            f"G={g} does not split into k_shards={k_shards} whole-group "
            "slices (dispatch pads G before sharding)"
        )
    g_local = g // k_shards
    k_local = g_local * m_group
    parts = [
        nm_policy_matmul(
            x[:, s * k_local : (s + 1) * k_local],
            values[:, s * g_local : (s + 1) * g_local],
            indices[:, s * g_local : (s + 1) * g_local],
            m_group=m_group, policy=policy, acc_bits=acc_bits,
            k_tile=k_tile, rounds=rounds, bm=bm, bn=bn,
            sort_impl=sort_impl, nm_impl=nm_impl, interpret=interpret,
            census=census,
        )
        for s in range(k_shards)
    ]
    return jnp.stack(parts, axis=-1)


def nm_policy_matmul(
    x: jax.Array,  # (M, K) integer carrier, K <= G * m_group
    values: jax.Array,  # (N, G, n_keep) int8 compressed weights
    indices: jax.Array,  # (N, G, n_keep) int32 in-group positions
    *,
    m_group: int,
    policy: str = "wide",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int | None = None,
    bn: int | None = None,
    bg: int | None = None,
    sort_impl: str = "auto",
    nm_impl: str | None = None,
    interpret: bool | None = None,
    census: bool = True,
) -> jax.Array:
    """Every accumulation policy directly on N:M compressed storage.

    The sparse sibling of ``policy_matmul``: same (M, N) int32 contract,
    same padding discipline, but the weight operand never exists dense
    in HBM. Two implementations serve it (``nm_impl`` /
    ``REPRO_PQS_NM_IMPL``, resolved by ``resolve_nm_impl``):

    * ``expand`` one-hot expands (bn, bg, n_keep) slabs to dense blocks
      in VMEM and runs the unchanged dense kernel bodies — the
      bit-exactness oracle, full dense-K work;
    * ``gather`` gathers the kept activation entries per m-group and
      contracts only the (bm, bn, bg*n_keep) kept products — n_keep/m
      of the work, bit-identical by the zero-product prefix property
      (see ``kernels/nm_spmm.py``).

    Padding happens on the GROUP axis (G) instead of K: groups pad to
    ``bg`` blocks (tiled policies pin ``bg * m_group = k_tile`` so tile
    boundaries coincide with the dense kernels'), and zero-padded
    groups expand/gather to zero products — additively inert through
    every policy, so results are bit-identical to ``nm_decompress``
    followed by dense ``policy_matmul``. Blocks resolve under the
    ``nm:`` (expand) or ``nmg:`` (gather) kernel family
    (``REPRO_PQS_BLOCKS``, autotune, ``_BLOCK_TABLE``), keyed on the
    compressed geometry ``(m_group, n_keep, G)`` rather than dense K.

    ``census=False``: the certified route, exactly as on
    ``policy_matmul`` — a `core.certify` proof makes the stepwise
    saturation dead code, so the request reroutes to the wide body on
    the SAME compressed storage (N:M savings retained).
    """
    assert policy in POLICIES, policy
    if not census:
        policy = "wide"  # provably saturate-free -> exact wide body
    interpret = (not _on_tpu()) if interpret is None else interpret
    if values.shape != indices.shape:
        raise ValueError(
            f"values/indices shape mismatch: {values.shape} vs "
            f"{indices.shape}"
        )
    if values.ndim != 3:
        raise ValueError(f"expected (N, G, n_keep) slabs, got {values.shape}")
    m = x.shape[0]
    n, g, n_keep = values.shape
    k_dense = g * m_group
    if x.shape[1] > k_dense:
        raise ValueError(
            f"contraction mismatch: x has K={x.shape[1]} but the "
            f"compressed weights cover G*m = {g}*{m_group} = {k_dense}"
        )
    if policy in ("sorted_tiled", "sorted_tiled_seq") and (
        k_tile % m_group != 0
    ):
        raise ValueError(
            f"tiled policies need k_tile % m_group == 0 so tile "
            f"boundaries align with the compressed groups; got "
            f"k_tile={k_tile}, m_group={m_group}"
        )
    kp = padded_k(k_dense, policy, k_tile)
    impl = resolve_nm_impl(policy, g, n_keep, m_group, nm_impl)
    fam = f"nmg:{policy}" if impl == "gather" else f"nm:{policy}"
    if bm is None and bn is None:

        def _runner(cbm, cbn, cbg):
            return nm_policy_matmul(
                x, values, indices, m_group=m_group, policy=policy,
                acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
                bm=cbm, bn=cbn, bg=cbg, sort_impl=sort_impl,
                nm_impl=impl, interpret=interpret,
            )

        bm, bn, abg = _blocks_for(fam, m, n, kp, _runner,
                                  tracing=isinstance(x, jax.core.Tracer),
                                  nm=(m_group, n_keep, g))
        bg = abg if bg is None else bg
    elif bm is None or bn is None:
        dbm, dbn = default_blocks(fam)
        bm = dbm if bm is None else bm
        bn = dbn if bn is None else bn
    xp = _pad_to(_pad_to(x, bm, 0), k_dense, 1)  # tail K -> whole groups
    vp = _pad_to(values, bn, 0)
    ip = _pad_to(indices, bn, 0)
    if policy in _sm.SORT_POLICIES:
        simpl = resolve_sort_impl(kp, interpret, sort_impl)
        if policy == "sorted_tiled":
            # pad G so the compressed groups cover exactly kp columns —
            # the tiled kernels then never need an in-kernel column pad
            gp = kp // m_group
            if gp > g:
                vp = jnp.pad(vp, ((0, 0), (0, gp - g), (0, 0)))
                ip = jnp.pad(ip, ((0, 0), (0, gp - g), (0, 0)))
        xp = _pad_to(xp, kp, 1)
        if simpl == "onepass":
            fn = (_nm.nm_gather_sort_matmul if impl == "gather"
                  else _nm.nm_sort_matmul)
            out = fn(
                xp, vp, ip, policy=policy, acc_bits=acc_bits,
                k_tile=k_tile, rounds=rounds, m_group=m_group,
                bm=bm, bn=bn, interpret=interpret,
            )
        else:
            fn = (_ss.nm_gather_stream_sort_matmul if impl == "gather"
                  else _ss.nm_stream_sort_matmul)
            out = fn(
                _as_int8(xp), vp, ip, policy=policy, acc_bits=acc_bits,
                k_tile=k_tile, rounds=rounds, m_group=m_group,
                bm=bm, bn=bn, interpret=interpret,
            )
    else:
        if policy == "sorted_tiled_seq":
            bg = k_tile // m_group  # the sort block IS the paper's k_tile
        elif bg is None:
            bg = max(1, min(512, next_pow2(k_dense)) // m_group)
        g_pad = (-g) % bg
        if g_pad:
            vp = jnp.pad(vp, ((0, 0), (0, g_pad), (0, 0)))
            ip = jnp.pad(ip, ((0, 0), (0, g_pad), (0, 0)))
            xp = _pad_to(xp, (g + g_pad) * m_group, 1)
        fn = (_nm.nm_gather_seq_policy_matmul if impl == "gather"
              else _nm.nm_seq_policy_matmul)
        out = fn(
            xp, vp, ip, policy=policy, acc_bits=acc_bits, rounds=rounds,
            m_group=m_group, bm=bm, bn=bn, bg=bg, interpret=interpret,
        )
    return out[:m, :n]


def quant_matmul(x, w, *, bm=128, bn=128, bk=512, interpret=None):
    """Padded dense int8 matmul: (M,K) x (K,N) -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    out = _qm.quant_matmul(xp, wp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def sorted_matmul(
    x, w, *, acc_bits=16, rounds=1, bm=None, bn=None, bk=256, interpret=None
):
    """PQS tiled-sort matmul: (M,K) x (N,K) -> (M,N) int32 @ acc_bits.

    Zero-padding is exact for the sort semantics: zero partial products are
    sign-neutral and additively inert at every stage.
    """
    return policy_matmul(
        x, w, policy="sorted_tiled_seq", acc_bits=acc_bits, k_tile=bk,
        rounds=rounds, bm=bm, bn=bn, interpret=interpret,
    )


def clip_matmul(x, w, *, acc_bits=16, bm=None, bn=None, bk=256,
                interpret=None):
    return policy_matmul(
        x, w, policy="clip", acc_bits=acc_bits, k_tile=bk,
        bm=bm, bn=bn, interpret=interpret,
    )


def nm_spmm(
    x, values, indices, *, m_group=16, bm=128, bn=128, bg=32, interpret=None
):
    """Compressed N:M matmul: (M,K) x [(N,G,keep) vals+idx] -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], values.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bg * m_group, 1)
    g_pad = (-values.shape[1]) % bg
    if g_pad:
        values = jnp.pad(values, ((0, 0), (0, g_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, 0), (0, g_pad), (0, 0)))
    vp = _pad_to(values, bn, 0)
    ip = _pad_to(indices, bn, 0)
    out = _nm.nm_spmm(
        xp, vp, ip, m_group=m_group, bm=bm, bn=bn, bg=bg, interpret=interpret
    )
    return out[:m, :n]


def compress_nm_weights(w: np.ndarray, n_keep: int, m: int):
    """Host-side packer: dense (N, K) -> (values, indices) for nm_spmm."""
    vals, idx = nm_compress(np.asarray(w), n_keep, m)
    return jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
