"""Public jit'd wrappers for the Pallas kernels: padding, dtype plumbing,
interpret-mode dispatch (CPU container -> interpret=True; real TPU ->
compiled). This is the layer the rest of the framework calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import nm_compress
from repro.kernels import nm_spmm as _nm
from repro.kernels import quant_matmul as _qm
from repro.kernels import sorted_matmul as _sm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def quant_matmul(x, w, *, bm=128, bn=128, bk=512, interpret=None):
    """Padded dense int8 matmul: (M,K) x (K,N) -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[1]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    out = _qm.quant_matmul(xp, wp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def sorted_matmul(
    x, w, *, acc_bits=16, rounds=1, bm=8, bn=128, bk=256, interpret=None
):
    """PQS tiled-sort matmul: (M,K) x (N,K) -> (M,N) int32 @ acc_bits.

    Zero-padding is exact for the sort semantics: zero partial products are
    sign-neutral and additively inert at every stage.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 1), bn, 0)
    out = _sm.sorted_matmul(
        xp, wp, acc_bits=acc_bits, rounds=rounds,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return out[:m, :n]


def clip_matmul(x, w, *, acc_bits=16, bm=8, bn=128, bk=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], w.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 1), bn, 0)
    out = _sm.clip_matmul(
        xp, wp, acc_bits=acc_bits, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    return out[:m, :n]


def nm_spmm(
    x, values, indices, *, m_group=16, bm=128, bn=128, bg=32, interpret=None
):
    """Compressed N:M matmul: (M,K) x [(N,G,keep) vals+idx] -> (M,N) int32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, n = x.shape[0], values.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bg * m_group, 1)
    g_pad = (-values.shape[1]) % bg
    if g_pad:
        values = jnp.pad(values, ((0, 0), (0, g_pad), (0, 0)))
        indices = jnp.pad(indices, ((0, 0), (0, g_pad), (0, 0)))
    vp = _pad_to(values, bn, 0)
    ip = _pad_to(indices, bn, 0)
    out = _nm.nm_spmm(
        xp, vp, ip, m_group=m_group, bm=bm, bn=bn, bg=bg, interpret=interpret
    )
    return out[:m, :n]


def compress_nm_weights(w: np.ndarray, n_keep: int, m: int):
    """Host-side packer: dense (N, K) -> (values, indices) for nm_spmm."""
    vals, idx = nm_compress(np.asarray(w), n_keep, m)
    return jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
