"""Baseline dense int8 x int8 -> int32 matmul Pallas kernel.

The conventional quantized matmul PQS improves on: partial products
accumulate into a WIDE int32 register (what the MXU natively provides).
Grid (M/bm, N/bn, K/bk) with the K axis innermost; the output block is
revisited across K steps and accumulated in place (standard Pallas
reduction pattern). Block shapes default to MXU-aligned 128x128 tiles
with a 512-deep K slab: VMEM footprint =
bm*bk + bk*bn (int8) + bm*bn (int32) ~= 192 KiB, well inside v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(jnp.int32)
    wb = w_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        xb, wb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def quant_matmul(
    x: jax.Array,  # (M, K) int8
    w: jax.Array,  # (K, N) int8
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)
