"""Pure-jnp oracles for every kernel — the bit-exact reference semantics.

Each oracle mirrors its kernel's numerics exactly (same tile order, same
saturation points), built on the core overflow library so the kernels, the
paper benchmarks, and the analysis tooling all share one definition of
"sorted tiled accumulation".
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sorted_accum import (
    monotone_accumulate,
    sorted_order,
    tiled_seq_order,
)


def quant_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M,K) int8 x (K,N) int8 -> (M,N) int32 wide accumulation."""
    return jax.lax.dot_general(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@partial(jax.jit, static_argnames=("acc_bits", "rounds", "k_tile"))
def sorted_matmul_ref(
    x: jax.Array,  # (M, K) int8
    w: jax.Array,  # (N, K) int8
    acc_bits: int = 16,
    rounds: int = 1,
    k_tile: int = 256,
) -> jax.Array:
    """Oracle for kernels.sorted_matmul: per-K-tile sorted pairs in natural
    tile order, stepwise saturating accumulation at acc_bits."""
    prods = x.astype(jnp.int32)[:, None, :] * w.astype(jnp.int32)[None, :, :]
    ordered = tiled_seq_order(prods, k_tile, rounds)
    acc, _ = monotone_accumulate(ordered, acc_bits, saturate=True)
    return acc


@partial(jax.jit, static_argnames=("acc_bits",))
def clip_matmul_ref(
    x: jax.Array, w: jax.Array, acc_bits: int = 16
) -> jax.Array:
    """Oracle for kernels.clip_matmul: natural order, saturating adds."""
    prods = x.astype(jnp.int32)[:, None, :] * w.astype(jnp.int32)[None, :, :]
    acc, _ = monotone_accumulate(prods, acc_bits, saturate=True)
    return acc


def nm_spmm_ref(
    x: jax.Array,  # (M, K) int8
    values: np.ndarray,  # (N, G, n_keep)
    indices: np.ndarray,  # (N, G, n_keep)
    m_group: int,
) -> jax.Array:
    """Oracle for kernels.nm_spmm: decompress then wide matmul."""
    n, g, n_keep = values.shape
    dense = jnp.zeros((n, g, m_group), jnp.int32)
    dense = dense.at[
        jnp.arange(n)[:, None, None],
        jnp.arange(g)[None, :, None],
        jnp.asarray(indices),
    ].add(jnp.asarray(values, jnp.int32))
    dense = dense.reshape(n, g * m_group)
    return jax.lax.dot_general(
        x.astype(jnp.int32),
        dense,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def sorted_dot_ref(
    prods: jax.Array, acc_bits: int, rounds: int = 1
) -> tuple[jax.Array, jax.Array]:
    """Single-dot oracle: (value, overflowed) after sorting + saturation."""
    ordered = sorted_order(prods, rounds)
    return monotone_accumulate(ordered, acc_bits, saturate=True)
