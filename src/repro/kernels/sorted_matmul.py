"""PQS accumulation-policy matmul kernels (the paper's core, TPU-adapted).

Computes Z = X Wᵀ in int8 with a *simulated narrow accumulator* under
every accumulation policy of ``core.overflow``:

  wide             — int32 MXU accumulation (the conventional baseline)
  clip             — natural order, saturating add at every step
  wrap             — natural order, two's-complement wraparound at p bits
  sorted_tiled_seq — per-k_tile split/sort/pairwise-add rounds on a
                     bitonic network (kernels/bitonic.py), tiles in
                     natural order, stepwise saturation (paper §6: "tile
                     size k=256 still eliminates 99% of transients")
  sorted           — one full-K sorting stage, then stepwise saturation
  sorted_tiled     — per-tile sort + sum-ranked tile pairing/interleave
                     (this repo's beyond-paper refinement)

``seq_policy_matmul`` streams K through the grid (k innermost, output
block revisited — the blocked-matmul-compatible form); ``sort_matmul``
keeps the full K axis VMEM-resident because its accumulation order is a
global permutation of K. The sort itself is vectorized over the (bm, bn)
output block on the VPU.

VMEM budget: the (bm, bn, bk) partial-product cube dominates at
bm*bn*bk*4 bytes — default (8, 128, 256) = 1 MiB, inside v5e's VMEM
alongside the x/w slabs. ``sort_matmul`` is the *legacy one-pass* form
of the global-permutation policies (bk = the whole padded K, cube fully
resident): ``kernels/ops.policy_matmul`` uses it up to
``ops.MAX_RESIDENT_K`` and routes larger K to the two-pass streaming
pipeline in ``kernels/sorted_stream.py``, which bounds VMEM by the int8
operand slabs instead of the cube (``ops.MAX_STREAM_K``).

Semantics are bit-exact with the pure-jnp oracles (``ref.py`` /
``core.overflow.accumulate``): stepwise saturation, not cumsum-then-clip,
so a mid-tile excursion clips exactly like MCU saturation arithmetic
would. ``sorted_tiled``'s pairing permutation is literally
``core.sorted_accum.tiled_sorted_order`` with the bitonic sort plugged
in, so both backends share one definition of the order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import qrange
from repro.core.sorted_accum import tiled_sorted_order
from repro.kernels.bitonic import sorted_order_bitonic

SEQ_POLICIES = ("wide", "clip", "wrap", "sorted_tiled_seq")
SORT_POLICIES = ("sorted", "sorted_tiled")


def _stepwise(ordered: jax.Array, init: jax.Array, acc_bits: int,
              saturate: bool) -> jax.Array:
    """Accumulate (bm, bn, k) values into (bm, bn) p-bit registers, one
    saturating/wrapping add per step — mirrors monotone_accumulate."""
    qmin, qmax = qrange(acc_bits)
    span = jnp.int32(2**acc_bits)

    def body(t, acc):
        nxt = acc + ordered[:, :, t]
        if saturate:
            return jnp.clip(nxt, qmin, qmax)
        return jnp.mod(nxt - qmin, span) + qmin

    return jax.lax.fori_loop(0, ordered.shape[-1], body, init)


def _seq_body(xb, wb, o_ref, *, policy: str, acc_bits: int, rounds: int):
    """One K-streaming grid step on int32 blocks xb (bm, bk) / wb
    (bn, bk). THE single definition of the seq-policy semantics — the
    dense kernel and the N:M compressed kernel (kernels/nm_spmm.py)
    differ only in how wb reaches VMEM, so a semantics change here
    cannot desynchronize the two storage forms."""
    if policy == "wide":
        o_ref[...] += jax.lax.dot_general(
            xb, wb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        return
    prods = xb[:, None, :] * wb[None, :, :]  # (bm, bn, bk) partial products
    if policy == "sorted_tiled_seq":
        prods = sorted_order_bitonic(prods, rounds)  # sort stage (VPU)
    o_ref[...] = _stepwise(prods, o_ref[...], acc_bits,
                           saturate=(policy != "wrap"))


def _seq_kernel(x_ref, w_ref, o_ref, *, policy: str, acc_bits: int,
                rounds: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _seq_body(x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
              o_ref, policy=policy, acc_bits=acc_bits, rounds=rounds)


def _sort_body(xb, wb, o_ref, *, policy: str, acc_bits: int, k_tile: int,
               rounds: int):
    """Full-K-resident global-sort step on int32 slabs xb (bm, K) / wb
    (bn, K) — shared by the dense and N:M compressed kernels."""
    prods = xb[:, None, :] * wb[None, :, :]  # (bm, bn, K)
    if policy == "sorted":
        ordered = sorted_order_bitonic(prods, rounds)
    else:  # sorted_tiled: shared pairing permutation, bitonic intra-tile
        ordered = tiled_sorted_order(prods, k_tile, rounds,
                                     order_fn=sorted_order_bitonic)
    o_ref[...] = _stepwise(ordered, jnp.zeros_like(o_ref), acc_bits,
                           saturate=True)


def _sort_kernel(x_ref, w_ref, o_ref, *, policy: str, acc_bits: int,
                 k_tile: int, rounds: int):
    _sort_body(x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
               o_ref, policy=policy, acc_bits=acc_bits, k_tile=k_tile,
               rounds=rounds)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "rounds", "bm", "bn", "bk",
                     "interpret"),
)
def seq_policy_matmul(
    x: jax.Array,  # (M, K) int8/int32-carrier activations
    w: jax.Array,  # (N, K) weights (rows = output channels)
    *,
    policy: str = "clip",
    acc_bits: int = 16,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """K-streaming policies: wide | clip | wrap | sorted_tiled_seq.

    For sorted_tiled_seq, bk IS the paper's k_tile (the sort never sees
    across a block boundary) and must be a power of two for the bitonic
    network.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    assert policy in SEQ_POLICIES, policy
    if policy == "sorted_tiled_seq":
        assert bk & (bk - 1) == 0, f"bk must be a power of 2, got {bk}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_seq_kernel, policy=policy, acc_bits=acc_bits,
                             rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "acc_bits", "k_tile", "rounds", "bm", "bn",
                     "interpret"),
)
def sort_matmul(
    x: jax.Array,  # (M, K) int
    w: jax.Array,  # (N, K) int
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Global-permutation policies: sorted | sorted_tiled (full K resident).

    ``sorted`` requires K to be a power of two (one bitonic stage over the
    whole axis); ``sorted_tiled`` requires K % k_tile == 0 with k_tile a
    power of two. Callers (kernels/ops.py) zero-pad — zeros are
    sign-neutral and additively inert through sort and saturation.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        assert k & (k - 1) == 0, f"K must be a power of 2, got {k}"
    else:
        assert k_tile & (k_tile - 1) == 0 and k % k_tile == 0, (k, k_tile)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_sort_kernel, policy=policy, acc_bits=acc_bits,
                             k_tile=k_tile, rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


def sorted_matmul(
    x: jax.Array,  # (M, K) int8 activations
    w: jax.Array,  # (N, K) int8 weights (rows = output channels)
    *,
    acc_bits: int = 16,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(M, N) int32 carrier holding acc_bits-bit saturated dot products
    under the sorted_tiled_seq policy (bk = k_tile)."""
    return seq_policy_matmul(
        x, w, policy="sorted_tiled_seq", acc_bits=acc_bits, rounds=rounds,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )


def clip_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    acc_bits: int = 16,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Clipping baseline: natural order, saturating adds."""
    return seq_policy_matmul(
        x, w, policy="clip", acc_bits=acc_bits,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
