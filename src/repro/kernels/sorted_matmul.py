"""PQS sorted-accumulation matmul kernel (the paper's core, TPU-adapted).

Computes Z = X Wᵀ in int8 with a *simulated narrow accumulator*: each
output element's K partial products are processed k_tile at a time; within
a tile they pass one (or more) split/sort/pairwise-add rounds on a bitonic
sorting network (kernels/bitonic.py), then the re-ordered values are
accumulated stepwise into a p-bit saturating register. This is the paper
§6 tiled variant ("tile size k=256 still eliminates 99% of transient
overflows") — the form compatible with blocked matmul hardware — with the
sort itself vectorized over the (bm, bn) output block on the VPU.

VMEM budget: the (bm, bn, bk) partial-product cube dominates at
bm*bn*bk*4 bytes — default (8, 128, 256) = 1 MiB, inside v5e's 128 MiB
VMEM alongside the x/w slabs.

Semantics are bit-exact with the pure-jnp oracle
``ref.sorted_matmul_ref`` (= core.overflow 'sorted_tiled_seq' policy):
stepwise saturation, not cumsum-then-clip, so a mid-tile excursion clips
exactly like MCU saturation arithmetic would.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import qrange
from repro.kernels.bitonic import sorted_order_bitonic


def _kernel(x_ref, w_ref, o_ref, *, acc_bits: int, rounds: int):
    qmin, qmax = qrange(acc_bits)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(jnp.int32)  # (bm, bk)
    wb = w_ref[...].astype(jnp.int32)  # (bn, bk)
    prods = xb[:, None, :] * wb[None, :, :]  # (bm, bn, bk) partial products
    ordered = sorted_order_bitonic(prods, rounds)  # sort stage (VPU)

    def body(t, acc):
        nxt = acc + ordered[:, :, t]
        return jnp.clip(nxt, qmin, qmax)  # saturating add, every step

    o_ref[...] = jax.lax.fori_loop(0, ordered.shape[-1], body, o_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "rounds", "bm", "bn", "bk", "interpret"),
)
def sorted_matmul(
    x: jax.Array,  # (M, K) int8 activations
    w: jax.Array,  # (N, K) int8 weights (rows = output channels)
    *,
    acc_bits: int = 16,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(M, N) int32 carrier holding acc_bits-bit saturated dot products."""
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    assert bk & (bk - 1) == 0, f"bk must be a power of 2 (bitonic), got {bk}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_kernel, acc_bits=acc_bits, rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


def _clip_kernel(x_ref, w_ref, o_ref, *, acc_bits: int):
    """Clipping baseline: same tiling, natural order, saturating adds."""
    qmin, qmax = qrange(acc_bits)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(jnp.int32)
    wb = w_ref[...].astype(jnp.int32)
    prods = xb[:, None, :] * wb[None, :, :]

    def body(t, acc):
        return jnp.clip(acc + prods[:, :, t], qmin, qmax)

    o_ref[...] = jax.lax.fori_loop(0, prods.shape[-1], body, o_ref[...])


@functools.partial(
    jax.jit, static_argnames=("acc_bits", "bm", "bn", "bk", "interpret")
)
def clip_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    acc_bits: int = 16,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_clip_kernel, acc_bits=acc_bits)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)
