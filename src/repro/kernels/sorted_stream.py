"""Two-pass K-streaming kernels for the global-permutation sort policies.

The legacy ``sorted_matmul.sort_matmul`` keeps the whole (bm, bn, K)
partial-product cube VMEM-resident, which caps compiled calls at
``kernels.ops.MAX_RESIDENT_K``. The kernels here replace the cube with
the operand *slabs* (int8, 4x narrower than the int32 products and bn x
smaller than the cube) plus an O(k_tile) working set, lifting the K
ceiling from 4096 to ``kernels.ops.MAX_STREAM_K`` (65536 by default):

``sorted_tiled`` — two genuine passes over K:

  pass 1  ``tile_sums_matmul``: stream k_tiles through the grid (MXU dot
          per tile) into a (M, N, K/k_tile) tile-sum statistic. Sorting
          a tile never changes its sum, so these raw-product sums equal
          the oracle's post-sort sums exactly (int32 addition is
          associative; k_tile * 127^2 is far below 2^31).
  pairing ``core.sorted_accum.pair_permutation`` over the tile sums —
          literally the oracle's rank-and-interleave rule, evaluated
          once outside the kernels on the small (M, N, n_tiles) array.
  pass 2  ``paired_accum_matmul``: revisit K in *paired* order. The
          pairing is per output element (each (m, n) dot ranks its own
          tile sums), so a permutation-driven BlockSpec index map —
          which is necessarily uniform across the (bm, bn) block —
          cannot realize it. Instead the int8 operand slabs stay
          resident, and each pair slot gathers its two k_tiles per
          element (``take_along_axis`` over the K axis), bitonic-sorts
          them intra-tile, element-interleaves (a0, b0, a1, b1, ...)
          and saturating-accumulates stepwise. Only the (bm, bn,
          2*k_tile) interleaved pair is ever materialized as products.

``sorted`` — the order is one split/sort/pair stage over the *whole* K
axis per element, so the product cube genuinely must exist to be
sorted; ``chunked_sort_matmul`` bounds it by chunking the bn axis
inside the kernel ((bm, bc, K) live at a time, bc chosen so the chunk
stays under ``CUBE_BUDGET`` bytes) while the int8 slabs stay resident.

VMEM budget (pass 2, defaults bm=8, bn=128, k_tile=256, K=32768):
x slab 8*32Ki = 256 KiB int8, w slab 128*32Ki = 4 MiB int8, perm block
8*128*128*4 = 512 KiB, working pair 8*128*512*4 = 2 MiB — ~7 MiB total
vs the 128 MiB cube the one-pass kernel would need.

HBM budget: the tile-sum statistic and its permutation are
(M, N, K/k_tile) int32 each — per-M-row cost 8 * N * K/k_tile bytes.
``core.dispatch.pqs_dot`` bounds it by chunking M (its
``_SORT_STATS_BUDGET``); direct callers of ``stream_sort_matmul`` with
large M*N should chunk M themselves.

Semantics are bit-exact with ``core.overflow.accumulate`` (the jnp
oracle) and with the legacy one-pass ``sort_matmul`` where that still
runs; ``tests/test_sorted_stream.py`` sweeps both, including K well
above ``MAX_RESIDENT_K``. Mosaic lowering of the per-element gather on
real TPUs is untested (same standing caveat as the in-kernel argsort of
the one-pass kernel); interpret mode is exact everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sorted_accum import pair_permutation
from repro.kernels.bitonic import sorted_order_bitonic
from repro.kernels.nm_spmm import (
    _next_pow2,
    expand_nm_slab,
    gather_nm_products,
    pad_last_pow2,
)
from repro.kernels.sorted_matmul import SORT_POLICIES, _stepwise

# Largest (bm, bc, K) int32 product chunk chunked_sort_matmul keeps live
# while sorting (the bitonic network roughly doubles it with temporaries).
CUBE_BUDGET = 4 * 1024 * 1024


def _tile_sums_kernel(x_ref, w_ref, o_ref):
    xb = x_ref[...].astype(jnp.int32)  # (bm, k_tile)
    wb = w_ref[...].astype(jnp.int32)  # (bn, k_tile)
    o_ref[:, :, 0] = jax.lax.dot_general(
        xb, wb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit, static_argnames=("k_tile", "bm", "bn", "interpret")
)
def tile_sums_matmul(
    x: jax.Array,  # (M, K) int
    w: jax.Array,  # (N, K) int
    *,
    k_tile: int = 256,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pass 1: per-element per-k_tile partial sums, (M, N, K/k_tile) int32.

    One MXU dot per (i, j, t) grid step — K streams through the grid, so
    VMEM holds only the (bm, k_tile) / (bn, k_tile) slabs plus a
    (bm, bn, 1) output block.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2 and k % k_tile == 0, (x.shape, w.shape, k_tile)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    return pl.pallas_call(
        _tile_sums_kernel,
        grid=(m // bm, n // bn, n_tiles),
        in_specs=[
            pl.BlockSpec((bm, k_tile), lambda i, j, t: (i, t)),
            pl.BlockSpec((bn, k_tile), lambda i, j, t: (j, t)),
        ],
        out_specs=pl.BlockSpec((bm, bn, 1), lambda i, j, t: (i, j, t)),
        out_shape=jax.ShapeDtypeStruct((m, n, n_tiles), jnp.int32),
        interpret=interpret,
    )(x, w)


def _nm_tile_sums_kernel(x_ref, v_ref, i_ref, o_ref, *, m_group: int):
    xb = x_ref[...].astype(jnp.int32)  # (bm, k_tile)
    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, k_tile)
    o_ref[:, :, 0] = jax.lax.dot_general(
        xb, wb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(
    jax.jit, static_argnames=("m_group", "k_tile", "bm", "bn", "interpret")
)
def nm_tile_sums_matmul(
    x: jax.Array,  # (M, K) int, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    m_group: int = 16,
    k_tile: int = 256,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pass-1 hook for compressed storage: per-k_tile partial sums,
    (M, N, K/k_tile) int32, streamed from the COMPRESSED slabs.

    Sorting a tile never changes its sum and pruned positions are zero,
    so the kept-only dot per tile equals the dense tile sum exactly —
    the pairing permutation downstream is therefore identical to the
    dense pipeline's while HBM traffic for weights drops by ~n_keep/m
    (the paper's pruning payoff, measured in `pqs_dot(with_census=True)`
    overflow counts as shorter effective K per tile).
    """
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group and k % k_tile == 0, (x.shape, values.shape,
                                                 m_group, k_tile)
    assert k_tile % m_group == 0, (k_tile, m_group)
    bg = k_tile // m_group
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    kern = functools.partial(_nm_tile_sums_kernel, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, n_tiles),
        in_specs=[
            pl.BlockSpec((bm, k_tile), lambda i, j, t: (i, t)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, t: (j, t, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, t: (j, t, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn, 1), lambda i, j, t: (i, j, t)),
        out_shape=jax.ShapeDtypeStruct((m, n, n_tiles), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _gather_tile(xb, wb, tile_idx, k_tile):
    """Products of one k_tile per element: (bm, bn) tile indices ->
    (bm, bn, k_tile) int32. xb is (bm, K), wb is (bn, K)."""
    ks = tile_idx[:, :, None] * k_tile + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, k_tile), 2
    )  # (bm, bn, k_tile) absolute K offsets
    xg = jnp.take_along_axis(xb[:, None, :], ks, axis=-1)
    wg = jnp.take_along_axis(wb[None, :, :], ks, axis=-1)
    return xg * wg


def _paired_body(xb, wb, pm, o_ref, acc_bits: int, k_tile: int,
                 rounds: int):
    """Shared pass-2 body: accumulate K in per-element paired order.

    xb (bm, K) / wb (bn, K) int32 slabs, pm (bm, bn, n_tiles) pairing —
    the dense and nm kernels differ only in how wb reaches VMEM."""
    n_tiles = pm.shape[-1]
    bm, bn = xb.shape[0], wb.shape[0]

    def slot(s, acc):
        pa = _gather_tile(xb, wb, pm[:, :, 2 * s], k_tile)
        pb = _gather_tile(xb, wb, pm[:, :, 2 * s + 1], k_tile)
        pa = sorted_order_bitonic(pa, rounds)
        pb = sorted_order_bitonic(pb, rounds)
        inter = jnp.stack([pa, pb], axis=-1).reshape(bm, bn, 2 * k_tile)
        return _stepwise(inter, acc, acc_bits, saturate=True)

    acc = jax.lax.fori_loop(
        0, n_tiles // 2, slot, jnp.zeros_like(o_ref)
    )
    if n_tiles % 2:  # unpaired leftover tile rides last, un-interleaved
        tail = _gather_tile(xb, wb, pm[:, :, n_tiles - 1], k_tile)
        acc = _stepwise(sorted_order_bitonic(tail, rounds), acc, acc_bits,
                        saturate=True)
    o_ref[...] = acc


def _paired_kernel(x_ref, w_ref, p_ref, o_ref, *, acc_bits: int,
                   k_tile: int, rounds: int):
    xb = x_ref[...].astype(jnp.int32)  # (bm, K) slab
    wb = w_ref[...].astype(jnp.int32)  # (bn, K) slab
    pm = p_ref[...]  # (bm, bn, n_tiles) per-element pairing permutation
    _paired_body(xb, wb, pm, o_ref, acc_bits, k_tile, rounds)


def _nm_paired_kernel(x_ref, v_ref, i_ref, p_ref, o_ref, *, acc_bits: int,
                      k_tile: int, rounds: int, m_group: int):
    """Pass 2 fed by the compressed slab: HBM streams (bn, G, n_keep)
    values+indices instead of the (bn, K) dense rows; the one-hot expand
    rebuilds the dense slab in VMEM (bit-identical — pruned positions
    expand to zero) and the paired gather proceeds unchanged."""
    xb = x_ref[...].astype(jnp.int32)  # (bm, K) slab
    wb = expand_nm_slab(v_ref[...], i_ref[...], m_group)  # (bn, G*m)
    pm = p_ref[...]
    _paired_body(xb, wb, pm, o_ref, acc_bits, k_tile, rounds)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "k_tile", "rounds", "bm", "bn",
                     "interpret"),
)
def paired_accum_matmul(
    x: jax.Array,  # (M, K) int
    w: jax.Array,  # (N, K) int
    perm: jax.Array,  # (M, N, K/k_tile) int32 pairing permutation
    *,
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pass 2: accumulate K in per-element paired order, (M, N) int32."""
    m, k = x.shape
    n = w.shape[0]
    assert perm.shape == (m, n, k // k_tile), (perm.shape, (m, n, k, k_tile))
    assert k_tile & (k_tile - 1) == 0 and k % k_tile == 0, (k, k_tile)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    kern = functools.partial(_paired_kernel, acc_bits=acc_bits,
                             k_tile=k_tile, rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, bn, n_tiles), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w, perm)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "k_tile", "rounds", "m_group", "bm", "bn",
                     "interpret"),
)
def nm_paired_accum_matmul(
    x: jax.Array,  # (M, K) int, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    perm: jax.Array,  # (M, N, K/k_tile) int32 pairing permutation
    *,
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pass 2 on compressed storage: per-element paired accumulation."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (x.shape, values.shape, m_group)
    assert perm.shape == (m, n, k // k_tile), (perm.shape, (m, n, k, k_tile))
    assert k_tile & (k_tile - 1) == 0 and k % k_tile == 0, (k, k_tile)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    kern = functools.partial(_nm_paired_kernel, acc_bits=acc_bits,
                             k_tile=k_tile, rounds=rounds, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bm, bn, n_tiles), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices, perm)


def _sort_chunk_body(xb, wb, o_ref, c, bc, acc_bits: int, rounds: int):
    """Sort-and-accumulate one (bm, bc, K) cube chunk into o_ref's c-th
    column slice — shared by the dense and N:M compressed kernels (they
    differ only in how the (bc, K) weight chunk reaches VMEM)."""
    prods = xb[:, None, :] * wb[None, :, :]  # (bm, bc, K) live chunk
    ordered = sorted_order_bitonic(prods, rounds)
    o_ref[:, pl.ds(c * bc, bc)] = _stepwise(
        ordered, jnp.zeros((xb.shape[0], bc), jnp.int32), acc_bits,
        saturate=True,
    )


def _chunked_sort_kernel(x_ref, w_ref, o_ref, *, acc_bits: int, bc: int,
                         rounds: int):
    xb = x_ref[...].astype(jnp.int32)  # (bm, K) slab

    def chunk(c, _):
        wb = w_ref[pl.ds(c * bc, bc), :].astype(jnp.int32)  # (bc, K)
        _sort_chunk_body(xb, wb, o_ref, c, bc, acc_bits, rounds)
        return 0

    n_chunks = o_ref.shape[1] // bc
    jax.lax.fori_loop(0, n_chunks, chunk, 0)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "rounds", "bm", "bn", "bc", "interpret"),
)
def chunked_sort_matmul(
    x: jax.Array,  # (M, K) int, K a power of two
    w: jax.Array,  # (N, K) int
    *,
    acc_bits: int = 16,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    bc: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Full-K ``sorted`` policy with a bn-chunked product cube.

    The global sort needs all K products of an element live at once, but
    only for ``bc`` output channels at a time: (bm, bc, K) int32 must fit
    ``CUBE_BUDGET``; the (bm, K)/(bn, K) int8 slabs are what scale with K.
    """
    m, k = x.shape
    n = w.shape[0]
    assert k & (k - 1) == 0, f"K must be a power of 2, got {k}"
    assert m % bm == 0 and n % bn == 0 and bn % bc == 0, (m, n, bm, bn, bc)
    kern = functools.partial(_chunked_sort_kernel, acc_bits=acc_bits,
                             bc=bc, rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


def _nm_chunked_sort_kernel(x_ref, v_ref, i_ref, o_ref, *, acc_bits: int,
                            bc: int, rounds: int, m_group: int):
    """``sorted`` on compressed storage: expand only the bc-row slice of
    the compressed slab per chunk, so the live int32 working set stays
    (bm, bc, K) + (bc, K) — the dense kernel's budget."""
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp) slab (pre-padded)
    kp = xb.shape[1]

    def chunk(c, _):
        vc = v_ref[pl.ds(c * bc, bc), :, :]  # (bc, G, n_keep)
        ic = i_ref[pl.ds(c * bc, bc), :, :]
        wb = expand_nm_slab(vc, ic, m_group)  # (bc, G*m)
        if kp > wb.shape[1]:
            wb = jnp.pad(wb, ((0, 0), (0, kp - wb.shape[1])))
        _sort_chunk_body(xb, wb, o_ref, c, bc, acc_bits, rounds)
        return 0

    n_chunks = o_ref.shape[1] // bc
    jax.lax.fori_loop(0, n_chunks, chunk, 0)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "rounds", "m_group", "bm", "bn", "bc",
                     "interpret"),
)
def nm_chunked_sort_matmul(
    x: jax.Array,  # (M, kp) int, kp a power of two >= G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    acc_bits: int = 16,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    bc: int = 8,
    interpret: bool = False,
) -> jax.Array:
    m, kp = x.shape
    n, g, n_keep = values.shape
    assert g * m_group <= kp, (values.shape, m_group, kp)
    assert kp & (kp - 1) == 0, f"K must be a power of 2, got {kp}"
    assert m % bm == 0 and n % bn == 0 and bn % bc == 0, (m, n, bm, bn, bc)
    kern = functools.partial(_nm_chunked_sort_kernel, acc_bits=acc_bits,
                             bc=bc, rounds=rounds, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _sort_chunk(bm: int, bn: int, k: int) -> int:
    """Largest bc dividing bn with the (bm, bc, K) int32 chunk in budget."""
    for bc in range(bn, 1, -1):
        if bn % bc == 0 and bm * bc * k * 4 <= CUBE_BUDGET:
            return bc
    return 1


def stream_sort_matmul(
    x: jax.Array,  # (M, K) int — M, N multiples of bm, bn; K pre-padded
    w: jax.Array,  # (N, K) int
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Streaming entry point for ``sorted`` | ``sorted_tiled``.

    Same contract as ``sorted_matmul.sort_matmul`` (callers zero-pad; the
    padding rules are identical) but with slab-bounded VMEM, so
    ``kernels.ops.policy_matmul`` routes K above ``MAX_RESIDENT_K`` here.
    """
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        return chunked_sort_matmul(
            x, w, acc_bits=acc_bits, rounds=rounds, bm=bm, bn=bn,
            bc=_sort_chunk(bm, bn, x.shape[1]), interpret=interpret,
        )
    sums = tile_sums_matmul(x, w, k_tile=k_tile, bm=bm, bn=bn,
                            interpret=interpret)
    perm = jax.jit(pair_permutation)(sums)
    return paired_accum_matmul(
        x, w, perm, acc_bits=acc_bits, k_tile=k_tile, rounds=rounds,
        bm=bm, bn=bn, interpret=interpret,
    )


def nm_stream_sort_matmul(
    x: jax.Array,  # (M, kp) int — pre-padded like stream_sort_matmul's x
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Streaming global-sort entry point for N:M compressed storage.

    Same contract as ``stream_sort_matmul`` but the weight operand stays
    compressed end-to-end: pass 1 computes tile sums straight from the
    compressed slabs (``nm_tile_sums_matmul``), the pairing permutation
    is the shared ``pair_permutation``, and pass 2 / the chunked cube
    expand in VMEM only. Bit-identical to decompress-then-dense.
    """
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        return nm_chunked_sort_matmul(
            x, values, indices, acc_bits=acc_bits, rounds=rounds,
            m_group=m_group, bm=bm, bn=bn,
            bc=_sort_chunk(bm, bn, x.shape[1]), interpret=interpret,
        )
    sums = nm_tile_sums_matmul(x, values, indices, m_group=m_group,
                               k_tile=k_tile, bm=bm, bn=bn,
                               interpret=interpret)
    perm = jax.jit(pair_permutation)(sums)
    return nm_paired_accum_matmul(
        x, values, indices, perm, acc_bits=acc_bits, k_tile=k_tile,
        rounds=rounds, m_group=m_group, bm=bm, bn=bn, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# fused activation-gather variants: never rebuild the dense slab in VMEM
# ---------------------------------------------------------------------------


def _nm_gather_tile_sums_kernel(x_ref, v_ref, i_ref, o_ref, *,
                                m_group: int):
    """Pass 1 from kept products only: sum of the gathered (bm, bn,
    bg*n_keep) products per tile == the dense tile sum exactly (pruned
    positions contribute zero to any sum), so the downstream pairing
    permutation is identical to both the dense and expand pipelines'."""
    xb = x_ref[...].astype(jnp.int32)  # (bm, k_tile)
    prods = gather_nm_products(xb, v_ref[...], i_ref[...], m_group)
    o_ref[:, :, 0] = jnp.sum(prods, axis=-1)


@functools.partial(
    jax.jit, static_argnames=("m_group", "k_tile", "bm", "bn", "interpret")
)
def nm_gather_tile_sums(
    x: jax.Array,  # (M, K) int, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    m_group: int = 16,
    k_tile: int = 256,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gather twin of ``nm_tile_sums_matmul``: per-k_tile sums from
    n_keep/m of the products (a VPU gather-multiply-reduce instead of
    the expand path's dense MXU dot)."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group and k % k_tile == 0, (x.shape, values.shape,
                                                 m_group, k_tile)
    assert k_tile % m_group == 0, (k_tile, m_group)
    bg = k_tile // m_group
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    kern = functools.partial(_nm_gather_tile_sums_kernel, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, n_tiles),
        in_specs=[
            pl.BlockSpec((bm, k_tile), lambda i, j, t: (i, t)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, t: (j, t, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, t: (j, t, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn, 1), lambda i, j, t: (i, j, t)),
        out_shape=jax.ShapeDtypeStruct((m, n, n_tiles), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def _nm_gather_paired_kernel(x_ref, v_ref, i_ref, p_ref, o_ref, *,
                             acc_bits: int, k_tile: int, rounds: int,
                             m_group: int):
    """Pass 2 on kept products: each pair slot gathers its two
    *compressed* tiles (lc = (k_tile/m)*n_keep kept entries each, the
    per-element tile indices addressing the flattened (bn, G*n_keep)
    slab), pow2-pads, sorts, interleaves and stepwise-accumulates.

    Bit-exact vs the expand path because each sorted padded kept tile is
    the sorted dense tile's nonzero-covering prefix (positives descend /
    negatives ascend identically; the dense tail past the kept count is
    all zeros) and interleaved zero pairs are stepwise-inert.
    """
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp) slab
    vals = v_ref[...]  # (bn, G, n_keep)
    idx = i_ref[...]
    pm = p_ref[...]  # (bm, bn, n_tiles)
    bn, g, n_keep = vals.shape
    bm = xb.shape[0]
    n_tiles = pm.shape[-1]
    lc = (k_tile // m_group) * n_keep  # kept entries per compressed tile
    base = jax.lax.broadcasted_iota(
        jnp.int32, (bn, g, n_keep), 1) * m_group
    posd = (idx.astype(jnp.int32) + base).reshape(bn, g * n_keep)
    vflat = vals.reshape(bn, g * n_keep).astype(jnp.int32)

    def ctile(t_idx):
        """(bm, bn) tile indices -> pow2-padded (bm, bn, lp) kept
        products of that k_tile."""
        cs = t_idx[:, :, None] * lc + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, lc), 2
        )  # (bm, bn, lc) offsets into the flat compressed axis
        cs = jnp.broadcast_to(cs, (bm, bn, lc))
        wg = jnp.take_along_axis(
            jnp.broadcast_to(vflat[None], (bm, bn, g * n_keep)), cs, axis=-1)
        pg = jnp.take_along_axis(
            jnp.broadcast_to(posd[None], (bm, bn, g * n_keep)), cs, axis=-1)
        xg = jnp.take_along_axis(
            jnp.broadcast_to(xb[:, None, :], (bm, bn, xb.shape[1])),
            pg, axis=-1)
        return pad_last_pow2(xg * wg)

    lp = _next_pow2(lc)

    def slot(s, acc):
        pa = sorted_order_bitonic(ctile(pm[:, :, 2 * s]), rounds)
        pb = sorted_order_bitonic(ctile(pm[:, :, 2 * s + 1]), rounds)
        inter = jnp.stack([pa, pb], axis=-1).reshape(bm, bn, 2 * lp)
        return _stepwise(inter, acc, acc_bits, saturate=True)

    acc = jax.lax.fori_loop(0, n_tiles // 2, slot, jnp.zeros_like(o_ref))
    if n_tiles % 2:  # unpaired leftover tile rides last, un-interleaved
        tail = sorted_order_bitonic(ctile(pm[:, :, n_tiles - 1]), rounds)
        acc = _stepwise(tail, acc, acc_bits, saturate=True)
    o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "k_tile", "rounds", "m_group", "bm", "bn",
                     "interpret"),
)
def nm_gather_paired_accum_matmul(
    x: jax.Array,  # (M, K) int, K = G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    perm: jax.Array,  # (M, N, K/k_tile) int32 pairing permutation
    *,
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gather twin of ``nm_paired_accum_matmul``: the working pair is
    (bm, bn, 2*next_pow2((k_tile/m)*n_keep)) int32 — n_keep/m of the
    expand path's (bm, bn, 2*k_tile) — and no dense slab is rebuilt."""
    m, k = x.shape
    n, g, n_keep = values.shape
    assert k == g * m_group, (x.shape, values.shape, m_group)
    assert perm.shape == (m, n, k // k_tile), (perm.shape, (m, n, k, k_tile))
    assert k_tile & (k_tile - 1) == 0 and k % k_tile == 0, (k, k_tile)
    assert k_tile % m_group == 0, (k_tile, m_group)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_tiles = k // k_tile
    kern = functools.partial(_nm_gather_paired_kernel, acc_bits=acc_bits,
                             k_tile=k_tile, rounds=rounds, m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bm, bn, n_tiles), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices, perm)


def _nm_gather_chunked_sort_kernel(x_ref, v_ref, i_ref, o_ref, *,
                                   acc_bits: int, bc: int, rounds: int,
                                   m_group: int):
    """``sorted`` on kept products: per bc-chunk, gather the chunk rows'
    kept products ((bm, bc, G*n_keep) instead of (bm, bc, kp)), pow2-pad,
    sort, stepwise-accumulate. The sorted kept stream is the sorted
    dense stream's nonzero-covering prefix, so saturation matches."""
    xb = x_ref[...].astype(jnp.int32)  # (bm, kp) slab (pre-padded)

    def chunk(c, _):
        vc = v_ref[pl.ds(c * bc, bc), :, :]  # (bc, G, n_keep)
        ic = i_ref[pl.ds(c * bc, bc), :, :]
        prods = gather_nm_products(xb, vc, ic, m_group)
        ordered = sorted_order_bitonic(pad_last_pow2(prods), rounds)
        o_ref[:, pl.ds(c * bc, bc)] = _stepwise(
            ordered, jnp.zeros((xb.shape[0], bc), jnp.int32), acc_bits,
            saturate=True,
        )
        return 0

    n_chunks = o_ref.shape[1] // bc
    jax.lax.fori_loop(0, n_chunks, chunk, 0)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "rounds", "m_group", "bm", "bn", "bc",
                     "interpret"),
)
def nm_gather_chunked_sort_matmul(
    x: jax.Array,  # (M, kp) int, kp a power of two >= G * m_group
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    acc_bits: int = 16,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    bc: int = 8,
    interpret: bool = False,
) -> jax.Array:
    m, kp = x.shape
    n, g, n_keep = values.shape
    assert g * m_group <= kp, (values.shape, m_group, kp)
    assert kp & (kp - 1) == 0, f"K must be a power of 2, got {kp}"
    assert m % bm == 0 and n % bn == 0 and bn % bc == 0, (m, n, bm, bn, bc)
    kern = functools.partial(_nm_gather_chunked_sort_kernel,
                             acc_bits=acc_bits, bc=bc, rounds=rounds,
                             m_group=m_group)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bn, g, n_keep), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, values, indices)


def nm_gather_stream_sort_matmul(
    x: jax.Array,  # (M, kp) int — pre-padded like stream_sort_matmul's x
    values: jax.Array,  # (N, G, n_keep) int8
    indices: jax.Array,  # (N, G, n_keep) int32
    *,
    policy: str = "sorted",
    acc_bits: int = 16,
    k_tile: int = 256,
    rounds: int = 1,
    m_group: int = 16,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gather twin of ``nm_stream_sort_matmul``: same contract, but no
    kernel ever rebuilds a dense weight slab — pass 1 sums gathered kept
    products, pass 2 / the chunked cube sort only kept products. The
    chunked ``sorted`` cube budget is sized by the *compressed* length,
    so bc (channels sorted at once) grows by ~m/n_keep."""
    assert policy in SORT_POLICIES, policy
    if policy == "sorted":
        _, g, n_keep = values.shape
        return nm_gather_chunked_sort_matmul(
            x, values, indices, acc_bits=acc_bits, rounds=rounds,
            m_group=m_group, bm=bm, bn=bn,
            bc=_sort_chunk(bm, bn, _next_pow2(g * n_keep)),
            interpret=interpret,
        )
    sums = nm_gather_tile_sums(x, values, indices, m_group=m_group,
                               k_tile=k_tile, bm=bm, bn=bn,
                               interpret=interpret)
    perm = jax.jit(pair_permutation)(sums)
    return nm_gather_paired_accum_matmul(
        x, values, indices, perm, acc_bits=acc_bits, k_tile=k_tile,
        rounds=rounds, m_group=m_group, bm=bm, bn=bn, interpret=interpret,
    )
