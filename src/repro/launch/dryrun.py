import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a successful
``.lower().compile()`` on the 256-chip single-pod mesh and the 512-chip
2-pod mesh means every sharding constraint, collective, and memory
placement is accepted by the SPMD partitioner. Captures per cell:

  - memory_analysis()      : per-device bytes (argument/output/temp/peak)
  - cost_analysis()        : per-device HLO flops + bytes accessed (NB:
                             while bodies counted once — see probe below)
  - collective byte census : trip-count-weighted parse of the partitioned
                             HLO call graph (launch/hlo_census.py)
  - FLOP probe             : a second, UNROLLED + unchunked-attention
                             lowering on one device whose
                             lowered.cost_analysis() gives trip-exact
                             *global* HLO flops (no compile, no alloc)

Roofline terms (benchmarks/roofline.py) combine these per DESIGN.md §7.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2-pod pass
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.launch import sharding as shard_lib
from repro.launch.hlo_census import collective_census, loop_flop_multiplier
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    make_opt_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    params_specs,
    token_specs,
)
from repro.models.model import build_model


def _make_step(model, kind: str):
    if kind == "train":
        return make_train_step(model), (0, 1)
    if kind == "prefill":
        return make_prefill_step(model), ()
    return make_serve_step(model), (2,)


def _shardings_for(mesh, model, kind: str, shape, quantized: bool = False):
    """(in_shardings, out_shardings, arg_specs) for one cell's step.

    quantized=True lowers the step against PQS int8 QTensor weights
    (bits=8, 8:16 N:M) — the paper's storage format at production scale
    (§Perf iteration 6: decode weight-streaming).
    """
    p_specs = params_specs(model)
    if quantized:
        from repro.core.qtensor import quantize_tree

        p_specs = jax.eval_shape(
            lambda p: quantize_tree(p, bits=8, n_keep=8, m=16,
                                    min_size=1 << 16),
            p_specs,
        )
    moe_rep = bool(getattr(model.cfg, "moe_local_groups", False))
    serve_mode = quantized and kind == "decode"
    p_shard = shard_lib.params_shardings(mesh, p_specs,
                                         moe_replicate=moe_rep,
                                         serve_mode=serve_mode)
    if kind == "train":
        o_specs = make_opt_specs(model)
        o_shard = shard_lib.opt_shardings(mesh, o_specs)
        b_specs = batch_specs(model.cfg, shape)
        b_shard = shard_lib.batch_shardings(mesh, b_specs)
        ins = (p_shard, o_shard, b_shard)
        outs = (p_shard, o_shard, shard_lib.replicated(mesh))
        args = (p_specs, o_specs, b_specs)
    elif kind == "prefill":
        b_specs = batch_specs(model.cfg, shape)
        b_shard = shard_lib.batch_shardings(mesh, b_specs)
        logits_spec = jax.eval_shape(
            lambda p, b: model.forward(p, b), p_specs, b_specs
        )
        ins = (p_shard, b_shard)
        outs = shard_lib.logits_sharding(mesh, logits_spec.shape)
        args = (p_specs, b_specs)
    else:  # decode
        c_specs = cache_specs(model, shape)
        c_shard = shard_lib.cache_shardings(mesh, c_specs)
        t_specs = token_specs(model.cfg, shape)
        t_shard = shard_lib.batch_shardings(mesh, {"token": t_specs})["token"]
        logits_spec = jax.eval_shape(
            lambda p, t, c: model.decode(p, t, c)[0], p_specs, t_specs, c_specs
        )
        ins = (p_shard, t_shard, c_shard)
        outs = (shard_lib.logits_sharding(mesh, logits_spec.shape), c_shard)
        args = (p_specs, t_specs, c_specs)
    return ins, outs, args


def _cost_dict(cost) -> dict:
    """Normalize jax cost_analysis() output: some versions return a dict,
    others a per-program list of dicts (take the entry program's)."""
    if isinstance(cost, dict):
        return cost
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return {}


def _memory_dict(mem) -> dict:
    """Per-device memory stats; older xla builds lack peak_memory_in_bytes,
    in which case arguments + outputs + temps is the standard upper bound."""
    arg = getattr(mem, "argument_size_in_bytes", None)
    out = getattr(mem, "output_size_in_bytes", None)
    tmp = getattr(mem, "temp_size_in_bytes", None)
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None and None not in (arg, out, tmp):
        peak = arg + out + tmp
    return {
        "argument_bytes": arg,
        "output_bytes": out,
        "temp_bytes": tmp,
        "peak_bytes": peak,
    }


def probe_cost(arch: str, shape_name: str) -> dict[str, float]:
    """Trip-exact global HLO flops/bytes: unrolled scans, unchunked attention,
    single logical device, lower-only (never compiled, never allocated)."""
    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg, scan_unroll=True, attn_chunk_threshold=1 << 30
    )
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    step, _ = _make_step(model, shape.kind)
    if shape.kind == "train":
        args = (params_specs(model), make_opt_specs(model),
                batch_specs(cfg, shape))
    elif shape.kind == "prefill":
        args = (params_specs(model), batch_specs(cfg, shape))
    else:
        args = (params_specs(model), token_specs(cfg, shape),
                cache_specs(model, shape))
    lowered = jax.jit(step).lower(*args)
    cost = _cost_dict(lowered.cost_analysis())
    return {
        "global_flops": float(cost.get("flops", 0.0)),
        "global_bytes_hlo": float(cost.get("bytes accessed", 0.0)),
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    with_probe: bool = True,
    variant: Optional[str] = None,
) -> dict[str, Any]:
    cfg = get_config(arch)
    if variant and "sp" in variant:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if variant and "moe" in variant:
        cfg = dataclasses.replace(cfg, moe_local_groups=True)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind

    step, donate = _make_step(model, kind)
    quantized = bool(variant and "q8" in variant)
    ins, outs, args = _shardings_for(mesh, model, kind, shape,
                                     quantized=quantized)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            step, in_shardings=ins, out_shardings=outs, donate_argnums=donate
        )
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    census = collective_census(compiled.as_text())
    ndev = int(mesh.devices.size)

    result = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": ndev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _memory_dict(mem),
        "cost": {
            "flops_per_device_hlo": cost.get("flops"),
            "bytes_per_device_hlo": cost.get("bytes accessed"),
        },
        "collectives": census,
    }
    if with_probe:
        t0 = time.time()
        result["probe"] = probe_cost(arch, shape_name)
        result["probe"]["probe_s"] = round(time.time() - t0, 1)
        r = loop_flop_multiplier(
            result["probe"]["global_flops"],
            cost.get("flops") or 0.0,
            ndev,
        )
        result["loop_multiplier"] = r
        result["derived"] = {
            "flops_per_device": result["probe"]["global_flops"] / ndev,
            "bytes_per_device": (cost.get("bytes accessed") or 0.0) * r,
        }
    if verbose:
        d = result.get("derived", {})
        print(
            f"[dryrun] {arch:22s} {shape_name:12s} {result['mesh']:8s} OK "
            f"lower {t_lower:5.1f}s compile {t_compile:6.1f}s  "
            f"flops/dev {d.get('flops_per_device', 0):.3e}  "
            f"bytes/dev {d.get('bytes_per_device', 0):.3e}  "
            f"coll/dev {census['total_bytes_per_device']:.3e}B  "
            f"peak {result['memory']['peak_bytes'] or 0:.2e}B"
        , flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(arch)
        for shape_name in shapes:
            if shape_name not in cells_for(arch):
                print(f"[dryrun] skip {arch} x {shape_name} (see DESIGN.md)")
                continue
            for mp in meshes:
                try:
                    results.append(
                        run_cell(arch, shape_name, mp,
                                 with_probe=not args.no_probe)
                    )
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mp, repr(e)))
                    print(f"[dryrun] {arch} {shape_name} multi_pod={mp} "
                          f"FAILED: {e}", flush=True)

    out = args.out or os.path.join(
        os.path.dirname(__file__), "..", "..", "..",
        "benchmarks", "results", f"dryrun_{args.mesh}.json",
    )
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"[dryrun] wrote {len(results)} cells, {len(failures)} failures -> {out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
