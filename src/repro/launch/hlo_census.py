"""HLO-text analysis for the dry-run: trip-count-aware collective census.

``compiled.cost_analysis()`` and a naive text scan both count while-loop
bodies exactly ONCE, but scan-over-layers puts the FSDP all-gathers and TP
all-reduces *inside* the layer loop. This module parses the partitioned
HLO into its computation call graph, extracts each while loop's trip count
from its condition (`compare(iter, constant(N)), direction=LT`), and
multiplies every collective's operand bytes by the product of enclosing
trip counts — giving honest per-step collective traffic.

Shapes in partitioned HLO are per-device, so the returned byte counts are
per-device per step (the roofline collective term divides by link bw).
"""

from __future__ import annotations

import re
from typing import Any

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]"
)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> dict[str, dict[str, Any]]:
    """name -> {instrs: [(name, opname, result_bytes, operand_names, line)],
                whiles: [(cond, body)], calls: [comp...], is_entry: bool}"""
    comps: dict[str, dict[str, Any]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and (line.startswith("ENTRY") or not line.startswith(" ")):
            cur = hdr.group(1)
            comps[cur] = {
                "instrs": [],
                "whiles": [],
                "calls": [],
                "is_entry": line.strip().startswith("ENTRY"),
            }
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        op_m = _OPNAME_RE.search(rhs)
        opname = op_m.group(1) if op_m else ""
        result_bytes = _shape_bytes(rhs[: op_m.start()] if op_m else rhs)
        operands: list[str] = []
        if op_m:
            close = rhs.find(")", op_m.end())
            operands = re.findall(r"%([\w.\-]+)", rhs[op_m.end(): close])
        comps[cur]["instrs"].append((name, opname, result_bytes, operands, rhs))
        if opname == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            if cm and bm:
                comps[cur]["whiles"].append((cm.group(1), bm.group(1)))
        for key in ("to_apply", "true_computation", "false_computation"):
            for sub in re.findall(key + r"=%?([\w.\-]+)", rhs):
                comps[cur]["calls"].append(sub)
        bm = re.search(r"branches=\{([^}]*)\}", rhs)
        if bm:
            comps[cur]["calls"] += re.findall(r"%?([\w.\-]+)", bm.group(1))
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    """Extract N from `compare(x, constant(N)), direction=LT` heuristically."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    consts: dict[str, int] = {}
    for name, opname, _rb, _ops, rhs in comp["instrs"]:
        cm = re.search(r"constant\((\d+)\)", rhs)
        if cm:
            consts[name] = int(cm.group(1))
    for name, opname, _rb, ops, rhs in comp["instrs"]:
        if opname == "compare" and "direction=LT" in rhs:
            for o in ops:
                if o in consts:
                    return max(consts[o], 1)
    # fallback: largest integer constant in the condition
    return max(consts.values(), default=1)


def _result_bytes_index(comps: dict) -> dict[str, int]:
    idx: dict[str, int] = {}
    for comp in comps.values():
        for name, _op, rb, _ops, _rhs in comp["instrs"]:
            idx[name] = rb
    return idx


def collective_census(hlo: str) -> dict[str, Any]:
    """Trip-count-weighted per-device collective operand bytes."""
    comps = parse_computations(hlo)
    bytes_idx = _result_bytes_index(comps)

    # multipliers via BFS from entry computations
    mult: dict[str, float] = {}
    roots = [n for n, c in comps.items() if c["is_entry"]] or list(comps)[:1]
    stack = [(r, 1.0) for r in roots]
    while stack:
        name, m = stack.pop()
        if m <= mult.get(name, 0.0):
            continue
        mult[name] = m
        comp = comps.get(name)
        if comp is None:
            continue
        for cond, body in comp["whiles"]:
            trip = _trip_count(comps, cond)
            stack.append((body, m * trip))
            stack.append((cond, m * trip))
        for callee in comp["calls"]:
            stack.append((callee, m))

    per_op = {c: 0.0 for c in COLLECTIVES}
    link_op = {c: 0.0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    weighted_counts = {c: 0.0 for c in COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        for name, opname, _rb, operands, rhs in comp["instrs"]:
            base = opname
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base.endswith("-done"):
                continue
            if base not in COLLECTIVES:
                continue
            nbytes = sum(bytes_idx.get(o, 0) for o in operands)
            if nbytes == 0:  # operands untyped in text: use result size
                nbytes = _rb
            g = _group_size(rhs)
            per_op[base] += nbytes * m
            link_op[base] += _link_bytes(base, nbytes, g) * m
            counts[base] += 1
            weighted_counts[base] += m
    return {
        "bytes_per_device": per_op,
        "link_bytes_per_device": link_op,
        "counts": counts,
        "weighted_counts": weighted_counts,
        "total_bytes_per_device": sum(per_op.values()),
        "total_link_bytes_per_device": sum(link_op.values()),
    }


def _group_size(rhs: str) -> int:
    """Replica-group size of a collective (devices participating)."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rhs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = re.search(r"source_target_pairs=\{(.*?)\}\}", rhs)
    if m:  # collective-permute: pairwise
        return 2
    return 2


def _link_bytes(op: str, operand_bytes: float, g: int) -> float:
    """Per-device ICI link traffic model (ring algorithms).

    all-gather      : operand is the local shard s; each device forwards
                      s*(g-1) bytes  (full gathered size ~ s*g).
    reduce-scatter  : operand is the full buffer G; traffic G*(g-1)/g.
    all-reduce      : RS + AG: 2*G*(g-1)/g.
    all-to-all      : each device keeps 1/g, sends G*(g-1)/g.
    collective-perm : point-to-point: G.
    """
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return operand_bytes * (g - 1)
    if op == "reduce-scatter":
        return operand_bytes * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * operand_bytes * (g - 1) / g
    if op == "all-to-all":
        return operand_bytes * (g - 1) / g
    return operand_bytes


def loop_flop_multiplier(
    probe_global_flops: float, compiled_per_device_flops: float, ndev: int
) -> float:
    """Trip-count correction R: probe (unrolled, exact) over compiled
    (loop bodies once). Used to scale compiled per-device byte counts."""
    denom = max(compiled_per_device_flops * ndev, 1.0)
    return max(probe_global_flops / denom, 1.0)
