"""Production mesh construction + named-axis conventions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; tests see the
real 1-CPU topology).

Axes:
  single pod : (16, 16)        -> ("data", "model")       = 256 chips
  multi-pod  : (2, 16, 16)     -> ("pod", "data", "model") = 512 chips

"pod" and "data" together form the FSDP/batch axes (params and optimizer
state sharded over both; batch split over both); "model" is the tensor-
parallel axis. DCN (inter-pod) traffic rides only the "pod" axis —
gradient all-reduce — which is the standard multi-pod training topology.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_abstract_mesh(
    shape: Sequence[int], axes: Sequence[str]
) -> "jax.sharding.AbstractMesh":
    """Device-free mesh for sharding-rule evaluation, across jax versions.

    jax <= 0.4.x wants ``AbstractMesh(((name, size), ...))`` — a tuple of
    (name, size) pairs; newer jax takes ``AbstractMesh(shape, axes)``.
    Passing a bare shape tuple to the old signature raises
    ``TypeError: 'int' object is not iterable``, so construction is
    centralized here.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_host_serve_mesh(model_parallel: Optional[int] = None
                         ) -> jax.sharding.Mesh:
    """("data", "model") mesh over the local devices with a real TP axis.

    For multi-device CPU runs (XLA_FLAGS=--xla_force_host_platform_
    device_count=N) exercising the sharded ``pqs_dot`` serving path:
    puts as much of the device count on "model" as divides it (or the
    requested ``model_parallel``), the rest on "data".
    """
    n = len(jax.devices())
    tp = model_parallel or (n if n % 2 or n < 4 else n // 2)
    if n % tp:
        raise ValueError(f"model_parallel={tp} does not divide {n} devices")
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def shrink_serve_mesh(
    mesh: jax.sharding.Mesh,
    lost: int,
    model_parallel: Optional[int] = None,
) -> jax.sharding.Mesh:
    """("data", "model") mesh over the survivors after losing ``lost`` devices.

    Drops the last ``lost`` devices of ``mesh`` (the simulated failed
    members) and rebuilds the serve-mesh layout over what remains —
    same TP heuristic as ``make_host_serve_mesh`` unless
    ``model_parallel`` pins it. Pass the result to
    ``ServingFleet.remesh_engine`` / ``ServingEngine.remesh``; the
    sharded integer projections are bit-exact at any mesh shape, so
    decode resumes with identical tokens on the smaller fleet.
    """
    devices = list(mesh.devices.flatten())
    if not 0 < lost < len(devices):
        raise ValueError(
            f"lost={lost} must leave at least 1 of {len(devices)} devices"
        )
    import numpy as np

    survivors = devices[: len(devices) - lost]
    n = len(survivors)
    tp = model_parallel or (n if n % 2 or n < 4 else n // 2)
    if n % tp:
        raise ValueError(f"model_parallel={tp} does not divide {n} survivors")
    grid = np.asarray(survivors).reshape(n // tp, tp)
    return jax.sharding.Mesh(grid, ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"


def axis_size(mesh: jax.sharding.Mesh, *names: str) -> int:
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out
