"""Serving driver: batched prefill + continuous-batching decode.

CPU container: reduced configs, real token generation through the
ServingEngine. Production: the same ``serve_step`` is the object the
decode dry-run cells lower on the 256/512-chip meshes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 6 --max-new 16

Fault-tolerance drills run the same engine under the fleet supervisor:

  PYTHONPATH=src python -m repro.launch.serve --smoke --inject-fail 5,11 \
      --snapshot-every 3
  PYTHONPATH=src python -m repro.launch.serve --smoke --int-policy \
      sorted_tiled_seq --acc-bits 17 --census-threshold 0.01
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model, param_count
from repro.serving import CensusWatch, Request, ServingEngine, ServingFleet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-mode", default="batched",
                    choices=["batched", "steps"],
                    help="batched: one jitted prefill step per admission "
                         "cohort; steps: legacy token-by-token")
    ap.add_argument("--page-size", type=int, default=None,
                    help="enable the paged KV/SSM cache with this many "
                         "tokens per page (default: dense per-slot lanes)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV page-pool size (default: worst case, "
                         "slots x ceil(max_len / page_size))")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["int8"],
                    help="int8: quantize KV pages (needs --page-size)")
    ap.add_argument("--prefill-decode-ratio", type=int, default=0,
                    help="interleave: decode steps between prefill "
                         "micro-steps (0 = prefill immediately on admit)")
    # fault-tolerance drills: fleet supervision, failures, degradation
    ap.add_argument("--fleet", action="store_true",
                    help="drive the engine through ServingFleet + "
                         "ServeSupervisor instead of engine.drain")
    ap.add_argument("--inject-fail", default=None, metavar="STEPS",
                    help="comma-separated engine steps to crash at "
                         "(implies --fleet; recovery from snapshots)")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="fleet steps between serving-state snapshots")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist snapshots here via AsyncCheckpointer "
                         "(default: in-memory only)")
    ap.add_argument("--quota", type=int, default=None,
                    help="fleet admission quota (max in-flight requests)")
    ap.add_argument("--deadline", type=int, default=None,
                    help="per-request deadline in fleet steps; expired "
                         "requests are cancelled and retried with backoff")
    ap.add_argument("--int-policy", default=None,
                    choices=["wide", "clip", "wrap", "sorted",
                             "sorted_tiled", "sorted_tiled_seq"],
                    help="quantize weights and decode through integer "
                         "pqs_dot under this accumulator policy")
    ap.add_argument("--acc-bits", type=int, default=24,
                    help="accumulator width for --int-policy")
    ap.add_argument("--census-threshold", type=float, default=None,
                    help="enable census-triggered degradation at this "
                         "overflow rate (requires --int-policy)")
    ap.add_argument("--census-window", type=int, default=8,
                    help="decode steps per census window")
    ap.add_argument("--certify", action="store_true",
                    help="enforce the A2Q accumulator bound on the "
                         "quantized weights, certify every site "
                         "(core.certify), and serve certified sites "
                         "census-free (requires --int-policy)")
    ap.add_argument("--qat-steps", type=int, default=0,
                    help="accumulator-aware fine-tuning steps before "
                         "quantization (runtime.a2q_finetune; 0 = skip)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] arch={cfg.name} params={param_count(params):,} "
          f"slots={args.slots}")

    int_lin = None
    census_watch = None
    cert = None
    if args.int_policy:
        from repro.core import dispatch

        if args.qat_steps:
            from repro.runtime import QATConfig, a2q_finetune

            rng = np.random.default_rng(1)

            def next_batch(i: int) -> dict:
                tok = rng.integers(
                    0, cfg.vocab_size, size=(2, 16)
                ).astype(np.int32)
                return {"tokens": jnp.asarray(tok),
                        "labels": jnp.asarray(tok)}

            qcfg = QATConfig(acc_bits=args.acc_bits)
            params, history = a2q_finetune(
                model, params, next_batch, args.qat_steps, qcfg
            )
            print(f"[serve] qat: {args.qat_steps} steps, "
                  f"loss {history[0]['loss']:.4f} -> "
                  f"{history[-1]['loss']:.4f}, final census rates "
                  f"{ {k: round(v, 4) for k, v in history[-1]['census_rates'].items()} }")

        if args.certify:
            from repro.runtime import quantize_and_certify

            params, cert = quantize_and_certify(params, args.acc_bits)
            print("[serve] " + cert.summary().replace("\n", "\n[serve] "))
        else:
            from repro.core.qtensor import quantize_tree

            params = quantize_tree(
                params, bits=8, min_size=1 << 10, min_dim=16
            )
        int_lin = dispatch.IntegerLinConfig(
            policy=args.int_policy, acc_bits=args.acc_bits,
            k_tile=64, backend="jnp", certificate=cert,
        )
        if args.census_threshold is not None:
            census_watch = CensusWatch(
                threshold=args.census_threshold, window=args.census_window
            )
    elif args.census_threshold is not None:
        ap.error("--census-threshold requires --int-policy")
    elif args.certify or args.qat_steps:
        ap.error("--certify/--qat-steps require --int-policy")

    failure_injector = None
    if args.inject_fail:
        from repro.runtime import FailureInjector

        failure_injector = FailureInjector(
            {int(s) for s in args.inject_fail.split(",")}
        )
        args.fleet = True

    engine = ServingEngine(
        model, params, num_slots=args.slots, max_len=args.max_len,
        prefill_mode=args.prefill_mode,
        page_size=args.page_size, num_pages=args.num_pages,
        cache_dtype=args.cache_dtype or "float32",
        prefill_decode_ratio=args.prefill_decode_ratio,
        int_lin=int_lin, census_watch=census_watch,
        failure_injector=failure_injector,
    )
    if int_lin is not None:
        cal = {"tokens": jnp.asarray(
            (np.arange(32).reshape(2, 16) % cfg.vocab_size + 1) % cfg.vocab_size,
            jnp.int32,
        )}
        frozen = engine.calibrate([cal])
        print(f"[serve] integer decode: policy={args.int_policy} "
              f"acc_bits={args.acc_bits} calibrated {len(frozen)} sites"
              + (f", census threshold={args.census_threshold} "
                 f"window={args.census_window}" if census_watch else ""))
    if args.page_size:
        print(f"[serve] paged cache: page_size={args.page_size} "
              f"pages={engine.paging.num_pages} "
              f"dtype={args.cache_dtype or 'float32'} "
              f"footprint={engine.cache_nbytes() / 1e6:.3f} MB")
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=rng.integers(4, 12)
            ).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    if args.fleet:
        from repro.runtime import ServeSupervisor

        fleet = ServingFleet(
            snapshot_dir=args.snapshot_dir,
            snapshot_every=args.snapshot_every,
            default_deadline=args.deadline,
        )
        fleet.add_engine("m", engine, quota=args.quota)
        for r in reqs:
            fleet.submit("m", r)
        ServeSupervisor(fleet).run()
        fleet.wait()
    else:
        engine.drain(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"[serve] req {r.uid}: prompt {r.prompt.tolist()} -> "
              f"{r.output}")
    print(f"[serve] {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s, batched over {args.slots} slots)")
    st = engine.stats
    print(f"[serve] device steps: {st['prefill_steps']} prefill for "
          f"{st['cohorts']} admission cohorts ({args.prefill_mode}), "
          f"{st['decode_steps']} decode")
    if args.page_size:
        print(f"[serve] pages: peak {st['pages_peak']} in use, "
              f"queue_wait_steps={st['queue_wait_steps']}, "
              f"hol_skips={st['hol_skips']}")
    if args.fleet:
        fs = fleet.stats
        print(f"[serve] fleet: snapshots={fs['snapshots']} "
              f"recoveries={fs['recoveries']} "
              f"recovery_s={fs['recovery_s']:.3f} "
              f"deadline_cancels={fs['deadline_cancels']} "
              f"failed={fs['failed_requests']}")
        for ev in fleet.events:
            print(f"[serve] event: {ev}")
    if census_watch is not None:
        print(f"[serve] census: degrades={st['census_degrades']} "
              f"rates={ {k: round(v, 4) for k, v in engine.last_census_rates.items()} }")
        for ev in engine.events:
            print(f"[serve] event: {ev}")


if __name__ == "__main__":
    main()
