"""Serving driver: batched prefill + continuous-batching decode.

CPU container: reduced configs, real token generation through the
ServingEngine. Production: the same ``serve_step`` is the object the
decode dry-run cells lower on the 256/512-chip meshes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model, param_count
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-mode", default="batched",
                    choices=["batched", "steps"],
                    help="batched: one jitted prefill step per admission "
                         "cohort; steps: legacy token-by-token")
    ap.add_argument("--page-size", type=int, default=None,
                    help="enable the paged KV/SSM cache with this many "
                         "tokens per page (default: dense per-slot lanes)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV page-pool size (default: worst case, "
                         "slots x ceil(max_len / page_size))")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["int8"],
                    help="int8: quantize KV pages (needs --page-size)")
    ap.add_argument("--prefill-decode-ratio", type=int, default=0,
                    help="interleave: decode steps between prefill "
                         "micro-steps (0 = prefill immediately on admit)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] arch={cfg.name} params={param_count(params):,} "
          f"slots={args.slots}")

    engine = ServingEngine(
        model, params, num_slots=args.slots, max_len=args.max_len,
        prefill_mode=args.prefill_mode,
        page_size=args.page_size, num_pages=args.num_pages,
        cache_dtype=args.cache_dtype or "float32",
        prefill_decode_ratio=args.prefill_decode_ratio,
    )
    if args.page_size:
        print(f"[serve] paged cache: page_size={args.page_size} "
              f"pages={engine.paging.num_pages} "
              f"dtype={args.cache_dtype or 'float32'} "
              f"footprint={engine.cache_nbytes() / 1e6:.3f} MB")
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=rng.integers(4, 12)
            ).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    engine.drain(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"[serve] req {r.uid}: prompt {r.prompt.tolist()} -> "
              f"{r.output}")
    print(f"[serve] {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s, batched over {args.slots} slots)")
    st = engine.stats
    print(f"[serve] device steps: {st['prefill_steps']} prefill for "
          f"{st['cohorts']} admission cohorts ({args.prefill_mode}), "
          f"{st['decode_steps']} decode")
    if args.page_size:
        print(f"[serve] pages: peak {st['pages_peak']} in use, "
              f"queue_wait_steps={st['queue_wait_steps']}, "
              f"hol_skips={st['hol_skips']}")


if __name__ == "__main__":
    main()
