"""GSPMD sharding rules: params, optimizer state, batches, decode caches.

Strategy (DESIGN.md §6): 2-D sharding — every weight matrix is sharded on
the FSDP axes ("pod","data") over its input dim AND tensor-parallel on
"model" over its output dim; "out-type" projections (wo / w_out /
out_proj) are reversed so TP matmul chains avoid resharding. MoE expert
stacks get expert-parallel on "model" when the expert count divides it.

Every rule passes through ``sanitize``: any named axis that does not
evenly divide its dimension is dropped (right-to-left for tuple axes), so
odd vocabularies (49155), tiny expert counts, conv kernels etc. degrade to
coarser-but-correct shardings instead of failing to lower. This is what
makes one rule set hold across all 10 architectures x 4 shapes.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

AxisEntry = Any  # str | tuple[str, ...] | None

_OUT_TYPE = re.compile(r"(wo|w_out|out_proj|head)($|\W)")
_EXPERT = re.compile(r"(moe.*(w_gate|w_up|w_out))")


def _axis_div(mesh: Mesh, entry: AxisEntry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    out = 1
    for a in entry:
        out *= mesh.shape[a]
    return out


def sanitize(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop axis names that don't divide their dim (tuples: right-to-left)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    fixed: list[AxisEntry] = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            fixed.append(None)
            continue
        cand = entry if isinstance(entry, tuple) else (entry,)
        cand = tuple(a for a in cand if a in mesh.axis_names)
        while cand and dim % _axis_div(mesh, cand) != 0:
            cand = cand[:-1]
        fixed.append(None if not cand else (cand if len(cand) > 1 else cand[0]))
    return P(*fixed)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


_EMBED = re.compile(r"(^|/)(embed|pos_embed)$")


def param_spec(
    mesh: Mesh, path: str, shape: tuple[int, ...],
    moe_replicate: bool = False,
    serve_mode: bool = False,
) -> P:
    """Sharding rule for one parameter leaf.

    serve_mode drops the FSDP axes (weights replicated over "pod"/"data",
    sharded on "model" only): decode has no optimizer state to co-shard,
    and dropping FSDP removes every per-layer weight all-gather from the
    decode step (§Perf iteration 6b). Memory cost: params/TP per device.
    """
    fsdp = () if serve_mode else data_axes(mesh)
    nd = len(shape)
    if nd <= 1:
        return P()  # norms, biases, scalars: replicated
    if _EMBED.search(path):
        # Vocab/position tables shard on "model", NOT on the data axes:
        # the lookup's indices (tokens) are batch-sharded on "data", and
        # GSPMD resolves an operand/indices same-axis conflict by
        # REPLICATING the gather output — which silently un-shards the
        # batch for the whole network (found in §Perf iteration 1).
        return sanitize(mesh, P("model", None), shape)
    if path.endswith("head") or "/head" in path:
        # head (d, V): vocab on "model" matches the logits out-sharding
        # P(dp, None, "model") -> the head matmul needs no collective.
        return sanitize(mesh, P(None, "model"), shape)
    lead = [None] * (nd - 2)
    if _EXPERT.search(path) and nd >= 3:
        if moe_replicate:
            # local-groups dispatch: experts replicated over "model",
            # storage sharded over the data axes only (gathered per layer)
            spec = [None] * (nd - 3) + [None, fsdp, None]
            return sanitize(mesh, P(*spec), shape)
        e = shape[nd - 3]
        if e % mesh.shape["model"] == 0:
            # expert-parallel: experts on "model", fsdp on the widest of the
            # remaining two dims
            spec = [None] * (nd - 3) + ["model", fsdp, None]
            return sanitize(mesh, P(*spec), shape)
        # TP within expert (granite: 40/32 experts don't divide 16)
        spec = [None] * (nd - 3) + [None, fsdp, "model"]
        if _OUT_TYPE.search(path):
            spec = [None] * (nd - 3) + [None, "model", fsdp]
        return sanitize(mesh, P(*spec), shape)
    if _OUT_TYPE.search(path):
        return sanitize(mesh, P(*lead, "model", fsdp), shape)
    return sanitize(mesh, P(*lead, fsdp, "model"), shape)


def qtensor_specs(
    mesh: Mesh, path: str, qt: Any,
    moe_replicate: bool = False, serve_mode: bool = False,
    k_axis: str | None = None, k_shard_min_k: int = 0,
) -> Any:
    """PartitionSpec pytree for one QTensor leaf (specs ride the QTensor).

    The int8 ``values`` take the same rule as the float matrix they
    replaced; the per-output-channel ``scale`` (and any calibrated
    ``act_qparams`` arrays, shaped like the leading/layer dims) inherit
    the axis entries of the dims they index into ``values``, so weight
    shards and their scales land on the same devices — no gather before
    the integer dot.

    ``k_axis`` places long-K leaves for the K-sharded ``pqs_dot`` path:
    leaves whose input (contraction) dim is >= ``k_shard_min_k`` get
    that mesh axis on the input dim, matching the in_specs of
    ``pqs_dot(..., k_axis=...)`` so the per-shard K slices are already
    resident — no resharding before the distributed dot.
    """
    from repro.core.qtensor import QTensor

    v_shape = tuple(qt.values.shape)
    v_spec = param_spec(mesh, path, v_shape, moe_replicate, serve_mode)
    entries = list(v_spec) + [None] * (len(v_shape) - len(v_spec))
    if (k_axis is not None and k_axis in mesh.axis_names
            and len(v_shape) >= 2 and v_shape[-2] >= k_shard_min_k):
        entries[-2] = k_axis  # (…, in, out): K shards over k_axis
        v_spec = sanitize(mesh, P(*entries), v_shape)
        entries = list(v_spec) + [None] * (len(v_shape) - len(v_spec))
    # scale: (..., out) — leading dims + the values' last (out) dim
    s_spec = sanitize(
        mesh, P(*entries[:-2], entries[-1]), tuple(qt.scale.shape)
    )
    aq = getattr(qt, "act_qparams", None)
    aq_specs = None
    if aq is not None:
        lead = sanitize(mesh, P(*entries[:-2]), tuple(aq.scale.shape))
        aq_specs = type(aq)(lead, lead, aq.bits, aq.symmetric)
    corr = getattr(qt, "act_corr", None)
    # act_corr is (..., out) like scale — same placement
    corr_spec = None if corr is None else s_spec
    return QTensor(v_spec, s_spec, aq_specs, corr_spec)


def sparse_qtensor_specs(
    mesh: Mesh, path: str, qt: Any,
    moe_replicate: bool = False, serve_mode: bool = False,
    k_axis: str | None = None, k_shard_min_k: int = 0,
) -> Any:
    """PartitionSpec pytree for one N:M-compressed SparseQTensor leaf.

    The rule is derived from the LOGICAL dense (in, out) matrix the leaf
    replaces: whatever axis entry the dense rule gives the output dim
    lands on the values' out axis (dim -3), and the dense input-dim
    entry lands on the GROUP axis (dim -2) — sharding G is sharding K in
    units of m_group, so a weight shard still holds whole groups and
    the kernels' expand never crosses devices. indices mirror values;
    scale and act_corr ride the out entry; n_keep never shards.

    ``k_axis``/``k_shard_min_k`` mirror ``qtensor_specs``: long-K leaves
    put that axis on the group dim (K shards in units of whole groups,
    matching the compressed in_specs of ``pqs_dot(..., k_axis=...)``).
    """
    from repro.core.qtensor import SparseQTensor

    v_shape = tuple(qt.values.shape)
    dense_shape = v_shape[:-3] + (qt.k_dim, v_shape[-3])
    dspec = param_spec(mesh, path, dense_shape, moe_replicate, serve_mode)
    entries = list(dspec) + [None] * (len(dense_shape) - len(dspec))
    in_entry, out_entry = entries[-2], entries[-1]
    if (k_axis is not None and k_axis in mesh.axis_names
            and qt.k_dim >= k_shard_min_k):
        in_entry = k_axis  # group axis: K shards in whole groups
    v_spec = sanitize(
        mesh, P(*entries[:-2], out_entry, in_entry, None), v_shape
    )
    s_spec = sanitize(
        mesh, P(*entries[:-2], out_entry), tuple(qt.scale.shape)
    )
    aq = getattr(qt, "act_qparams", None)
    aq_specs = None
    if aq is not None:
        lead = sanitize(mesh, P(*entries[:-2]), tuple(aq.scale.shape))
        aq_specs = type(aq)(lead, lead, aq.bits, aq.symmetric)
    corr_spec = None if getattr(qt, "act_corr", None) is None else s_spec
    return SparseQTensor(v_spec, v_spec, s_spec, qt.m_group, qt.k_dim,
                         aq_specs, corr_spec)


def params_shardings(
    mesh: Mesh, params_shapes: Any, moe_replicate: bool = False,
    serve_mode: bool = False,
    k_axis: str | None = None, k_shard_min_k: int = 0,
) -> Any:
    """Pytree of NamedShardings matching a (ShapeDtypeStruct) param tree.

    QTensor leaves map to QTensor-shaped sharding subtrees: int8 values
    and their QParams scales shard together (see ``qtensor_specs``);
    N:M-compressed SparseQTensor leaves map the same way with the group
    axis standing in for the input dim (``sparse_qtensor_specs``).
    ``k_axis``/``k_shard_min_k`` place long-K quantized leaves for the
    K-sharded serving path (input/group dim on ``k_axis``).
    """
    from repro.core.qtensor import QTensor, SparseQTensor

    def rule(path, leaf):
        if isinstance(leaf, (QTensor, SparseQTensor)):
            spec_fn = (sparse_qtensor_specs if isinstance(leaf, SparseQTensor)
                       else qtensor_specs)
            specs = spec_fn(mesh, _path_str(path), leaf,
                            moe_replicate, serve_mode,
                            k_axis=k_axis, k_shard_min_k=k_shard_min_k)
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda s: isinstance(s, P),
            )
        spec = param_spec(mesh, _path_str(path), tuple(leaf.shape),
                          moe_replicate, serve_mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        rule, params_shapes,
        is_leaf=lambda l: isinstance(l, (QTensor, SparseQTensor)),
    )


def opt_shardings(mesh: Mesh, opt_shapes: Any) -> Any:
    """Optimizer state mirrors params leaf-for-leaf (ZeRO-3); the step
    counter and any scalar leaves replicate."""
    return params_shardings(mesh, opt_shapes)


def batch_shardings(mesh: Mesh, batch_shapes: dict[str, Any]) -> dict[str, Any]:
    dp = data_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        if "positions" in name and len(shape) == 3:  # (3, B, S)
            return NamedSharding(mesh, sanitize(mesh, P(None, dp, None), shape))
        if len(shape) >= 1:
            spec = P(dp, *([None] * (len(shape) - 1)))
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_shardings(mesh: Mesh, cache_shapes: Any, serve_mode: bool = False) -> Any:
    """Decode-cache rule.

    KV tensors (..., B, S, G, hd): batch on the data axes when it divides;
    for global-batch-1 long-context cells the sequence axis takes "data"
    instead. The head_dim axis shards on "model" (uniformly divisible
    across all archs, unlike G which can be < tp degree).
    SSM state (..., B, H, P, N): heads on "model".
    Conv state (..., B, K, D): channels on "model".

    Paged serving pools (``serving.paged_cache`` leaves) have no batch
    axis — the page/state-page axis stands in for it. ``serve_mode=True``
    shards that pool axis over the data axes (each data-parallel member
    owns a page shard of the serving pool, mirroring
    ``params_shardings(serve_mode=True)``); the default replicates pools
    so training-side dry runs stay conservative. Page tables, state
    indices and positions always replicate (every member resolves the
    same indirection).
    """
    dp = data_axes(mesh)
    pool_dp = dp if serve_mode else None

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        nd = len(shape)
        last = name.split("/")[-1]
        if last in ("pos", "table", "sidx") or nd <= 1:
            return NamedSharding(mesh, P())
        if last in ("kp", "vp") and nd >= 4:  # (..., Np, pg, G, hd)
            lead = [None] * (nd - 4)
            spec = P(*lead, pool_dp, None, None, "model")
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if last in ("ks", "vs") and nd >= 3:  # (..., Np, pg, G) scales
            lead = [None] * (nd - 3)
            spec = P(*lead, pool_dp, None, None)
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if last == "ssdp" and nd >= 4:  # (..., Ns, H, Phd, N)
            lead = [None] * (nd - 4)
            spec = P(*lead, pool_dp, "model", None, None)
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if last == "convp" and nd >= 3:  # (..., Ns, K, D)
            lead = [None] * (nd - 3)
            spec = P(*lead, pool_dp, None, "model")
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if last in ("k", "v") and nd >= 4:
            b, s = shape[nd - 4], shape[nd - 3]
            lead = [None] * (nd - 4)
            if b % _axis_div(mesh, dp) == 0:
                spec = P(*lead, dp, None, None, "model")
            else:
                spec = P(*lead, None, "data", None, "model")
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if name.endswith("ssd") and nd >= 4:  # (..., B, H, P, N)
            lead = [None] * (nd - 4)
            spec = P(*lead, dp, "model", None, None)
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        if name.endswith("conv") and nd >= 3:  # (..., B, K, D)
            lead = [None] * (nd - 3)
            spec = P(*lead, dp, None, "model")
            return NamedSharding(mesh, sanitize(mesh, spec, shape))
        # fallback: batch-shard the first plausible axis
        spec = P(dp, *([None] * (nd - 1)))
        return NamedSharding(mesh, sanitize(mesh, spec, shape))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def logits_sharding(mesh: Mesh, shape: tuple[int, ...]) -> NamedSharding:
    dp = data_axes(mesh)
    spec = P(dp, *([None] * (len(shape) - 2)), "model")
    return NamedSharding(mesh, sanitize(mesh, spec, shape))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def place_tree(state: Any, shardings: Any) -> Any:
    """device_put every leaf of ``state`` to its matching sharding.

    Leaves round-trip through host (np.asarray) first, so arrays whose
    previous placement no longer exists — a shrunken mesh after device
    loss — re-place cleanly. ``shardings`` may be a prefix tree (the
    treedef is taken from it, ``state`` flattened up to it), matching how
    sharding rules describe nested cache pytrees.
    """
    import numpy as np

    flat_s, tdef = jax.tree_util.tree_flatten(shardings)
    flat_x = tdef.flatten_up_to(state)
    out = [jax.device_put(np.asarray(x), s) for x, s in zip(flat_x, flat_s)]
    return jax.tree_util.tree_unflatten(tdef, out)
