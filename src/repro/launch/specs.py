"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns the batch pytree a step consumes; nothing is
allocated. ``decode_specs`` adds the KV/SSM cache tree (evaluated with
jax.eval_shape through the model's own init_caches, so cache structure is
always in sync with the models). ``step_fns`` builds the jitted-able
train / prefill / serve step callables.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import Model
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    bf16 = jnp.bfloat16
    if cfg.family == "vlm":
        out = {
            "embeddings": SDS((b, s, cfg.d_model), bf16),
            "positions": SDS((3, b, s), jnp.int32),
        }
    elif cfg.is_encoder_decoder:
        out = {
            "frames": SDS((b, s, cfg.d_model), bf16),
            "tokens": SDS((b, s), jnp.int32),
        }
    else:
        out = {"tokens": SDS((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = SDS((b, s), jnp.int32)
    return out


def token_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """Single decode-step token input."""
    b = shape.global_batch
    if cfg.family == "vlm":
        return SDS((b, 1, cfg.d_model), jnp.bfloat16)
    return SDS((b, 1), jnp.int32)


def params_specs(model: Model) -> Any:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def cache_specs(model: Model, shape: ShapeSpec, cache_dtype=jnp.bfloat16) -> Any:
    params = params_specs(model)
    b, s = shape.global_batch, shape.seq_len

    def mk(p):
        return model.init_caches(p, b, s, cache_dtype)

    return jax.eval_shape(mk, params)


def make_train_step(model: Model, lr: float = 3e-4) -> Callable:
    opt = adamw(lr=lr)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_opt_specs(model: Model, lr: float = 3e-4) -> Any:
    opt = adamw(lr=lr)
    params = params_specs(model)
    return jax.eval_shape(opt.init, params)


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, token, caches):
        return model.decode(params, token, caches)

    return serve_step
