"""Distributed training driver.

Production shape: pjit'd train step with the launch/sharding.py rules,
async checkpointing, restart-on-failure supervision, straggler monitoring,
and checkpointable data-iterator state. On the CPU container it runs the
reduced (--smoke) configs end-to-end on a host mesh; on a real cluster the
same entrypoint runs the full configs on make_production_mesh() (every
piece — shardings, steps, checkpoints — is mesh-agnostic).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import TokenStream
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model, param_count
from repro.optim import adamw, cosine_schedule
from repro.runtime import StragglerMonitor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = (
        make_production_mesh() if args.production_mesh else make_host_mesh()
    )
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} "
          f"devices={mesh.devices.size}")

    opt = adamw(
        lr=cosine_schedule(args.lr, args.steps, args.warmup), weight_decay=0.1
    )

    # --- init (sharded via jit so large params materialize pre-sharded) ---
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shard_lib.params_shardings(mesh, p_shapes)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_shard = shard_lib.opt_shardings(mesh, o_shapes)

    with mesh:
        params = jax.jit(model.init, out_shardings=p_shard)(
            jax.random.PRNGKey(0)
        )
        opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)
    print(f"[train] params: {param_count(params):,}")

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
    }
    b_shard = shard_lib.batch_shardings(mesh, batch_sds)
    step_fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, shard_lib.replicated(mesh)),
        donate_argnums=(0, 1),
    )

    data = TokenStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        host_id=jax.process_index(), num_hosts=jax.process_count(),
    )
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        payload, start = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state,
                            "data_step": np.asarray(0)}
        )
        params, opt_state = payload["params"], payload["opt"]
        data.restore({"step": int(payload["data_step"])})
        print(f"[train] resumed from step {start}")

    monitor = StragglerMonitor()
    times = []
    with mesh:
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            params, opt_state, loss = step_fn(params, opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            times.append(dt)
            monitor.observe(step, {jax.process_index(): dt})
            if step % args.log_every == 0 or step == args.steps - 1:
                tok_s = args.batch * args.seq / dt
                print(f"[train] step {step:5d}  loss {loss:8.4f}  "
                      f"{dt*1e3:7.1f} ms/step  {tok_s:9.0f} tok/s")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state,
                                     "data_step": np.asarray(data.step)})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state,
                               "data_step": np.asarray(data.step)})
        ckpt.wait()
    print(f"[train] done; median step {np.median(times)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
