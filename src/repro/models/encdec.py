"""Whisper-style encoder-decoder transformer.

The conv/mel frontend is a stub per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S_enc, d_model) to the encoder. Encoder
uses sinusoidal absolute positions and bidirectional attention; the decoder
uses learned positions, causal self-attention, and cross-attention into the
encoder output. Both stacks are homogeneous and scan over stacked params.

Decode keeps two caches per layer: the self-attention KV ring and the
cross-attention K/V computed once from the encoder output at prefill.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qtensor import asarray
from repro.models.hints import hint_batch, hint_logits
from repro.models.layers import (
    Params,
    _sdpa,
    attention,
    attention_decode,
    attn_init,
    empty_kv_cache,
    lin,
    mlp,
    mlp_init,
    norm,
    norm_init,
    write_prefill_kv,
)


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def enc_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg),
        "ln2": norm_init(cfg.d_model),
        "mlp": mlp_init(ks[1], cfg),
    }


def dec_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg),
        "ln_x": norm_init(cfg.d_model),
        "xattn": attn_init(ks[1], cfg),
        "ln2": norm_init(cfg.d_model),
        "mlp": mlp_init(ks[2], cfg),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    ke, kd, kv, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "enc_layers": jax.vmap(lambda k: enc_layer_init(k, cfg))(enc_keys),
        "enc_ln_f": norm_init(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: dec_layer_init(k, cfg))(dec_keys),
        "dec_ln_f": norm_init(cfg.d_model),
        "embed": jax.random.normal(kv, (cfg.vocab_size, cfg.d_model), dt)
        * (1.0 / cfg.d_model**0.5),
        "pos_embed": jax.random.normal(kp, (cfg.max_seq_len, cfg.d_model), dt)
        * 0.01,
    }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, d_model) stubbed frontend output -> encoder states."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = frames.shape
    x = frames.astype(dt) + sinusoids(s, cfg.d_model).astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, p):
        fn = lambda p, x: x + attention(  # noqa: E731
            p["attn"], norm(x, p["ln1"], cfg), positions, cfg,
            causal=False, use_rope=False,
        )
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = fn(p, x)
        x = x + mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return hint_batch(x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return norm(x, params["enc_ln_f"], cfg)


def decode_train(
    params: Params,
    tokens: jax.Array,  # (B, S_dec) int32
    enc_out: jax.Array,  # (B, S_enc, d)
    cfg: ModelConfig,
) -> jax.Array:
    """Teacher-forced decoder forward -> logits (B, S_dec, V)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    x = asarray(params["embed"], dt)[tokens]
    x = x + asarray(params["pos_embed"], dt)[None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, p):
        def fn(p, x):
            x = x + attention(
                p["attn"], norm(x, p["ln1"], cfg), positions, cfg,
                causal=True, use_rope=False,
            )
            x = x + attention(
                p["xattn"], norm(x, p["ln_x"], cfg), positions, cfg,
                kv_x=enc_out, use_rope=False,
            )
            return x

        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = fn(p, x)
        x = x + mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return hint_batch(x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = norm(x, params["dec_ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T)


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    return decode_train(params, tokens, encode(params, frames, cfg), cfg)


# ---------------------------------------------------------------------------
# decode (incremental)
# ---------------------------------------------------------------------------


def precompute_cross_kv(
    params: Params, enc_out: jax.Array, cfg: ModelConfig
) -> Params:
    """Per-layer cross-attention K/V from encoder states: (L, B, S, H, hd)."""
    b, s, _ = enc_out.shape
    g, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def one(p):
        k = lin(enc_out, p["xattn"]["wk"])
        v = lin(enc_out, p["xattn"]["wv"])
        if cfg.qkv_bias:
            k = k + p["xattn"]["bk"].astype(k.dtype)
            v = v + p["xattn"]["bv"].astype(v.dtype)
        return {
            "k": k.reshape(b, s, g, hd),
            "v": v.reshape(b, s, g, hd),
        }

    return jax.vmap(one)(params["dec_layers"])


def init_decode_caches(
    params: Params, cfg: ModelConfig, batch: int, max_len: int, dtype,
    paging=None,
) -> Any:
    """Decoder self-attention KV, layer-stacked; optionally paged.

    Cross-attention K/V (``precompute_cross_kv``) stays dense: it is
    written once per request from the encoder output and never grows.
    """
    if paging is not None:
        from repro.serving import paged_cache as pc

        one = pc.empty_paged_kv(batch, paging, cfg.num_kv_heads,
                                cfg.resolved_head_dim, dtype)
    else:
        one = empty_kv_cache(cfg, batch, max_len, None, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one
    )


def _cross_attend_step(p: Params, x: jax.Array, xkv: Params,
                       cfg: ModelConfig) -> jax.Array:
    """Cross-attention from precomputed encoder K/V; x: (B, Sq, d)."""
    b, sq = x.shape[0], x.shape[1]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = lin(x, p["wq"], site="wq")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(b, sq, h, hd)
    k = xkv["k"].astype(x.dtype)
    v = xkv["v"].astype(x.dtype)
    mask = jnp.ones((sq, k.shape[1]), bool)
    o = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    return lin(o.reshape(b, sq, h * hd), p["wo"], site="wo")


def prefill_step(
    params: Params,
    tokens: jax.Array,  # (B, S) int32, left-aligned prompts
    caches: Any,  # stacked self-attn KV
    cross_kv: Params,  # from precompute_cross_kv
    lengths: jax.Array,  # (B,) int32 valid tokens per slot (0 = skip)
    cfg: ModelConfig,
) -> tuple[jax.Array, Any]:
    """One-shot batched decoder prefill: self-KV captured per layer and
    scattered into the slot caches; cross-attention reads the
    precomputed encoder K/V exactly as the decode step does."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    x = asarray(params["embed"], dt)[tokens]
    x = x + asarray(params["pos_embed"], dt)[None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, inp):
        p, cache, xkv = inp
        h, (k, v) = attention(
            p["attn"], norm(x, p["ln1"], cfg), positions, cfg,
            causal=True, use_rope=False, return_kv=True,
        )
        x = x + h
        x = x + _cross_attend_step(p["xattn"], norm(x, p["ln_x"], cfg), xkv,
                                   cfg)
        x = x + mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return hint_batch(x), write_prefill_kv(cache, k, v, lengths)

    x, new_caches = jax.lax.scan(
        body, x, (params["dec_layers"], caches, cross_kv),
        unroll=cfg.scan_unroll,
    )
    x = norm(x, params["dec_ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches


def decode_step(
    params: Params,
    token: jax.Array,  # (B, 1) int32
    caches: Any,  # stacked self-attn KV
    cross_kv: Params,  # from precompute_cross_kv
    cfg: ModelConfig,
) -> tuple[jax.Array, Any]:
    dt = jnp.dtype(cfg.compute_dtype)
    x = asarray(params["embed"], dt)[token]
    pos = caches["pos"][0]  # (B,) — layer 0's per-sequence positions
    x = x + asarray(params["pos_embed"], dt)[pos][:, None]

    def body(x, inp):
        p, cache, xkv = inp
        h, new_cache = attention_decode(
            p["attn"], norm(x, p["ln1"], cfg), cache, cfg, use_rope=False
        )
        x = x + h
        x = x + _cross_attend_step(p["xattn"], norm(x, p["ln_x"], cfg), xkv, cfg)
        x = x + mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return hint_batch(x), new_cache

    x, new_caches = jax.lax.scan(
        body, x, (params["dec_layers"], caches, cross_kv),
        unroll=cfg.scan_unroll,
    )
    x = norm(x, params["dec_ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches
