"""Activation sharding hints: pin the batch/TP layout inside model code.

GSPMD propagation from the jit in_shardings alone is not reliable through
embedding gathers, scans, and remat (§Perf iteration 1 found the batch
axis silently replicated mid-graph, turning TP matmuls into full-batch
f32 all-reduces). These hints pin the residual-stream layout at every
layer boundary. They are exact no-ops when no mesh is active (unit tests,
single-device examples) and filter axis names against the ambient mesh,
so the same model code runs everywhere.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _filter(mesh, entry: Any, dim: int) -> Any:
    if entry is None:
        return None
    cand = entry if isinstance(entry, tuple) else (entry,)
    cand = tuple(a for a in cand if a in mesh.axis_names)

    def div(c):
        n = 1
        for a in c:
            n *= mesh.shape[a]
        return n

    while cand and dim % div(cand) != 0:
        cand = cand[:-1]
    if not cand:
        return None
    return cand if len(cand) > 1 else cand[0]


def shard_hint(x: jax.Array, *entries: Any) -> jax.Array:
    """with_sharding_constraint(x, P(*entries)) against the ambient mesh;
    silently drops absent/non-dividing axes; no-op without a mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    ent = list(entries) + [None] * (x.ndim - len(entries))
    spec = P(*[_filter(mesh, e, d) for e, d in zip(ent, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)


DP = ("pod", "data")  # batch axes


def hint_batch(x: jax.Array) -> jax.Array:
    """Residual stream (B, S, d): batch on the data axes."""
    return shard_hint(x, DP)


def hint_batch_seq(x: jax.Array) -> jax.Array:
    """Sequence-parallel residual (B, S, d): batch on data, seq on model.
    Norms/elementwise run model-sharded; GSPMD turns the TP boundary
    all-reduces into reduce-scatter + all-gather pairs (§Perf)."""
    return shard_hint(x, DP, "model")


def hint_logits(x: jax.Array) -> jax.Array:
    """(B, S, V) or (B, 1, V): batch on data, vocab on model."""
    return shard_hint(x, DP, None, "model")
