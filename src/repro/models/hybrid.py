"""Jamba-style hybrid: Mamba+attention 1:7 interleave with interleaved MoE.

Layer i uses an attention mixer when ``i % attn_period == attn_offset``
(Jamba v0.1: period 8, offset 4) and a Mamba2 mixer otherwise; its FFN is
MoE when ``i % moe.layer_period == moe.layer_offset`` (odd layers) and a
dense MLP otherwise. Layers are heterogeneous, so params are a python list
of per-layer dicts and the layer loop is unrolled (32 layers — compile
stays manageable; the hot memory path is still scanned inside SSD/attn).

Jamba attention layers carry no positional encoding (the SSM layers encode
order), so ``use_rope=False``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qtensor import asarray
from repro.models import moe as moe_lib
from repro.models.hints import hint_batch, hint_logits
from repro.models.layers import (
    Params,
    attention,
    attention_decode,
    attn_init,
    empty_kv_cache,
    mlp,
    mlp_init,
    norm,
    norm_init,
    write_prefill_kv,
)
from repro.models.ssm import (
    empty_ssm_cache,
    mamba_forward,
    mamba_init,
    mamba_step,
)


def is_attn_layer(i: int, cfg: ModelConfig) -> bool:
    return i % cfg.attn_period == cfg.attn_offset


def is_moe_layer(i: int, cfg: ModelConfig) -> bool:
    m = cfg.moe
    return m is not None and i % m.layer_period == m.layer_offset


def layer_init(key, i: int, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"ln1": norm_init(cfg.d_model), "ln2": norm_init(cfg.d_model)}
    if is_attn_layer(i, cfg):
        p["attn"] = attn_init(ks[0], cfg)
    else:
        p["mamba"] = mamba_init(ks[0], cfg)
    if is_moe_layer(i, cfg):
        p["moe"] = moe_lib.moe_init(ks[1], cfg, cfg.moe)
    else:
        p["mlp"] = mlp_init(ks[1], cfg, d_ff=cfg.d_ff)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 1)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "layers": [layer_init(keys[i], i, cfg) for i in range(cfg.num_layers)],
        "ln_f": norm_init(cfg.d_model),
        "embed": jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model), dt)
        * (1.0 / cfg.d_model**0.5),
    }


def forward(
    params: Params,
    tokens: jax.Array,  # (B, S) int32
    positions: Optional[jax.Array] = None,
    cfg: ModelConfig = None,
) -> tuple[jax.Array, jax.Array]:
    b, s = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = asarray(params["embed"], dt)[tokens]
    aux_total = jnp.zeros((), jnp.float32)

    for i, p in enumerate(params["layers"]):
        def mixer(p, x):
            h = norm(x, p["ln1"], cfg)
            if "attn" in p:
                h = attention(p["attn"], h, positions, cfg, causal=True,
                              use_rope=False)
            else:
                h, _ = mamba_forward(p["mamba"], h, cfg)
            return x + h

        fn = jax.checkpoint(mixer) if cfg.remat else mixer
        x = fn(p, x)
        h = norm(x, p["ln2"], cfg)
        if "moe" in p:
            h, aux = moe_lib.moe_ffn(p["moe"], h, cfg, cfg.moe)
            aux_total = aux_total + aux
        else:
            h = mlp(p["mlp"], h, cfg)
        x = hint_batch(x + h)

    x = norm(x, params["ln_f"], cfg)
    logits = hint_logits(x @ asarray(params["embed"], x.dtype).T)
    return logits, aux_total / max(cfg.num_layers, 1)


def prefill_step(
    params: Params,
    tokens: jax.Array,  # (B, S) int32, left-aligned prompts
    caches: list[Any],
    lengths: jax.Array,  # (B,) int32 valid tokens per slot (0 = skip)
    cfg: ModelConfig,
) -> tuple[jax.Array, list[Any]]:
    """One-shot batched prefill across the attention/Mamba interleave.

    Attention layers capture per-layer K/V from the full-sequence pass
    and scatter them into the slot caches (masked by ``lengths``); Mamba
    layers run the SSD forward with dt zeroed past each lane's length,
    so both cache kinds end at exactly the per-slot token count.
    """
    b, s = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = asarray(params["embed"], dt)[tokens]
    new_caches: list[Any] = []

    for i, p in enumerate(params["layers"]):
        h = norm(x, p["ln1"], cfg)
        if "attn" in p:
            h, (k, v) = attention(p["attn"], h, positions, cfg, causal=True,
                                  use_rope=False, return_kv=True)
            new_caches.append(write_prefill_kv(caches[i], k, v, lengths))
        elif "ssdp" in caches[i]:  # pooled SSM state (paged serving)
            from repro.serving import paged_cache as pc

            dense, put = pc.ssm_gather(caches[i])
            h, nc = mamba_forward(p["mamba"], h, cfg, h0=dense["ssd"],
                                  lengths=lengths)
            new_caches.append(put(nc))
        else:
            h, nc = mamba_forward(p["mamba"], h, cfg, h0=caches[i]["ssd"],
                                  lengths=lengths)
            new_caches.append(nc)
        x = x + h
        h = norm(x, p["ln2"], cfg)
        if "moe" in p:
            # per-token routing: matches the decode step's capacity
            # situation, so prefill never drops a token decode would keep
            h, _ = moe_lib.moe_ffn_per_token(p["moe"], h, cfg, cfg.moe)
        else:
            h = mlp(p["mlp"], h, cfg)
        x = hint_batch(x + h)

    x = norm(x, params["ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches


def init_decode_caches(
    params: Params, cfg: ModelConfig, batch: int, max_len: int, dtype,
    paging=None,
) -> list[Any]:
    """Per-layer cache list; with ``paging`` both cache kinds pool:
    attention layers share the KV page pool, Mamba layers take one
    state page per active slot (``sidx``-indexed)."""
    if paging is not None:
        from repro.models.ssm import ssm_dims
        from repro.serving import paged_cache as pc

        dims = ssm_dims(cfg)
        s = cfg.ssm
        caches = []
        for i in range(cfg.num_layers):
            if is_attn_layer(i, cfg):
                caches.append(pc.empty_paged_kv(
                    batch, paging, cfg.num_kv_heads, cfg.resolved_head_dim,
                    dtype))
            else:
                caches.append(pc.empty_paged_ssm(
                    batch, paging, dims["nheads"], s.head_dim, s.d_state,
                    s.d_conv, dims["d_xbc"], dtype))
        return caches
    caches = []
    for i in range(cfg.num_layers):
        if is_attn_layer(i, cfg):
            caches.append(empty_kv_cache(cfg, batch, max_len, None, dtype))
        else:
            caches.append(empty_ssm_cache(cfg, batch, dtype))
    return caches


def decode_step(
    params: Params,
    token: jax.Array,  # (B, 1) int32
    caches: list[Any],
    cfg: ModelConfig,
) -> tuple[jax.Array, list[Any]]:
    dt = jnp.dtype(cfg.compute_dtype)
    x = asarray(params["embed"], dt)[token]
    new_caches = []
    for i, p in enumerate(params["layers"]):
        h = norm(x, p["ln1"], cfg)
        if "attn" in p:
            h, nc = attention_decode(p["attn"], h, caches[i], cfg,
                                     use_rope=False)
        elif "ssdp" in caches[i]:  # pooled SSM state (paged serving)
            from repro.serving import paged_cache as pc

            dense, put = pc.ssm_gather(caches[i])
            h, nc = mamba_step(p["mamba"], h, dense, cfg)
            nc = put(nc)
        else:
            h, nc = mamba_step(p["mamba"], h, caches[i], cfg)
        new_caches.append(nc)
        x = x + h
        h = norm(x, p["ln2"], cfg)
        if "moe" in p:
            h, _ = moe_lib.moe_ffn(p["moe"], h, cfg, cfg.moe)
        else:
            h = mlp(p["mlp"], h, cfg)
        x = hint_batch(x + h)
    x = norm(x, params["ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches
