"""Shared model-zoo layers: norms, RoPE/M-RoPE, GQA attention, MLP.

Pure-functional JAX. Conventions:
- params are plain dicts of arrays; stacked along axis 0 when scanned.
- activations flow in cfg.compute_dtype (bf16); norms/softmax in f32.
- attention is memory-efficient (scan over query chunks) above
  cfg.attn_chunk_threshold so compiled peak memory stays roofline-honest,
  and keeps GQA KV unexpanded on the decode path (§Perf iteration 7).
- PQS quantized weights: any projection may carry a QTensor (int8 +
  per-channel scales, N:M pruned) instead of a float matrix; ``lin()``
  dequantizes on the fly — the decode-bandwidth optimization of §Perf.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qtensor import asarray

Params = dict[str, Any]


def lin(x: jax.Array, w: Any, site: Optional[str] = None) -> jax.Array:
    """x @ w with transparent QTensor handling (PQS int8 serving).

    ``w`` may be a dense ``QTensor`` or an N:M-compressed
    ``SparseQTensor`` (pruned weights in values/indices form — the full
    P+Q+S storage). Default: dequantize-on-the-fly float matmul (the
    bandwidth story). Inside a ``core.dispatch.integer_lin`` context,
    quantized projections instead run as true integer dot products with
    simulated narrow accumulation through the unified ``pqs_dot`` layer
    (compressed weights stay compressed: ``storage="nm"``) — the
    numerics story — this is how the serving engine executes quantized
    projections under an accumulation policy; with a serving mesh on
    the config, the dot runs sharded (N on "model", M on data axes).

    ``site`` names the projection call site ("wq", "w_gate", ...) for
    the activation-range calibration pass: inside a
    ``core.dispatch.calibration`` context the input's (min, max) is
    reported per site (via jax.debug.callback, so scanned layer loops
    work), to be frozen into static QParams on the QTensor.

    Inside a ``core.dispatch.a2q_qat`` context, FLOAT 2-D weights at
    named sites instead run accumulator-aware fake quantization
    (`core.a2q.a2q_fake_quant` under an STE, overflow census as a
    training signal) — the QAT leg of train→certify→serve. Tiny
    projections (min dim < cfg.min_dim) and unnamed sites stay float.
    """
    if isinstance(w, jax.Array):
        if w.ndim == 2 and site is not None:
            from repro.core import dispatch

            qat = dispatch.a2q_qat_config()
            if qat is not None and min(w.shape) >= qat.min_dim:
                return dispatch.a2q_qat_lin(x, w, qat, site=site)
    else:
        from repro.core import dispatch
        from repro.core.qtensor import QTensor, SparseQTensor

        if isinstance(w, (QTensor, SparseQTensor)):
            store = dispatch.calibration_store()
            if store is not None and site is not None:
                jax.debug.callback(
                    partial(store.observe, site),
                    jnp.min(x.astype(jnp.float32)),
                    jnp.max(x.astype(jnp.float32)),
                )
            cfg = dispatch.integer_lin_config()
            if cfg is not None:
                return dispatch.qtensor_dot(x, w, cfg, site=site)
    return x @ asarray(w, x.dtype)


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim, out_dim, dtype, scale=None):
    scale = scale if scale is not None else (2.0 / (in_dim + out_dim)) ** 0.5
    return jax.random.normal(key, (in_dim, out_dim), dtype) * scale


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def norm(x: jax.Array, gamma: jax.Array, cfg: ModelConfig) -> jax.Array:
    return rms_norm(x, gamma) if cfg.norm == "rmsnorm" else layer_norm(x, gamma)


def norm_init(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)  # stored as (scale - 1)


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE and qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim // 2, dtype=jnp.float32) * 2 / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (B, S, H, hd)
    positions: jax.Array,  # (B, S) int32 or (3, B, S) for M-RoPE
    head_dim: int,
    theta: float,
    mrope_sections: Optional[tuple[int, ...]] = None,
) -> jax.Array:
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    if mrope_sections is not None:
        # M-RoPE: head_dim/2 frequency slots split into (t, h, w) sections,
        # each rotated by its own position stream. positions: (3, B, S).
        assert positions.ndim == 3 and positions.shape[0] == 3
        sec = jnp.concatenate(
            [
                jnp.full((s,), i, jnp.int32)
                for i, s in enumerate(mrope_sections)
            ]
        )  # (hd/2,) -> which stream each freq slot uses
        pos = positions.astype(jnp.float32)  # (3, B, S)
        angles = pos[..., None] * freqs[None, None, None, :]  # (3,B,S,hd/2)
        angles = jnp.moveaxis(angles, 0, -1)  # (B,S,hd/2,3)
        sec_idx = jnp.broadcast_to(
            sec[None, None, :, None], angles.shape[:-1] + (1,)
        )
        angles = jnp.take_along_axis(angles, sec_idx, axis=-1)[..., 0]
    else:
        assert positions.ndim == 2
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, g = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, g * hd, dt),
        "wv": dense_init(ks[2], d, g * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((g * hd,), dt)
        p["bv"] = jnp.zeros((g * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, G, hd) -> (B, S, H, hd) by repeating each KV head H/G times."""
    b, s, g, hd = k.shape
    rep = num_heads // g
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _attn_mask(
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    causal: bool,
    window: Optional[int],
    use_window: Optional[jax.Array] = None,  # traced bool: apply window?
) -> jax.Array:
    """(Sq, Sk) boolean mask: True = attend.

    ``use_window`` lets a scan-over-layers body select local vs global
    attention with a traced per-layer flag (gemma3's 5:1 pattern) while
    ``window`` itself stays static.
    """
    diff = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones(diff.shape, bool)
    if causal:
        m = jnp.logical_and(m, diff >= 0)
    if window is not None:
        w = diff < window
        if use_window is not None:
            w = jnp.logical_or(w, jnp.logical_not(use_window))
        m = jnp.logical_and(m, w)
    return m


def _sdpa(q, k, v, mask, softcap=None):
    """Attention with unexpanded GQA KV: q (B,Sq,H,hd), k/v (B,Sk,G,hd).

    Two regimes (§Perf iterations 7/7b):
    - Sq == 1 (decode): GQA-native einsum — the repeated KV is never
      materialized (a jnp.repeat costs H/G x the KV-cache bytes per layer
      and dominated decode HBM traffic).
    - Sq > 1 (train/prefill): expand KV to H heads. Here score traffic
      dwarfs the one-time expansion, and H (a multiple of the 16-way
      "model" axis) shards cleanly where G=8 KV heads cannot — the native
      form cost +26% collective bytes on the 72B train cell.
    """
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    if sq > 1 and rep > 1:
        k = _expand_kv(k, h)
        v = _expand_kv(v, h)
        g, rep = h, 1
    qg = q.reshape(b, sq, g, rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    scores = scores / (hd**0.5)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, :, None] if mask.ndim == 4 else mask
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window, softcap, chunk,
                  use_window=None):
    """Memory-efficient attention: scan over query chunks.

    Peak score memory is (B, H, chunk, Sk) instead of (B, H, Sq, Sk) —
    what keeps 32k-prefill inside v5e HBM (DESIGN.md §6).
    """
    b, sq, h, hd = q.shape
    assert sq % chunk == 0, (sq, chunk)
    qc = q.reshape(b, sq // chunk, chunk, h, hd)
    pc = q_pos.reshape(sq // chunk, chunk)

    def body(carry, inp):
        qi, pi = inp
        mask = _attn_mask(pi, k_pos, causal, window, use_window)
        oi = _sdpa(qi, k, v, mask, softcap)
        return carry, oi

    _, out = jax.lax.scan(body, None, (jnp.moveaxis(qc, 1, 0), pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)


def attention(
    params: Params,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S) or (3, B, S)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_window: Optional[jax.Array] = None,  # traced local/global select
    kv_x: Optional[jax.Array] = None,  # cross-attention source
    use_rope: bool = True,
    return_kv: bool = False,
) -> jax.Array:
    """Full-sequence attention (train / prefill, no cache).

    ``return_kv=True`` additionally returns the unexpanded post-RoPE
    (k, v) (B, Sk, G, hd) — what a decode cache stores — so one-shot
    batched prefill can write them straight into the per-slot caches.
    Calibration sites are the projection names; self- and
    cross-attention share them (static QParams attach by the weight
    leaf's key, which is "wq"/"wo"... for both).
    """
    b, s, d = x.shape
    h, g, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    sk = src.shape[1]

    q = lin(x, params["wq"], site="wq")
    k = lin(src, params["wk"], site="wk")
    v = lin(src, params["wv"], site="wv")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, sk, g, hd)
    v = v.reshape(b, sk, g, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, hd, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, hd, cfg.rope_theta, cfg.mrope_sections)
    kv = (k, v)

    pos1d = positions[0] if positions.ndim == 3 else positions
    q_pos = pos1d[0]  # (S,) — shared across batch in this framework
    k_pos = q_pos if kv_x is None else jnp.arange(sk)
    if s >= cfg.attn_chunk_threshold and s % cfg.attn_chunk_q == 0:
        o = _sdpa_chunked(
            q, k, v, q_pos, k_pos, causal and kv_x is None, window,
            cfg.attn_logit_softcap, cfg.attn_chunk_q, use_window,
        )
    else:
        mask = _attn_mask(
            q_pos, k_pos, causal and kv_x is None, window, use_window
        )
        o = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    out = lin(o.reshape(b, s, h * hd), params["wo"], site="wo")
    return (out, kv) if return_kv else out


def attention_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cache: dict[str, jax.Array],  # {"k","v": (B, S_max, G, hd), "pos": (B,)}
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Single-token decode against a KV cache; returns (out, new_cache).

    The cache position ``pos`` is a traced (B,) vector — one write index
    per sequence, so continuous-batching slots at different depths share
    one batched cache without leaking into each other. Sliding-window
    layers use a ring buffer of size window (positions wrap), so
    local-layer caches stay O(window) — the gemma3 long_500k memory story.

    Paged caches (``serving.paged_cache`` nodes, detected by their "kp"
    key) take a pool-scatter write and a page-table gather read instead
    of the dense lane scatter; scores/softmax are shared with the dense
    path, so the f32 paged decode is bit-identical to it. Only global
    layers page — sliding-window rings are already O(window).
    """
    b, one, d = x.shape
    assert one == 1
    h, g, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    paged = "kp" in cache
    if paged and window is not None:
        raise ValueError(
            "paged KV caches cover global-attention layers only; "
            "sliding-window layers keep dense rings"
        )
    pos = cache["pos"]  # (B,) int32 — next write index (tokens so far)
    if pos.ndim == 0:  # legacy scalar caches: all sequences in lockstep
        pos = jnp.broadcast_to(pos, (b,))
    s_max = None if paged else cache["k"].shape[1]

    q = lin(x, params["wq"], site="wq")
    k = lin(x, params["wk"], site="wk")
    v = lin(x, params["wv"], site="wv")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, g, hd)
    v = v.reshape(b, 1, g, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if use_rope:
        pvec = pos[:, None].astype(jnp.int32)  # (B, 1) — per-sequence
        if cfg.mrope_sections is not None:
            pvec = jnp.broadcast_to(pvec, (3,) + pvec.shape)
        q = apply_rope(q, pvec, hd, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pvec, hd, cfg.rope_theta, cfg.mrope_sections)

    if paged:
        from repro.serving import paged_cache as pc

        new_cache = pc.paged_kv_write_token(cache, k[:, 0], v[:, 0])
        kk, vv = pc.paged_kv_read(new_cache, x.dtype)  # (B, P*pg, G, hd)
        slot = jnp.arange(kk.shape[1])
        valid = slot[None, :] <= pos[:, None]  # (B, P*pg)
    else:
        write_idx = (
            jnp.mod(pos, s_max) if window is not None else pos
        )  # (B,)
        rows = jnp.arange(b)
        new_k = cache["k"].at[rows, write_idx].set(
            k[:, 0].astype(cache["k"].dtype)
        )
        new_v = cache["v"].at[rows, write_idx].set(
            v[:, 0].astype(cache["v"].dtype)
        )
        new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
        kk = new_k.astype(x.dtype)  # (B, S_max, G, hd) — never expanded
        vv = new_v.astype(x.dtype)
        slot = jnp.arange(s_max)
        if window is not None:
            # ring buffer: valid slots = the last min(pos+1, window) writes
            age = jnp.mod(write_idx[:, None] - slot[None, :], s_max)
            valid = age < jnp.minimum(pos + 1, window)[:, None]  # (B, S_max)
        else:
            valid = slot[None, :] <= pos[:, None]  # (B, S_max)

    rep = h // g
    qg = q.reshape(b, 1, g, rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kk).astype(jnp.float32)
    scores = scores / (hd**0.5)
    if cfg.attn_logit_softcap is not None:
        scores = jnp.tanh(scores / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", probs, vv)
    out = lin(o.reshape(b, 1, h * hd), params["wo"], site="wo")
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.activation == "gelu_plain":
        return {
            "w_in": dense_init(ks[0], d, ff, dt),
            "b_in": jnp.zeros((ff,), dt),
            "w_out": dense_init(ks[1], ff, d, dt),
            "b_out": jnp.zeros((d,), dt),
        }
    return {
        "w_gate": dense_init(ks[0], d, ff, dt),
        "w_up": dense_init(ks[1], d, ff, dt),
        "w_out": dense_init(ks[2], ff, d, dt),
    }


def mlp(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.activation == "gelu_plain":
        hid = lin(x, params["w_in"], site="w_in") + params["b_in"].astype(
            x.dtype
        )
        hid = jax.nn.gelu(hid)
        return lin(hid, params["w_out"], site="w_out") + params[
            "b_out"
        ].astype(x.dtype)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    gate = act(lin(x, params["w_gate"], site="w_gate"))
    up = lin(x, params["w_up"], site="w_up")
    return lin(gate * up, params["w_out"], site="w_out")


def write_prefill_kv(
    cache: dict[str, jax.Array],
    k: jax.Array,  # (B, S, G, hd) post-RoPE, from attention(return_kv=True)
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32 — tokens consumed per slot (0 = skip)
) -> dict[str, jax.Array]:
    """Write one-shot prefill K/V into a decode cache, per-slot masked.

    For slot b, positions t < lengths[b] land at cache index t (global
    layers) or t % size (sliding-window rings, size = cache length); only
    the last ``size`` positions of a longer-than-window prompt are
    written — each surviving position maps to a distinct ring slot, so
    the scatter has no write conflicts. Masked (t >= length, or evicted
    ring) positions scatter to an out-of-bounds sentinel and are
    dropped. ``pos`` becomes ``lengths``: exactly the state the
    token-by-token decode path would have reached.

    Paged caches scatter through the page table instead (the engine has
    already allocated the prompt's pages at admission).
    """
    if "kp" in cache:
        from repro.serving import paged_cache as pc

        return pc.paged_kv_write_prefill(cache, k, v, lengths)
    size = cache["k"].shape[1]
    b, s = k.shape[0], k.shape[1]
    t = jnp.arange(s)
    keep = (t[None, :] < lengths[:, None]) & (
        t[None, :] >= lengths[:, None] - size
    )  # (B, S)
    idx = jnp.where(keep, t[None, :] % size, size)  # size = OOB sentinel

    def scatter(ck, new):
        def one(ck_b, new_b, idx_b):
            return ck_b.at[idx_b].set(new_b.astype(ck_b.dtype), mode="drop")

        return jax.vmap(one)(ck, new, idx)

    return {
        "k": scatter(cache["k"], k),
        "v": scatter(cache["v"], v),
        "pos": jnp.broadcast_to(lengths.astype(jnp.int32),
                                cache["pos"].shape),
    }


def empty_kv_cache(
    cfg: ModelConfig, batch: int, s_max: int, window: Optional[int], dtype
) -> dict[str, jax.Array]:
    g, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(s_max, window) if window is not None else s_max
    return {
        "k": jnp.zeros((batch, size, g, hd), dtype),
        "v": jnp.zeros((batch, size, g, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),  # per-sequence write index
    }
