"""build_model(cfg): one API over every architecture family.

Returns a ``Model`` bundle of pure functions:

    init(key)                      -> params
    loss(params, batch)            -> scalar (train objective)
    forward(params, batch)         -> logits (train/prefill shapes)
    init_caches(params, batch, L)  -> decode caches (+ encdec cross-KV)
    decode(params, token, caches)  -> (logits, new_caches)
    param_count(params)            -> int

Batch dicts (produced by data/ and launch/input_specs):
    dense/moe:  {"tokens" (B,S) i32, "labels" (B,S) i32}
    vlm/audio:  {"embeddings"/"frames" (B,S,d) bf16, ["tokens"], "labels"}
    ssm/hybrid: {"tokens", "labels"}
M-RoPE positions for the vlm family ride in "positions" (3,B,S).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qtensor import QTensor, asarray
from repro.models import encdec, hybrid, ssm as ssm_lib, transformer
from repro.models.hints import hint_batch, hint_logits
from repro.models.layers import Params, norm, norm_init


def cast_for_compute(params: Any, cfg: ModelConfig) -> Any:
    """Cast >=2-D float params to compute dtype BEFORE the layer scan.

    Master params stay f32 for the optimizer; casting the *sharded* leaves
    up front means every FSDP all-gather inside the scan moves bf16, not
    f32 — half the ICI traffic (§Perf iteration 3). Gradients flow through
    the convert back to f32 masters. QTensor (int8) leaves pass through.
    """
    dt = jnp.dtype(cfg.compute_dtype)

    def conv(leaf):
        if isinstance(leaf, QTensor):
            return leaf
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and jnp.issubdtype(
            leaf.dtype, jnp.floating
        ):
            return leaf.astype(dt)
        return leaf

    return jax.tree_util.tree_map(
        conv, params, is_leaf=lambda l: isinstance(l, QTensor)
    )
from repro.models.transformer import lm_loss


# ---------------------------------------------------------------------------
# pure-Mamba2 LM (homogeneous -> scan over stacked layers)
# ---------------------------------------------------------------------------


def mamba_lm_init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 1)
    dt = jnp.dtype(cfg.param_dtype)
    stacked = jax.vmap(
        lambda k: {"ln": norm_init(cfg.d_model), "mamba": ssm_lib.mamba_init(k, cfg)}
    )(keys[: cfg.num_layers])
    return {
        "layers": stacked,
        "ln_f": norm_init(cfg.d_model),
        "embed": jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model), dt)
        * (1.0 / cfg.d_model**0.5),
    }


def mamba_lm_forward(params: Params, tokens: jax.Array, cfg: ModelConfig):
    dt = jnp.dtype(cfg.compute_dtype)
    x = asarray(params["embed"], dt)[tokens]

    def body(x, p):
        def fn(p, x):
            h, _ = ssm_lib.mamba_forward(p["mamba"], norm(x, p["ln"], cfg), cfg)
            return x + h

        step = jax.checkpoint(fn) if cfg.remat else fn
        return hint_batch(step(p, x)), None

    x, _ = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = norm(x, params["ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T)


def mamba_lm_init_caches(params, cfg: ModelConfig, batch: int, dtype,
                         paging=None):
    if paging is not None:
        from repro.serving import paged_cache as pc

        dims = ssm_lib.ssm_dims(cfg)
        s = cfg.ssm
        one = pc.empty_paged_ssm(batch, paging, dims["nheads"], s.head_dim,
                                 s.d_state, s.d_conv, dims["d_xbc"], dtype)
    else:
        one = ssm_lib.empty_ssm_cache(cfg, batch, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one
    )


def mamba_lm_prefill(params: Params, tokens: jax.Array, caches,
                     lengths: jax.Array, cfg: ModelConfig):
    """One-shot batched prefill: full-sequence SSD per layer with dt
    zeroed past each lane's length (identity recurrence), returning
    layer-stacked {"ssd", "conv"} caches at exactly ``lengths`` tokens.

    Pooled state (paged serving) gathers each slot's state page into the
    dense per-slot view first and scatters the result back after — the
    recurrence itself is unchanged."""
    paged = isinstance(caches, dict) and "ssdp" in caches
    if paged:
        from repro.serving import paged_cache as pc

        caches, put_back = pc.ssm_gather(caches)
    dt = jnp.dtype(cfg.compute_dtype)
    x = asarray(params["embed"], dt)[tokens]

    def body(x, inp):
        p, cache = inp
        h, nc = ssm_lib.mamba_forward(
            p["mamba"], norm(x, p["ln"], cfg), cfg, h0=cache["ssd"],
            lengths=lengths,
        )
        return hint_batch(x + h), nc

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches),
                                 unroll=cfg.scan_unroll)
    if paged:
        new_caches = put_back(new_caches)
    x = norm(x, params["ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches


def mamba_lm_decode(params: Params, token: jax.Array, caches, cfg: ModelConfig):
    paged = isinstance(caches, dict) and "ssdp" in caches
    if paged:
        from repro.serving import paged_cache as pc

        caches, put_back = pc.ssm_gather(caches)
    dt = jnp.dtype(cfg.compute_dtype)
    x = asarray(params["embed"], dt)[token]

    def body(x, inp):
        p, cache = inp
        h, nc = ssm_lib.mamba_step(p["mamba"], norm(x, p["ln"], cfg), cache, cfg)
        return hint_batch(x + h), nc

    x, new_caches = jax.lax.scan(
        body, x, (params["layers"], caches), unroll=cfg.scan_unroll
    )
    if paged:
        new_caches = put_back(new_caches)
    x = norm(x, params["ln_f"], cfg)
    return hint_logits(x @ asarray(params["embed"], x.dtype).T), new_caches


# ---------------------------------------------------------------------------
# unified bundle
# ---------------------------------------------------------------------------


def merge_caches_on_axis(axis: int) -> Callable[[Any, Any, jax.Array], Any]:
    """Per-sequence cache selector for continuous batching.

    Returns ``merge(old, new, active)`` where ``active`` is a (B,) bool
    mask over the cache's batch axis: active lanes take the freshly
    decoded cache, inactive lanes keep their previous state untouched.
    ``axis`` is where the batch dim lives in every cache leaf (1 for
    layer-stacked caches, 0 for per-layer cache lists).

    Paged cache nodes (page pools, no per-slot batch axis) merge per
    page via ``paged_cache.paged_merge`` — same invariant, pool layout.
    """

    def merge(old: Any, new: Any, active: jax.Array) -> Any:
        from repro.serving import paged_cache as pc

        def sel(o, n):
            if pc.is_paged(o):
                return pc.paged_merge(o, n, active)
            shape = [1] * o.ndim
            shape[axis] = active.shape[0]
            return jnp.where(active.reshape(shape), n, o)

        return jax.tree_util.tree_map(sel, old, new, is_leaf=pc.is_paged)

    return merge


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Params]
    forward: Callable[..., jax.Array]  # (params, batch) -> logits
    loss: Callable[..., jax.Array]  # (params, batch) -> scalar
    init_caches: Callable[..., Any]  # (params, batch_size, max_len, dtype)
    decode: Callable[..., tuple]  # (params, token, caches) -> (logits, caches)
    # (old_caches, new_caches, active (B,) bool) -> caches with inactive
    # sequences' state preserved — the serving engine's slot isolation.
    merge_caches: Callable[..., Any] = None
    # (params, tokens (B,S), caches, lengths (B,)) -> (logits, new_caches):
    # one-shot batched prefill — consume tokens[b, :lengths[b]] into slot
    # b's cache lanes in a single step (engine admission path).
    prefill: Callable[..., tuple] = None


def _tokens_or_embeddings(batch: dict) -> jax.Array:
    if "embeddings" in batch:
        return batch["embeddings"]
    if "frames" in batch:
        return batch["frames"]
    return batch["tokens"]


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def fwd(params, batch):
            logits, _ = transformer.forward(
                cast_for_compute(params, cfg), _tokens_or_embeddings(batch),
                batch.get("positions"), cfg,
            )
            return logits

        def loss(params, batch):
            logits, aux = transformer.forward(
                cast_for_compute(params, cfg), _tokens_or_embeddings(batch),
                batch.get("positions"), cfg,
            )
            return lm_loss(logits, batch["labels"], aux)

        wins = transformer.layer_windows(cfg)
        stacked = all(w == wins[0] for w in wins)
        return Model(
            cfg=cfg,
            init=lambda key: transformer.init_params(key, cfg),
            forward=fwd,
            loss=loss,
            init_caches=lambda params, b, L, dt=jnp.bfloat16, paging=None:
                transformer.init_decode_caches(params, cfg, b, L, dt,
                                               paging=paging),
            decode=lambda params, tok, caches: transformer.decode_step(
                cast_for_compute(params, cfg), tok, caches, cfg),
            merge_caches=merge_caches_on_axis(1 if stacked else 0),
            prefill=lambda params, toks, caches, lengths:
                transformer.prefill_step(
                    cast_for_compute(params, cfg), toks, caches, lengths,
                    cfg),
        )

    if fam == "audio" or cfg.is_encoder_decoder:
        def fwd(params, batch):
            return encdec.forward(cast_for_compute(params, cfg),
                                  batch["frames"], batch["tokens"], cfg)

        def loss(params, batch):
            logits = fwd(params, batch)
            return lm_loss(logits, batch["labels"])

        def init_caches(params, b, L, dt=jnp.bfloat16, enc_out=None,
                        paging=None):
            kv = encdec.init_decode_caches(params, cfg, b, L, dt,
                                           paging=paging)
            if enc_out is None:  # shape-only path for the dry-run
                enc_out = jnp.zeros((b, 1500, cfg.d_model), dt)
            cross = encdec.precompute_cross_kv(params, enc_out, cfg)
            return {"self": kv, "cross": cross}

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=fwd,
            loss=loss,
            init_caches=init_caches,
            decode=lambda params, tok, caches: (
                lambda out: (out[0], {"self": out[1], "cross": caches["cross"]})
            )(encdec.decode_step(cast_for_compute(params, cfg), tok,
                                 caches["self"], caches["cross"], cfg)),
            merge_caches=merge_caches_on_axis(1),  # {self,cross}: (L,B,...)
            prefill=lambda params, toks, caches, lengths: (
                lambda out: (out[0], {"self": out[1],
                                      "cross": caches["cross"]})
            )(encdec.prefill_step(cast_for_compute(params, cfg), toks,
                                  caches["self"], caches["cross"], lengths,
                                  cfg)),
        )

    if fam == "hybrid":
        def loss(params, batch):
            logits, aux = hybrid.forward(
                cast_for_compute(params, cfg), batch["tokens"], None, cfg)
            return lm_loss(logits, batch["labels"], aux)

        return Model(
            cfg=cfg,
            init=lambda key: hybrid.init_params(key, cfg),
            forward=lambda params, batch: hybrid.forward(
                cast_for_compute(params, cfg), batch["tokens"], None,
                cfg)[0],
            loss=loss,
            init_caches=lambda params, b, L, dt=jnp.bfloat16, paging=None:
                hybrid.init_decode_caches(params, cfg, b, L, dt,
                                          paging=paging),
            decode=lambda params, tok, caches: hybrid.decode_step(
                cast_for_compute(params, cfg), tok, caches, cfg),
            merge_caches=merge_caches_on_axis(0),  # per-layer list: (B,...)
            prefill=lambda params, toks, caches, lengths:
                hybrid.prefill_step(cast_for_compute(params, cfg), toks,
                                    caches, lengths, cfg),
        )

    if fam == "ssm":
        def loss(params, batch):
            logits = mamba_lm_forward(
                cast_for_compute(params, cfg), batch["tokens"], cfg)
            return lm_loss(logits, batch["labels"])

        return Model(
            cfg=cfg,
            init=lambda key: mamba_lm_init(key, cfg),
            forward=lambda params, batch: mamba_lm_forward(
                cast_for_compute(params, cfg), batch["tokens"], cfg),
            loss=loss,
            init_caches=lambda params, b, L, dt=jnp.float32, paging=None:
                mamba_lm_init_caches(params, cfg, b, dt, paging=paging),
            decode=lambda params, tok, caches: mamba_lm_decode(
                cast_for_compute(params, cfg), tok, caches, cfg),
            merge_caches=merge_caches_on_axis(1),  # layer-stacked: (L,B,...)
            prefill=lambda params, toks, caches, lengths: mamba_lm_prefill(
                cast_for_compute(params, cfg), toks, caches, lengths, cfg),
        )

    raise ValueError(f"unknown family {fam!r}")


def param_count(params: Any) -> int:
    def leaf_size(a):
        return int(a.size) if hasattr(a, "size") else 0

    return sum(leaf_size(a) for a in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, total: int) -> int:
    """MoE-aware active parameter count (for MODEL_FLOPS = 6 N_active D)."""
    if cfg.moe is None:
        return total
    m = cfg.moe
    # expert params scale by top_k/num_experts; estimate expert fraction
    expert = 3 * cfg.d_model * m.d_ff * m.num_experts
    n_moe_layers = len(
        [i for i in range(cfg.num_layers)
         if i % m.layer_period == m.layer_offset]
    )
    expert_total = expert * n_moe_layers
    active_expert = expert_total * m.top_k / m.num_experts
    return int(total - expert_total + active_expert)
