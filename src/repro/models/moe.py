"""Mixture-of-Experts layer: top-k router + capacity-buffer dispatch/combine.

GShard-style dispatch adapted for GSPMD sharding:

- tokens are flattened per batch row ("group"); all position bookkeeping
  (cumsum over one-hot expert assignment) is *local to a group*, so the
  batch axis shards cleanly on ("pod","data") with no cross-device cumsum.
- dispatch/combine are batched scatters/gathers into an (E, C, d) buffer
  per group — no global (S, E, C) one-hot einsum, so memory stays
  O(tokens * top_k * capacity_factor).
- with ``cfg.moe_local_groups`` (tiny-expert models under sequence
  parallelism) the sequence folds into the group axis and dispatch runs
  in the GShard one-hot-einsum form instead — affordable because groups
  are device-local, and einsums partition where scatters replicate
  (EXPERIMENTS §Perf iteration 5).
- expert FFNs run as a single einsum over the expert axis; expert weights
  shard on "model" either by expert (EP, when E % tp == 0), by d_ff (TP
  within expert), or replicate (local-groups mode) — launch/sharding.py
  picks per arch.

Tokens overflowing an expert's capacity are dropped (standard GShard
semantics); the router uses softmax-then-top-k with normalized gates, plus
the load-balancing auxiliary loss of Shazeer et al. for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.qtensor import asarray
from repro.models.layers import Params, dense_init


def moe_init(key, cfg: ModelConfig, mcfg: MoEConfig) -> Params:
    d, ff, e = cfg.d_model, mcfg.d_ff, mcfg.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    scale_in = (2.0 / (d + ff)) ** 0.5
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "w_gate": jax.random.normal(ks[1], (e, d, ff), dt) * scale_in,
        "w_up": jax.random.normal(ks[2], (e, d, ff), dt) * scale_in,
        "w_out": jax.random.normal(ks[3], (e, ff, d), dt) * scale_in,
    }


def capacity(tokens_per_group: int, mcfg: MoEConfig) -> int:
    c = int(
        tokens_per_group * mcfg.top_k * mcfg.capacity_factor
        / mcfg.num_experts
    )
    return max(c, mcfg.top_k)


def route(
    x: jax.Array,  # (G, S, d)
    router_w: jax.Array,
    mcfg: MoEConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_idx, gates, aux_loss).

    expert_idx: (G, S, k) int32, gates: (G, S, k) f32 normalized over k,
    aux_loss: scalar load-balance loss (mean_e f_e * p_e * E, GShard eq.).
    """
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, S, E)
    gates, idx = jax.lax.top_k(probs, mcfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # load-balance aux: fraction of tokens whose top-1 is e  x  mean prob e
    top1 = idx[..., 0]
    frac = jnp.mean(
        jax.nn.one_hot(top1, mcfg.num_experts, dtype=jnp.float32), axis=(0, 1)
    )
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac * mean_p) * mcfg.num_experts
    return idx, gates, aux


def _positions_in_expert(
    idx: jax.Array, num_experts: int  # (T, k) flat per group
) -> jax.Array:
    """Arrival order of each (token, k) assignment within its expert.

    Flattens (T, k) to (T*k,) in token-major order (earlier tokens win
    capacity), one-hot cumsums per expert. Returns (T, k) int32 positions.
    """
    t, k = idx.shape
    flat = idx.reshape(t * k)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1  # position if assigned
    pos = jnp.take_along_axis(pos_all, flat[:, None], axis=1)[:, 0]
    return pos.reshape(t, k)


def moe_ffn(
    params: Params,
    x: jax.Array,  # (G, S, d)  G = batch rows (sharded on data axes)
    cfg: ModelConfig,
    mcfg: MoEConfig,
) -> tuple[jax.Array, jax.Array]:
    """MoE FFN forward. Returns (out (G,S,d), aux_loss).

    With cfg.moe_local_groups (and a model axis in the ambient mesh), the
    sequence is folded into the group axis so that every group lives on
    exactly one device: routing cumsums, dispatch scatters, expert FFNs,
    and combine gathers all run collective-free (§Perf iteration 5).
    """
    if getattr(cfg, "moe_local_groups", False):
        from repro.models.hints import _ambient_mesh, shard_hint

        mesh = _ambient_mesh()
        r = int(mesh.shape.get("model", 1)) if mesh is not None else 1
        g0, s0, d0 = x.shape
        if r > 1 and s0 % r == 0 and s0 // r >= mcfg.top_k:
            # Split the seq dim on the model-shard boundary and vmap the
            # grouped dispatch over the new axis. NB: a flat reshape
            # (G*r, S/r, d) merges a sharded dim and trips GSPMD's
            # "involuntary full rematerialization" — the 4-D split +
            # inner vmap keeps every step layout-preserving (§Perf it. 5).
            x4 = x.reshape(g0, r, s0 // r, d0)
            x4 = shard_hint(x4, ("pod", "data"), "model")
            out, aux = jax.vmap(
                lambda xr: _moe_ffn_onehot(params, xr, cfg, mcfg),
                in_axes=1, out_axes=(1, 0),
            )(x4)
            out = shard_hint(out, ("pod", "data"), "model")
            return out.reshape(g0, s0, d0), jnp.mean(aux)
    return _moe_ffn_grouped(params, x, cfg, mcfg)


def _moe_ffn_grouped(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    mcfg: MoEConfig,
) -> tuple[jax.Array, jax.Array]:
    g, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    c = capacity(s, mcfg)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu

    idx, gates, aux = route(x, asarray(params["router"], jnp.float32), mcfg)

    def dispatch_one(xg, idxg, gatesg):
        # xg: (S, d), idxg: (S, k), gatesg: (S, k)
        pos = _positions_in_expert(idxg, e)  # (S, k)
        keep = pos < c
        gatesg = jnp.where(keep, gatesg, 0.0)
        pos_c = jnp.where(keep, pos, c)  # overflow -> scratch slot c
        buf = jnp.zeros((e, c + 1, d), xg.dtype)
        xk = jnp.broadcast_to(xg[:, None, :], (s, k, d)).reshape(s * k, d)
        buf = buf.at[idxg.reshape(-1), pos_c.reshape(-1)].add(xk)
        return buf[:, :c], pos_c, gatesg

    buf, pos_c, gates = jax.vmap(dispatch_one)(x, idx, gates)
    # buf: (G, E, C, d) -> expert FFN einsum (E is a batch dim)
    wg = asarray(params["w_gate"], x.dtype)
    wu = asarray(params["w_up"], x.dtype)
    wo = asarray(params["w_out"], x.dtype)
    h = act(jnp.einsum("gecd,edf->gecf", buf, wg)) * jnp.einsum(
        "gecd,edf->gecf", buf, wu
    )
    y = jnp.einsum("gecf,efd->gecd", h, wo)  # (G, E, C, d)

    def combine_one(yg, idxg, posg, gatesg):
        # yg: (E, C, d); gather each (token, k) result and gate-sum over k
        yg_pad = jnp.concatenate([yg, jnp.zeros((e, 1, d), yg.dtype)], axis=1)
        got = yg_pad[idxg.reshape(-1), posg.reshape(-1)].reshape(s, k, d)
        return jnp.sum(got * gatesg[..., None].astype(yg.dtype), axis=1)

    out = jax.vmap(combine_one)(y, idx, pos_c, gates)
    return out, aux


def _moe_ffn_onehot(
    params: Params,
    x: jax.Array,  # (G, S', d) — S' small (seq/model_shards)
    cfg: ModelConfig,
    mcfg: MoEConfig,
) -> tuple[jax.Array, jax.Array]:
    """GShard one-hot einsum dispatch/combine — only affordable with
    local groups (the (S', E, C) one-hot is per-device small), and unlike
    the scatter path it partitions cleanly under vmap-over-shards: every
    op is an einsum, GSPMD's strong suit (§Perf iteration 5 v3: the
    scatter/gather dispatch replicated activations under a sharded vmap).
    """
    g, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    c = capacity(s, mcfg)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu

    idx, gates, aux = route(x, asarray(params["router"], jnp.float32), mcfg)
    pos = jax.vmap(lambda i: _positions_in_expert(i, e))(idx)  # (G, S, k)
    keep = pos < c
    gates = jnp.where(keep, gates, 0.0)
    # dispatch one-hot (G, S, E, C) = [idx==e] x [pos==c], summed over k
    e_oh = jax.nn.one_hot(idx, e, dtype=x.dtype)  # (G, S, k, E)
    c_oh = jax.nn.one_hot(jnp.where(keep, pos, c), c, dtype=x.dtype)
    disp = jnp.einsum("gske,gskc->gsec", e_oh, c_oh)
    comb = jnp.einsum(
        "gske,gskc->gsec", e_oh * gates[..., None].astype(x.dtype), c_oh
    )
    buf = jnp.einsum("gsec,gsd->gecd", disp, x)
    wg = asarray(params["w_gate"], x.dtype)
    wu = asarray(params["w_up"], x.dtype)
    wo = asarray(params["w_out"], x.dtype)
    h = act(jnp.einsum("gecd,edf->gecf", buf, wg)) * jnp.einsum(
        "gecd,edf->gecf", buf, wu
    )
    y = jnp.einsum("gecf,efd->gecd", h, wo)
    out = jnp.einsum("gsec,gecd->gsd", comb, y)
    return out, aux


def moe_ffn_per_token(
    params: Params, x: jax.Array, cfg: ModelConfig, mcfg: MoEConfig
) -> tuple[jax.Array, jax.Array]:
    """Decode-identical MoE for one-shot batched prefill.

    Folds the sequence into the group axis so every token routes in its
    own group of one — exactly the capacity situation of a single decode
    step (capacity >= top_k, so nothing is ever dropped). The serving
    prefill uses this so one-shot admission reproduces the
    token-by-token path bit-for-bit in routing decisions; training and
    the roofline prefill cells keep the grouped capacity-buffer form.
    """
    g, s, d = x.shape
    out, aux = moe_ffn(params, x.reshape(g * s, 1, d), cfg, mcfg)
    return out.reshape(g, s, d), aux


def moe_ffn_dense(
    params: Params, x: jax.Array, cfg: ModelConfig, mcfg: MoEConfig
) -> tuple[jax.Array, jax.Array]:
    """Reference dropless MoE: every expert on every token, gate-masked.

    O(E/k) more FLOPs than dispatch — used as the numerics oracle in tests
    (dispatch must match where no token was capacity-dropped).
    """
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    idx, gates, aux = route(x, asarray(params["router"], jnp.float32), mcfg)
    wg = asarray(params["w_gate"], x.dtype)
    wu = asarray(params["w_up"], x.dtype)
    wo = asarray(params["w_out"], x.dtype)
    h = act(jnp.einsum("gsd,edf->gsef", x, wg)) * jnp.einsum(
        "gsd,edf->gsef", x, wu
    )
    y = jnp.einsum("gsef,efd->gsed", h, wo)  # (G, S, E, d)
    dense_gates = jnp.zeros(y.shape[:3], jnp.float32)
    dense_gates = jax.vmap(
        lambda dg, i, gt: dg.at[jnp.arange(x.shape[1])[:, None], i].add(gt)
    )(dense_gates, idx, gates)
    out = jnp.sum(y * dense_gates[..., None].astype(y.dtype), axis=2)
    return out, aux
