"""Mamba2 block with the SSD (state-space duality) chunked algorithm.

Follows arXiv:2405.21060: the sequence is processed in chunks; within a
chunk the recurrence is computed in its quadratic "attention-like" dual
form (MXU-friendly matmuls), and chunk states are stitched with a short
scan — O(L) total work with O(chunk^2) blocks.

Train/prefill: ``ssd_forward`` (returns final state for decode handoff).
Decode: ``ssd_step`` — O(1) per token, state (B, H, P, N).

PQS note (DESIGN.md §Arch-applicability): the in/out/x projections are
ordinary matmuls and take QTensor weights; the SSD recurrence itself
accumulates decayed fp32 state, not an integer dot product, so sorted
narrow accumulation does not apply inside the scan.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, lin, rms_norm


def ssm_dims(cfg: ModelConfig) -> dict[str, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.d_state
    return dict(
        d_inner=d_inner,
        nheads=nheads,
        d_xbc=d_xbc,
        d_in_proj=d_inner + d_xbc + nheads,  # z, xBC, dt
    )


def mamba_init(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    dims = ssm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    # dt bias initialized so softplus(dt_bias) spans [dt_min, dt_max]
    u = jax.random.uniform(ks[3], (dims["nheads"],), jnp.float32)
    dt_init = jnp.exp(
        u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inv softplus
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, dims["d_in_proj"], dt),
        "conv_w": jax.random.normal(ks[1], (s.d_conv, dims["d_xbc"]), jnp.float32)
        * (1.0 / s.d_conv) ** 0.5,
        "conv_b": jnp.zeros((dims["d_xbc"],), jnp.float32),
        "a_log": jnp.log(
            jnp.arange(1, dims["nheads"] + 1, dtype=jnp.float32)
        ),  # A = -exp(a_log), mamba2 default init A in [-1, -H]
        "dt_bias": dt_bias,
        "d_skip": jnp.ones((dims["nheads"],), jnp.float32),
        "out_norm": jnp.zeros((dims["d_inner"],), jnp.float32),
        "out_proj": dense_init(ks[4], dims["d_inner"], cfg.d_model, dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, L, D), w: (K, D)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i]
        for i in range(k)
    )
    return jax.nn.silu(out + b).astype(xbc.dtype)


def _ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) f32, post-softplus
    a: jax.Array,  # (H,) f32 negative
    bmat: jax.Array,  # (B, L, G, N)
    cmat: jax.Array,  # (B, L, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc, q = l // chunk, chunk
    rep = h // g

    # head-broadcast B and C
    bmat = jnp.repeat(bmat, rep, axis=2)  # (B, L, H, N)
    cmat = jnp.repeat(cmat, rep, axis=2)

    xt = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = bmat.reshape(bsz, nc, q, h, n)
    cc = cmat.reshape(bsz, nc, q, h, n)

    da = dtc * a  # (B, nc, q, H) negative decay increments
    cs = jnp.cumsum(da, axis=2)  # within-chunk cumulative decay
    tot = cs[:, :, -1:, :]  # (B, nc, 1, H)

    # ---- intra-chunk (dual quadratic form) ----
    # L[i, j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,q_i,q_j,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cc.astype(jnp.float32),
                    bc.astype(jnp.float32))
    att = cb * decay * dtc[:, :, None, :, :]  # weight dt_j on column j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xt.astype(jnp.float32))

    # ---- chunk states ----
    # S_c = sum_j exp(tot - cs_j) * dt_j * B_j ⊗ x_j   (B,nc,H,P,N)
    decay_to_end = jnp.exp(tot - cs)  # (B, nc, q, H)
    wx = xt.astype(jnp.float32) * (decay_to_end * dtc)[..., None]
    s_chunk = jnp.einsum("bcqhp,bcqhn->bchpn", wx, bc.astype(jnp.float32))

    # ---- inter-chunk recurrence over nc ----
    chunk_decay = jnp.exp(tot[:, :, 0, :])  # (B, nc, H)
    init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def scan_body(carry, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        prev = carry
        new = dec[:, :, None, None] * prev + s_c
        return new, prev  # emit state *before* this chunk

    final, prev_states = jax.lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    # ---- off-diagonal: carry-in state contribution ----
    cin = cc.astype(jnp.float32) * jnp.exp(cs)[..., None]  # (B,nc,q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", cin, prev_states)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y.astype(x.dtype), final


def mamba_forward(
    params: Params,
    x: jax.Array,  # (B, L, d_model)
    cfg: ModelConfig,
    h0: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,  # (B,) — prefill valid lengths
) -> tuple[jax.Array, Any]:
    """Full-sequence Mamba2 block. Returns (out, final_ssd_state).

    With ``lengths`` (one-shot batched prefill): positions t >=
    lengths[b] get dt forced to 0, which makes the recurrence an exact
    identity there (decay exp(0)=1, input weight dt=0) — so the final
    state of lane b is its state after exactly lengths[b] tokens, and
    the return value becomes (out, {"ssd", "conv"}) — a full decode
    cache including the conv ring (last d_conv-1 *raw* xBC inputs per
    lane, zero-padded like a fresh ring for short prompts).
    """
    s = cfg.ssm
    dims = ssm_dims(cfg)
    bsz, l, _ = x.shape
    hh, pp = dims["nheads"], s.head_dim

    zxbcdt = lin(x, params["in_proj"], site="in_proj")
    z, xbc, dtv = jnp.split(
        zxbcdt, [dims["d_inner"], dims["d_inner"] + dims["d_xbc"]], axis=-1
    )
    xbc_raw = xbc  # pre-conv inputs: what the decode conv ring stores
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xi, bmat, cmat = jnp.split(
        xbc, [dims["d_inner"], dims["d_inner"] + s.n_groups * s.d_state], axis=-1
    )
    dtv = jax.nn.softplus(
        dtv.astype(jnp.float32) + params["dt_bias"]
    )  # (B, L, H)
    if lengths is not None:
        valid = jnp.arange(l)[None, :] < lengths[:, None]  # (B, L)
        dtv = dtv * valid[:, :, None]
    a = -jnp.exp(params["a_log"])  # (H,)

    xh = xi.reshape(bsz, l, hh, pp)
    bmat = bmat.reshape(bsz, l, s.n_groups, s.d_state)
    cmat = cmat.reshape(bsz, l, s.n_groups, s.d_state)

    chunk = min(s.chunk, l)
    y, final = _ssd_chunked(xh, dtv, a, bmat, cmat, chunk, h0)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, l, dims["d_inner"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"])
    out = lin(y, params["out_proj"], site="out_proj")
    if lengths is None:
        return out, final
    # conv ring: the last (d_conv - 1) raw inputs BEFORE each lane's end,
    # zeros where the prompt is shorter than the ring (matches a fresh
    # ring that shifted in `lengths` tokens)
    km1 = s.d_conv - 1
    idx = lengths[:, None] - km1 + jnp.arange(km1)[None, :]  # (B, K-1)
    took = jnp.take_along_axis(
        xbc_raw, jnp.maximum(idx, 0)[:, :, None], axis=1
    )
    conv = jnp.where(idx[:, :, None] >= 0, took, 0).astype(xbc_raw.dtype)
    return out, {"ssd": final, "conv": conv}


def empty_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    dims = ssm_dims(cfg)
    return {
        "ssd": jnp.zeros(
            (batch, dims["nheads"], s.head_dim, s.d_state), jnp.float32
        ),
        "conv": jnp.zeros((batch, s.d_conv - 1, dims["d_xbc"]), dtype),
    }


def mamba_step(
    params: Params,
    x: jax.Array,  # (B, 1, d_model)
    cache: dict[str, jax.Array],
    cfg: ModelConfig,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """O(1) single-token decode step."""
    s = cfg.ssm
    dims = ssm_dims(cfg)
    bsz = x.shape[0]
    hh, pp = dims["nheads"], s.head_dim

    zxbcdt = lin(x[:, 0], params["in_proj"], site="in_proj")  # (B, d_in_proj)
    z, xbc, dtv = jnp.split(
        zxbcdt, [dims["d_inner"], dims["d_inner"] + dims["d_xbc"]], axis=-1
    )
    # conv ring: window = last (d_conv-1) inputs + current
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,D)
    conv_out = jnp.einsum(
        "bkd,kd->bd", win.astype(jnp.float32), params["conv_w"]
    )
    xbc_c = jax.nn.silu(conv_out + params["conv_b"]).astype(x.dtype)
    new_conv = win[:, 1:, :]

    xi, bmat, cmat = jnp.split(
        xbc_c, [dims["d_inner"], dims["d_inner"] + s.n_groups * s.d_state],
        axis=-1,
    )
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    rep = hh // s.n_groups

    xh = xi.reshape(bsz, hh, pp).astype(jnp.float32)
    bm = jnp.repeat(
        bmat.reshape(bsz, s.n_groups, s.d_state), rep, axis=1
    ).astype(jnp.float32)
    cm = jnp.repeat(
        cmat.reshape(bsz, s.n_groups, s.d_state), rep, axis=1
    ).astype(jnp.float32)

    da = jnp.exp(dtv * a)  # (B, H)
    h_new = (
        da[:, :, None, None] * cache["ssd"]
        + (dtv[:, :, None] * xh)[..., None] * bm[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, cm)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, dims["d_inner"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"])
    out = lin(y, params["out_proj"], site="out_proj")[:, None, :]
    return out, {"ssd": h_new, "conv": new_conv}
