"""Decoder-only LM: train forward, prefill, and KV-cache decode.

Covers the dense / vlm / moe families (qwen2*, qwen3, command-r, gemma3,
granite-moe). Layers are homogeneous, so parameters are *stacked* along
axis 0 and the layer loop is a ``jax.lax.scan`` (fast compiles at 80
layers, GSPMD-friendly: the per-layer all-gather of FSDP-sharded weights
happens inside the loop body). Gemma3's 5:1 local:global pattern rides the
same scan via a traced per-layer ``is_local`` flag.

Decode uses a python loop over layers when the arch mixes cache sizes
(sliding-window rings for local layers, full KV for global ones) and a
scanned stacked cache otherwise.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.hints import hint_batch, hint_batch_seq, hint_logits
from repro.models.layers import (
    Params,
    attention,
    attention_decode,
    attn_init,
    dense_init,
    empty_kv_cache,
    lin,
    mlp,
    mlp_init,
    norm,
    norm_init,
    write_prefill_kv,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {
        "ln1": norm_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg),
        "ln2": norm_init(cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(ks[1], cfg, cfg.moe)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.num_layers + 2)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(keys[: cfg.num_layers])
    p: Params = {
        "layers": stacked,
        "ln_f": norm_init(cfg.d_model),
    }
    if (not cfg.input_is_embeddings) or cfg.tie_embeddings:
        p["embed"] = (
            jax.random.normal(
                keys[-2], (cfg.vocab_size, cfg.d_model), jnp.dtype(cfg.param_dtype)
            )
            * (1.0 / cfg.d_model**0.5)
        )
    if not cfg.tie_embeddings:
        p["head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size,
                               jnp.dtype(cfg.param_dtype))
    return p


def layer_windows(cfg: ModelConfig) -> list[Optional[int]]:
    """Static per-layer sliding window (None = global attention)."""
    out: list[Optional[int]] = []
    for i in range(cfg.num_layers):
        if cfg.sliding_window is not None and cfg.global_period is not None:
            is_global = (i % cfg.global_period) == cfg.global_period - 1
            out.append(None if is_global else cfg.sliding_window)
        elif cfg.sliding_window is not None:
            out.append(cfg.sliding_window)
        else:
            out.append(None)
    return out


def is_local_flags(cfg: ModelConfig) -> jax.Array:
    return jnp.asarray([w is not None for w in layer_windows(cfg)])


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig):
    """tokens (B,S) int32 -> (B,S,d) activations, or pass embeddings through."""
    dt = jnp.dtype(cfg.compute_dtype)
    if tokens.dtype in (jnp.int32, jnp.int64):
        from repro.core.qtensor import asarray

        x = asarray(params["embed"], dt)[tokens]
    else:
        x = tokens.astype(dt)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    return x


def logits_from_hidden(params: Params, x: jax.Array, cfg: ModelConfig):
    x = norm(x, params["ln_f"], cfg)
    if cfg.tie_embeddings:
        from repro.core.qtensor import asarray

        return x @ asarray(params["embed"], x.dtype).T
    return lin(x, params["head"], site="head")


def _layer_body(p: Params, x, positions, is_local, *, cfg: ModelConfig,
                window: Optional[int]):
    """One pre-norm transformer layer. Returns (x, aux_loss)."""
    h = attention(
        p["attn"], norm(x, p["ln1"], cfg), positions, cfg,
        causal=True, window=window, use_window=is_local,
    )
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        h, aux = moe_lib.moe_ffn(p["moe"], norm(x, p["ln2"], cfg), cfg, cfg.moe)
    else:
        h = mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
    return x + h, aux


def forward(
    params: Params,
    tokens: jax.Array,  # (B, S) int32 or (B, S, d) embeddings
    positions: Optional[jax.Array] = None,
    cfg: ModelConfig = None,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, moe_aux_loss)."""
    b = tokens.shape[0]
    s = tokens.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions, (3, b, s))
    hint = hint_batch_seq if cfg.seq_parallel else hint_batch
    x = hint(embed_tokens(params, tokens, cfg))

    window = cfg.sliding_window
    flags = is_local_flags(cfg)

    def body(carry, inp):
        x, aux = carry
        p, flag = inp
        fn = partial(_layer_body, cfg=cfg, window=window)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, a = fn(p, x, positions, flag)
        return (hint(x), aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], flags),
                               unroll=cfg.scan_unroll)
    logits = hint_logits(logits_from_hidden(params, x, cfg))
    return logits, aux / max(cfg.num_layers, 1)


# ---------------------------------------------------------------------------
# one-shot batched prefill (serving admission path)
# ---------------------------------------------------------------------------


def prefill_step(
    params: Params,
    tokens: jax.Array,  # (B, S) int32 or (B, S, d) embeddings; left-aligned
    caches: Any,
    lengths: jax.Array,  # (B,) int32 — valid prompt tokens per slot (0=skip)
    cfg: ModelConfig,
) -> tuple[jax.Array, Any]:
    """Consume whole prompts in ONE batched step, filling decode caches.

    Functionally equivalent to feeding each slot's tokens[b, :lengths[b]]
    through ``decode_step`` one position at a time, but executed as a
    single full-sequence forward: per-layer post-RoPE K/V are captured
    (unexpanded) and scattered into the per-slot cache lanes, masked by
    ``lengths`` — padded tail positions never touch the cache, and
    causality keeps them from influencing valid positions. Returns
    (logits (B, S, V), new_caches) with ``pos = lengths``.
    """
    b = tokens.shape[0]
    s = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions, (3, b, s))
    x = hint_batch(embed_tokens(params, tokens, cfg))

    window = cfg.sliding_window
    wins = layer_windows(cfg)
    flags = is_local_flags(cfg)
    homogeneous = all(w == wins[0] for w in wins)

    def one_layer(p, x, cache, flag, win):
        h, (k, v) = attention(
            p["attn"], norm(x, p["ln1"], cfg), positions, cfg,
            causal=True, window=win, use_window=flag, return_kv=True,
        )
        x = x + h
        if cfg.moe is not None:
            # per-token routing: identical capacity situation to decode,
            # so prefill never capacity-drops a token decode would keep
            h, _ = moe_lib.moe_ffn_per_token(
                p["moe"], norm(x, p["ln2"], cfg), cfg, cfg.moe)
        else:
            h = mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return x + h, write_prefill_kv(cache, k, v, lengths)

    if homogeneous:
        def body(x, inp):
            p, flag, cache = inp
            x, new_cache = one_layer(p, x, cache, flag, wins[0])
            return hint_batch(x), new_cache

        x, new_caches = jax.lax.scan(
            body, x, (params["layers"], flags, caches),
            unroll=cfg.scan_unroll,
        )
    else:
        new_caches = []
        for i, win in enumerate(wins):
            p = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, nc = one_layer(p, x, caches[i], flags[i], win)
            new_caches.append(nc)
    return hint_logits(logits_from_hidden(params, x, cfg)), new_caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_caches(
    params: Params, cfg: ModelConfig, batch: int, max_len: int, dtype,
    paging=None,
) -> Any:
    """Stacked (homogeneous) or per-layer-list (mixed-window) caches.

    With ``paging`` (a ``serving.paged_cache.PagedSpec``) global layers
    get pool-backed paged KV; sliding-window layers keep dense rings —
    they are already O(window) per slot, so paging buys them nothing.
    """
    wins = layer_windows(cfg)

    def one(win):
        if paging is not None and win is None:
            from repro.serving import paged_cache as pc

            return pc.empty_paged_kv(batch, paging, cfg.num_kv_heads,
                                     cfg.resolved_head_dim, dtype)
        return empty_kv_cache(cfg, batch, max_len, win, dtype)

    if all(w == wins[0] for w in wins):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
            one(wins[0]),
        )
    return [one(w) for w in wins]


def decode_step(
    params: Params,
    token: jax.Array,  # (B, 1) int32 or (B, 1, d) embeddings
    caches: Any,
    cfg: ModelConfig,
) -> tuple[jax.Array, Any]:
    """One decode step; returns (logits (B,1,V), new_caches)."""
    x = embed_tokens(params, token, cfg)
    wins = layer_windows(cfg)
    homogeneous = all(w == wins[0] for w in wins)

    def one_layer(p, x, cache, window):
        h, new_cache = attention_decode(
            p["attn"], norm(x, p["ln1"], cfg), cache, cfg, window=window
        )
        x = x + h
        if cfg.moe is not None:
            h, _ = moe_lib.moe_ffn(p["moe"], norm(x, p["ln2"], cfg), cfg, cfg.moe)
        else:
            h = mlp(p["mlp"], norm(x, p["ln2"], cfg), cfg)
        return x + h, new_cache

    if homogeneous:
        def body(x, inp):
            p, cache = inp
            x, new_cache = one_layer(p, x, cache, wins[0])
            return hint_batch(x), new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches),
                                     unroll=cfg.scan_unroll)
    else:
        new_caches = []
        for i, w in enumerate(wins):
            p = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, nc = one_layer(p, x, caches[i], w)
            new_caches.append(nc)
    return hint_logits(logits_from_hidden(params, x, cfg)), new_caches


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(
    logits: jax.Array,  # (B, S, V)
    labels: jax.Array,  # (B, S) int32; -1 = ignore
    aux: jax.Array = 0.0,
    aux_weight: float = 0.01,
    z_weight: float = 1e-4,
) -> jax.Array:
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(
        lg, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * valid
    denom = jnp.maximum(jnp.sum(valid), 1.0)
    z_loss = jnp.sum((lse**2) * valid) / denom
    return jnp.sum(nll) / denom + aux_weight * aux + z_weight * z_loss
