from repro.optim.optim import (  # noqa: F401
    OptState,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup,
    sgd_momentum,
)
