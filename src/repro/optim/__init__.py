from repro.optim.a2q import (  # noqa: F401
    a2q_l1_ratio,
    a2q_project_tree,
    with_a2q_projection,
)
from repro.optim.optim import (  # noqa: F401
    OptState,
    Optimizer,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup,
    sgd_momentum,
)
