"""A2Q+-style per-channel weight-norm projection as an optimizer transform.

`core.a2q` enforces the accumulator bound in the *integer* domain (the only
domain where it is exact); this module supplies the training-side
complement: after every optimizer step, each output channel of every large
float weight is softly projected toward the scale-invariant shape condition

    ||w||_1 / ||w||_inf  <=  ratio := (2^(p-1) - 1) / 2^(b-1) / qmax_w

which is what per-channel max-calibrated quantization turns the integer L1
bound into (see `core.a2q`'s module docstring). Keeping iterates near the
certifiable region means the STE projection inside `a2q_fake_quant`
truncates little and gradients stay informative — this is the role of
A2Q+'s weight-normalization reparameterization, realized here as a
soft-threshold projection (per-row bisection on the threshold) so it
composes with any `optim.Optimizer` unchanged.

The projection is a pre-conditioner, not the guarantee: the guarantee is
the integer-domain enforcement (`core.certify.enforce_acc_bounds`) plus
the certification pass that follows training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optim import Optimizer

Pytree = jax.Array | dict | list | tuple


def a2q_l1_ratio(
    weight_bits: int = 8, acc_bits: int = 16, act_bits: int = 8
) -> float:
    """Float-domain shape cap ||w||_1/||w||_inf for certifiable rows.

    Sufficient (sign-agnostic) form: a quantized row with
    ||w^q||_1 <= (2^(p-1)-1)/2^(b-1) keeps both sign-split excursions
    inside the p-bit caps for any admissible b-bit activation code; with
    max calibration ||w^q||_1 ~= ||w||_1 * qmax_w / ||w||_inf.
    """
    cap_pos = 2 ** (acc_bits - 1) - 1
    qmax_w = 2 ** (weight_bits - 1) - 1
    return cap_pos / (2 ** (act_bits - 1)) / qmax_w


def _soft_threshold_rows(
    v: jax.Array, ratio: float, iters: int = 25, outer: int = 2
) -> jax.Array:
    """Project rows (C, K) toward ||v||_1 <= ratio * ||v||_inf.

    Per row: bisect the soft threshold lam so that
    sum(relu(|v| - lam)) <= ratio * ||v||_inf, apply
    sign(v) * relu(|v| - lam). Thresholding also shrinks the max, so a
    couple of outer sweeps re-anchor the target; rows already inside the
    region pass through bit-exactly (lam = 0).
    """
    for _ in range(outer):
        a = jnp.abs(v)
        amax = jnp.max(a, axis=-1, keepdims=True)
        target = ratio * amax
        need = jnp.sum(a, axis=-1, keepdims=True) > target
        lo = jnp.zeros_like(amax)
        hi = amax
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            s = jnp.sum(jnp.maximum(a - mid, 0.0), axis=-1, keepdims=True)
            over = s > target
            lo = jnp.where(over, mid, lo)
            hi = jnp.where(over, hi, mid)
        lam = jnp.where(need, hi, 0.0)
        v = jnp.sign(v) * jnp.maximum(a - lam, 0.0)
    return v


def a2q_project_tree(
    params: Pytree,
    weight_bits: int = 8,
    acc_bits: int = 16,
    act_bits: int = 8,
    min_dim: int = 16,
) -> Pytree:
    """Shape-project every large float matrix, channelwise. Pytree in/out.

    Targets the same leaves QAT fake-quantizes and quantization will
    later convert: float leaves with >= 2 dims and min(last two dims) >=
    ``min_dim`` (norm gains, biases, tiny heads pass through). Output
    channels are the LAST axis ((…, in, out) convention), matching
    `core.a2q`'s per-(out)-channel rows.
    """
    ratio = a2q_l1_ratio(weight_bits, acc_bits, act_bits)

    def conv(leaf):
        if not isinstance(leaf, jax.Array) and not hasattr(leaf, "dtype"):
            return leaf
        if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if min(leaf.shape[-2:]) < min_dim:
            return leaf
        wt = jnp.swapaxes(leaf.astype(jnp.float32), -1, -2)
        rows = wt.reshape(-1, wt.shape[-1])
        proj = _soft_threshold_rows(rows, ratio)
        out = jnp.swapaxes(proj.reshape(wt.shape), -1, -2)
        return out.astype(leaf.dtype)

    return jax.tree_util.tree_map(conv, params)


def with_a2q_projection(
    opt: Optimizer,
    weight_bits: int = 8,
    acc_bits: int = 16,
    act_bits: int = 8,
    min_dim: int = 16,
) -> Optimizer:
    """Wrap an optimizer so every update lands near the certifiable region.

    The A2Q+ step order: inner update first (AdamW, SGD, anything with
    the `optim.Optimizer` contract), then the per-channel weight-norm
    projection on the new params. Optimizer state is untouched — moments
    keep tracking the unprojected dynamics, mirroring how A2Q+ trains
    through its normalization reparameterization.
    """

    def update(grads, state, params):
        new_params, new_state = opt.update(grads, state, params)
        return a2q_project_tree(
            new_params, weight_bits, acc_bits, act_bits, min_dim
        ), new_state

    return Optimizer(opt.init, update)
