"""From-scratch optimizers: AdamW, SGD+momentum, schedules, gradient clip.

Functional style: an optimizer is a pair (init_fn, update_fn) over pytrees.
Optimizer state mirrors the param tree leaf-for-leaf, so pjit shards it
exactly like the params (ZeRO-3: sharded first/second moments).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array  # i32 scalar
    mu: Pytree  # first moment (or momentum buffer)
    nu: Optional[Pytree]  # second moment (None for SGD)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple[Pytree, OptState]]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def linear_warmup(base_lr: float, warmup_steps: int) -> Callable:
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return base_lr * frac

    return fn


def cosine_schedule(
    base_lr: float, total_steps: int, warmup_steps: int = 0,
    final_frac: float = 0.1,
) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup_steps, warm, cos)

    return fn


# ---------------------------------------------------------------------------
# gradient clipping
# ---------------------------------------------------------------------------


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    ), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jax.tree_util.tree_map(  # noqa: E731
            lambda a: jnp.zeros(a.shape, jnp.float32), p
        )
        return OptState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            dp = mh / (jnp.sqrt(vh) + eps)
            # decoupled weight decay on >=2-D leaves only (skip norms/bias)
            if p.ndim >= 2:
                dp = dp + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * dp).astype(p.dtype)
            return new_p, m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_m, new_v)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def sgd_momentum(
    lr: float | Callable = 1e-2,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = None,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        mu = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, jnp.float32), params
        )
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                g = g + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g
            return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            treedef.unflatten([o[0] for o in out]),
            OptState(step, treedef.unflatten([o[1] for o in out]), None),
        )

    return Optimizer(init, update)
