from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureInjector,
    StragglerMonitor,
    TrainSupervisor,
    elastic_remesh,
)
