from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureInjector,
    ServeSupervisor,
    StragglerMonitor,
    TrainSupervisor,
    default_retryable,
    elastic_remesh,
)
