from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureInjector,
    ServeSupervisor,
    StragglerMonitor,
    TrainSupervisor,
    default_retryable,
    elastic_remesh,
)
from repro.runtime.qat import (  # noqa: F401
    QATConfig,
    a2q_finetune,
    quantize_and_certify,
)
