"""Fault tolerance runtime: checkpoint/restart, stragglers, elastic re-mesh.

Production framing (DESIGN.md §6), CPU-simulatable components:

- ``TrainSupervisor`` — drives a train step under failure: on an injected
  or real exception it restores the latest checkpoint and resumes, with
  bounded restarts. Data-iterator state is checkpointed too, so restart
  replays no batch twice.
- ``StragglerMonitor`` — per-step deadline from a rolling p50×k rule; on a
  real fleet the signal piggybacks on the existing all-reduce (no extra
  collectives): each host contributes its last step time into a tiny
  padded lane of the gradient buffer; slow hosts are flagged for preemptive
  re-scheduling. Here the aggregation is simulated over reported times.
- ``elastic_remesh`` — rebuild a smaller/larger mesh after losing or
  gaining hosts and re-shard a checkpointed state onto it. The batch axis
  shrinks; training resumes at the same step with the same params (tested
  at toy scale on CPU devices).
- ``ServeSupervisor`` — the serving analogue: drives a ``ServingFleet``
  step loop, restoring crashed engines from their latest serving-state
  snapshot (optionally remeshing onto survivors first via the
  ``on_failure`` hook) with the same bounded-restart budget.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def default_retryable() -> tuple[type[BaseException], ...]:
    """Exception types a supervisor treats as recoverable node failures.

    Device loss / runtime aborts surface from jax as
    ``jaxlib.xla_extension.XlaRuntimeError``. On current jaxlib that class
    subclasses RuntimeError so the plain default already covers it, but
    the subclassing is not contractual — list it explicitly so the
    default survives a jaxlib that moves it off RuntimeError.
    """
    types: list[type[BaseException]] = [RuntimeError]
    try:
        from jaxlib.xla_extension import XlaRuntimeError

        types.append(XlaRuntimeError)
    except ImportError:
        pass
    try:
        from jax.errors import JaxRuntimeError

        types.append(JaxRuntimeError)
    except ImportError:
        pass
    return tuple(dict.fromkeys(types))


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.failures = 0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerReport:
    step: int
    times: dict[int, float]  # host -> seconds
    stragglers: list[int]
    deadline: float


class StragglerMonitor:
    """Rolling-median deadline straggler detection.

    A host is a straggler when its step time exceeds ``k`` x the rolling
    median of the fleet. Mitigation hooks: the supervisor can drop the
    host from the mesh (elastic_remesh) or re-dispatch its shard.
    """

    def __init__(self, k: float = 2.0, window: int = 32):
        self.k = k
        self.history: deque[float] = deque(maxlen=window)

    def observe(self, step: int, host_times: dict[int, float]) -> StragglerReport:
        med = float(np.median(list(host_times.values())))
        self.history.append(med)
        deadline = self.k * float(np.median(self.history))
        stragglers = [h for h, t in host_times.items() if t > deadline]
        return StragglerReport(step, host_times, stragglers, deadline)


def elastic_remesh(
    state: Any,
    make_mesh: Callable[[int], jax.sharding.Mesh],
    new_num_devices: int,
    sharding_rule: Callable[[jax.sharding.Mesh], Any],
) -> tuple[Any, jax.sharding.Mesh]:
    """Re-shard ``state`` onto a mesh over ``new_num_devices``.

    ``sharding_rule(mesh)`` returns a pytree of NamedShardings matching
    ``state`` (same rule used at startup, evaluated on the new mesh) —
    shrink/grow happens purely through the mesh shape.
    """
    from repro.launch.sharding import place_tree

    mesh = make_mesh(new_num_devices)
    return place_tree(state, sharding_rule(mesh)), mesh


class TrainSupervisor:
    """Checkpoint/restart training driver with bounded restarts.

    step_fn(state, batch) -> (state, metrics); state is any pytree.
    data_state/data_restore checkpoint the input pipeline position.
    """

    def __init__(
        self,
        ckpt_dir: str,
        step_fn: Callable,
        ckpt_every: int = 50,
        max_restarts: int = 5,
        failure_injector: Optional[FailureInjector] = None,
        retryable: Optional[tuple[type[BaseException], ...]] = None,
        reset_after: int = 0,
    ):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = failure_injector
        self.retryable = retryable if retryable is not None else default_retryable()
        # after this many consecutive clean steps the restart budget
        # refills — long runs aren't killed by unrelated sporadic faults
        self.reset_after = reset_after
        self.restarts = 0
        self.step_times: list[float] = []

    def run(
        self,
        state: Any,
        next_batch: Callable[[], Any],
        num_steps: int,
        data: Any = None,  # object with .state()/.restore() (TokenStream)
        start_step: int = 0,
    ) -> tuple[Any, int]:
        step = start_step
        # entry-state snapshot: the restart-from-scratch path must rewind
        # to *this* state and data position, not whatever the failed step
        # left behind (host copies — state may alias donated buffers)
        init_state = jax.tree_util.tree_map(np.asarray, state)
        init_data = dict(data.state()) if data is not None else None
        clean_steps = 0
        # resume if a checkpoint exists
        if latest_step(self.ckpt_dir) is not None:
            payload, ck_step = restore_checkpoint(
                self.ckpt_dir, self._payload(state, data)
            )
            state = payload["state"]
            if data is not None:
                data.restore(
                    {"step": int(payload["data_step"]), "seed": 0, "host_id": 0}
                )
            step = ck_step

        while step < num_steps:
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                batch = next_batch()
                state, _metrics = self.step_fn(state, batch)
                self.step_times.append(time.perf_counter() - t0)
                step += 1
                clean_steps += 1
                if self.reset_after and clean_steps >= self.reset_after:
                    self.restarts = 0
                if step % self.ckpt_every == 0 or step == num_steps:
                    save_checkpoint(
                        self.ckpt_dir, step, self._payload(state, data)
                    )
            except self.retryable:
                self.restarts += 1
                clean_steps = 0
                if self.restarts > self.max_restarts:
                    raise
                ck = latest_step(self.ckpt_dir)
                if ck is None:
                    # restart from scratch: rewind to the entry snapshot,
                    # not the mid-failure state/data position
                    step = start_step
                    state = init_state
                    if data is not None:
                        data.restore(dict(init_data))
                    continue
                payload, step = restore_checkpoint(
                    self.ckpt_dir, self._payload(state, data)
                )
                state = payload["state"]
                if data is not None:
                    data.restore(
                        {"step": int(payload["data_step"]), "seed": 0, "host_id": 0}
                    )
        return state, step

    @staticmethod
    def _payload(state: Any, data: Any) -> dict:
        return {
            "state": state,
            "data_step": np.asarray(data.step if data is not None else 0),
        }


class ServeSupervisor:
    """Supervised serving loop: step the fleet, recover on failure.

    The serving analogue of ``TrainSupervisor``: each ``step()`` drives
    one ``ServingFleet.step()`` under the retryable-exception umbrella.
    On a retryable failure the supervisor asks the fleet to restore the
    crashed engine from its latest snapshot (``fleet.recover``) and
    retries the step; non-retryable exceptions and exhausted budgets
    propagate. ``on_failure(fleet, error)`` runs before recovery — the
    hook point for elastic remesh onto surviving devices
    (``fleet.remesh_engine``) when the failure was a mesh-member loss.
    """

    def __init__(
        self,
        fleet: Any,
        max_restarts: int = 5,
        retryable: Optional[tuple[type[BaseException], ...]] = None,
        reset_after: int = 0,
        on_failure: Optional[Callable[[Any, BaseException], None]] = None,
    ):
        self.fleet = fleet
        self.max_restarts = max_restarts
        self.retryable = retryable if retryable is not None else default_retryable()
        self.reset_after = reset_after
        self.on_failure = on_failure
        self.restarts = 0
        self.recoveries: list[dict] = []
        self._clean_steps = 0

    def step(self) -> int:
        """One protected fleet step. Returns the fleet's pending count."""
        while True:
            try:
                n = self.fleet.step()
            except self.retryable as e:
                self.restarts += 1
                self._clean_steps = 0
                if self.restarts > self.max_restarts:
                    raise
                if self.on_failure is not None:
                    self.on_failure(self.fleet, e)
                self.recoveries.append(self.fleet.recover(e))
                continue
            self._clean_steps += 1
            if self.reset_after and self._clean_steps >= self.reset_after:
                self.restarts = 0
            return n

    def run(self, max_steps: int = 100_000) -> None:
        """Step until the fleet drains (no active, queued, or backlogged work)."""
        for _ in range(max_steps):
            if self.step() == 0:
                return
        raise RuntimeError(f"fleet failed to drain within {max_steps} steps")
