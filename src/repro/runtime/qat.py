"""Accumulator-aware fine-tuning: the "train" of train -> certify -> serve.

`a2q_finetune` runs a model's float params through a short QAT loop in
which every named linear site executes `core.a2q.a2q_fake_quant` (STE
projection against the sign-split accumulator bound — see the `a2q_qat`
dispatch context and the `models.layers.lin` hook), the optimizer applies
A2Q+-style per-channel weight-norm projection after each step
(`optim.with_a2q_projection`), and the per-site overflow census runs as a
*training signal* through the exact monitor plumbing serving uses
(`dispatch.CensusMonitor`), so the loop's history shows the same overflow
rates a `CensusWatch` would act on.

`quantize_and_certify` is the handoff to serving: quantize the fine-tuned
params, enforce the bound exactly in the integer domain
(`core.certify.enforce_acc_bounds` — rounding during requantization can
leave a row marginally over even after perfect QAT), and emit the
`Certificate` the engine attaches to `IntegerLinConfig`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.core import certify, dispatch
from repro.core.qtensor import quantize_tree
from repro.optim import Optimizer, adamw, with_a2q_projection


@dataclasses.dataclass(frozen=True)
class QATConfig:
    """Knobs for the accumulator-aware fine-tuning loop.

    weight_bits/acc_bits/act_bits pin the (b, p) pair being certified
    for; they must match the serving `IntegerLinConfig` for the
    certificate to cover the served widths. ``census_rows`` activation
    rows per site feed the census signal (0 disables it);
    ``project_each_step`` applies the A2Q+ weight-norm projection after
    every optimizer update; ``min_dim`` skips tiny projections, matching
    what `quantize_tree` will quantize.
    """

    weight_bits: int = 8
    acc_bits: int = 16
    act_bits: int = 8
    lr: float = 1e-3
    census_rows: int = 4
    min_dim: int = 16
    project_each_step: bool = True


def a2q_finetune(
    model: Any,
    params: Any,
    next_batch: Callable[[int], dict],
    steps: int,
    cfg: QATConfig = QATConfig(),
    optimizer: Optional[Optimizer] = None,
) -> tuple[Any, list[dict]]:
    """Fine-tune ``params`` under accumulator-aware fake quantization.

    ``model`` follows the model-zoo contract (``model.loss(params,
    batch)`` with batch["tokens"]/batch["labels"]); ``next_batch(i)``
    supplies the batch for step i. Returns (new_params, history) where
    each history entry carries the step loss and the drained per-site
    census (dots, overflow events, rates) — the training signal.
    """
    opt = optimizer or adamw(lr=cfg.lr, weight_decay=0.0)
    if cfg.project_each_step:
        opt = with_a2q_projection(
            opt, cfg.weight_bits, cfg.acc_bits, cfg.act_bits, cfg.min_dim
        )
    qat = dispatch.QATQuantConfig(
        weight_bits=cfg.weight_bits, acc_bits=cfg.acc_bits,
        act_bits=cfg.act_bits, min_dim=cfg.min_dim,
        census_rows=cfg.census_rows,
    )
    mon = dispatch.CensusMonitor()
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(p, s, batch):
        loss, grads = jax.value_and_grad(model.loss)(p, batch)
        p2, s2 = opt.update(grads, s, p)
        return p2, s2, loss

    history: list[dict] = []
    # contexts wrap TRACING: the jitted step traced inside them carries
    # the STE projection and census callbacks permanently
    with dispatch.a2q_qat(qat), dispatch.census_monitor(mon):
        for i in range(steps):
            params, opt_state, loss = step_fn(
                params, opt_state, next_batch(i)
            )
            jax.block_until_ready(loss)
            rates = mon.rates()
            history.append({
                "step": i,
                "loss": float(loss),
                "census": mon.drain(),
                "census_rates": rates,
            })
    return params, history


def quantize_and_certify(
    params: Any,
    acc_bits: int,
    act_bits: int = 8,
    weight_bits: int = 8,
    n_keep: Optional[int] = None,
    m: int = 16,
    min_size: int = 1 << 10,
    min_dim: int = 16,
) -> tuple[Any, certify.Certificate]:
    """Quantize -> enforce the bound exactly -> emit the certificate.

    The integer-domain enforcement is belt-and-suspenders after QAT
    (requantization rounding can nudge a row over the bound; rows
    already inside pass through bit-exactly) and is what makes the
    returned certificate actually cover ``acc_bits`` by construction.
    Calibration (`ServingEngine.calibrate` + ``attach_act_qparams``)
    can run afterwards — certificates hash only the integer weights.
    """
    qparams = quantize_tree(
        params, bits=weight_bits, n_keep=n_keep, m=m,
        min_size=min_size, min_dim=min_dim,
    )
    qparams = certify.enforce_acc_bounds(qparams, acc_bits, act_bits)
    cert = certify.certify_params(qparams, acc_bits, act_bits)
    return qparams, cert
