from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.paged_cache import (  # noqa: F401
    PageAllocator,
    PagedSpec,
)
