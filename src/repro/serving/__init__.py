from repro.serving.engine import (  # noqa: F401
    CensusWatch,
    Request,
    ServingEngine,
)
from repro.serving.fleet import ServingFleet  # noqa: F401
from repro.serving.paged_cache import (  # noqa: F401
    PageAllocator,
    PagedSpec,
)
