"""Batched serving engine: slot-based continuous batching over decode steps.

The engine owns a batch of ``num_slots`` sequence slots backed by one
batched KV/SSM cache pytree (batch = slot axis). Requests are admitted
into free slots, prefilled, then advanced together by a single jitted
decode step per token — the slot axis stays fully batched no matter how
requests arrive/finish (continuous batching). Finished slots are freed and
refilled from the queue.

Cache layouts:
  dense (default)           one (B, max_len, ...) lane per slot
  paged (``page_size=``)    KV/SSM state in shared page pools with
                            per-slot page tables (serving/paged_cache.py):
                            pages allocate lazily as sequences grow, free
                            on completion, and admission applies
                            *backpressure* (request waits in queue) when
                            the pool cannot cover a request's worst case —
                            never a mid-decode allocation failure, because
                            admission reserves the worst-case page count
                            up front. ``cache_dtype="int8"`` (paged only)
                            stores KV pages as int8 with per-position,
                            per-kv-head scales; SSM/conv state stays float.

Prefill is ONE jitted batched step per admission cohort
(``Model.prefill``): every admitted slot's whole prompt (minus the
held-back final token) is consumed in a single full-sequence pass that
scatters per-layer K/V (or runs the length-masked SSD recurrence) into
the slot cache lanes — across all architecture families (attention KV,
SSM state, hybrid, cross-attn). Prompt lengths are padded to power-of-
two buckets so recompiles stay bounded. ``prefill_mode="steps"`` keeps
the legacy token-by-token path (the parity oracle in tests).

Admission interleaving: by default (``prefill_decode_ratio=0``) admitted
requests prefill immediately, as before. With ratio N > 0, admitted
slots wait in a pending list and one batched prefill micro-step runs per
N decode steps, so a long prompt arriving mid-stream does not stall
every in-flight decode. ``_admit`` also skip-scans the queue (bounded by
``admit_lookahead``) past requests too long for the *remaining* page
budget, so one long request cannot head-of-line-block shorter ones;
skips and queue wait are counted in ``stats``.

Slot isolation: every jitted step takes an ``active`` (B,) mask and
merges caches through ``model.merge_caches``, so inactive slots' cache
lanes — and, on the paged path, the pool pages their tables own — are
bit-identical before and after the step. Decode results therefore do not
depend on which other requests happen to share the batch — greedy decode
of a prompt is reproducible under any slot occupancy.

Sampling: greedy or temperature; the temperature path draws from a
per-request generator seeded by ``(engine seed, request uid)``, so a
request's sampled continuation is reproducible regardless of batch
composition or admission order.

Long-K layers can opt into hierarchical K-sharded accumulation:
``int_lin=IntegerLinConfig(k_shards=S, k_shard_min_k=...)`` routes every
QTensor projection whose contraction dim reaches the threshold through
the per-shard-partials + tree-combine ``pqs_dot`` path (shorter
projections keep the bit-identical full-K path); with a serving mesh,
``k_axis=`` names the mesh axis the K shards live on — pair it with
``launch.sharding.params_shardings(..., k_axis=, k_shard_min_k=)`` so
the weight shards are already resident where the dot needs them.
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import pickle
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models.model import Model
from repro.serving import paged_cache

logger = logging.getLogger("repro.serving")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    failed: bool = False  # fleet gave up (deadline retries exhausted)
    t_submit: float = 0.0  # wall clock at submit()
    t_done: float = 0.0  # wall clock when the request finished


@dataclasses.dataclass(frozen=True)
class CensusWatch:
    """Census-triggered graceful degradation knobs.

    Every ``window`` decode steps the engine reads the per-site overflow
    census rates accumulated since the last check. A site whose
    event/dot ratio exceeds ``threshold`` (with at least ``min_dots``
    dots observed — tiny windows don't trigger) is hot-swapped:
    ``mode="wide"`` flips that site's policy to the overflow-free wide
    accumulator, ``mode="widen"`` raises its ``acc_bits`` to
    ``widen_to``. Either way the rest of the model keeps its narrow
    policies, a structured event is appended to ``engine.events``, and
    ``stats["census_degrades"]`` counts.

    By default degradation is monotone — a site never narrows back
    within an engine's lifetime (re-calibration is the undo, not a rate
    dip). ``undegrade_after=N`` makes it reversible: a degraded site
    whose census stays clean (rate <= threshold over >= min_dots dots)
    for N *consecutive* windows drops its overrides and returns to the
    engine-wide narrow config — logged as a ``census_undegrade`` event,
    counted in ``stats["census_undegrades"]``, and, like the overrides
    themselves, surviving snapshot/restore (a snapshot taken after the
    removal never resurrects the override). A dirty window resets the
    streak; windows with fewer than ``min_dots`` observed dots neither
    advance nor reset it.
    """

    threshold: float = 0.01
    window: int = 8
    mode: str = "wide"  # "wide" (policy swap) | "widen" (acc_bits raise)
    widen_to: int = 30
    min_dots: int = 1
    undegrade_after: Optional[int] = None  # N clean windows to re-narrow


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        num_slots: int = 8,
        max_len: int = 512,
        cache_dtype=jnp.float32,
        seed: int = 0,
        int_lin: Optional["dispatch.IntegerLinConfig"] = None,
        mesh=None,
        prefill_mode: str = "batched",
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        prefill_decode_ratio: int = 0,
        admit_lookahead: int = 8,
        failure_injector: Optional[Any] = None,
        census_watch: Optional[CensusWatch] = None,
    ):
        if prefill_mode not in ("batched", "steps"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if census_watch is not None and int_lin is None:
            raise ValueError(
                "census_watch monitors integer projections — it needs "
                "int_lin= (float engines have no overflow census)"
            )
        if int_lin is not None:
            # K-sharded integer projections need a coherent (k_shards,
            # k_axis, mesh) triple before any step traces — fail at
            # construction, not on the first decode
            if int_lin.k_axis is not None:
                if mesh is None:
                    raise ValueError(
                        f"int_lin.k_axis={int_lin.k_axis!r} needs a "
                        "serving mesh (ServingEngine(..., mesh=...))"
                    )
                if int_lin.k_axis not in mesh.axis_names:
                    raise ValueError(
                        f"int_lin.k_axis={int_lin.k_axis!r} is not an "
                        f"axis of the serving mesh {mesh.axis_names}"
                    )
            elif int_lin.k_shards is not None and mesh is not None:
                raise ValueError(
                    "int_lin.k_shards on a meshed engine needs "
                    "int_lin.k_axis= naming the mesh axis the K shards "
                    "live on"
                )
            if int_lin.certificate is not None:
                # a certificate only proves accumulator safety for the
                # exact integer weights it hashed — refuse to serve a
                # census-free path for anything else
                # (core.certify.CertificateError on mismatch)
                int_lin.certificate.verify(params)
        if mesh is not None and int_lin is not None:
            # distribute the integer projections over the serving mesh
            int_lin = dataclasses.replace(int_lin, mesh=mesh)
        quantized = (
            cache_dtype == "int8"
            if isinstance(cache_dtype, str)
            else jnp.dtype(cache_dtype) == jnp.int8
        )
        if quantized:
            if page_size is None:
                raise ValueError(
                    'cache_dtype="int8" quantizes KV *pages* — it '
                    "requires the paged cache (page_size=...)"
                )
            # non-KV float leaves (SSM state, conv rings, window rings)
            # stay f32 — only the KV page pools store int8
            cache_dtype = jnp.float32
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.int_lin = int_lin
        self.mesh = mesh
        self.prefill_mode = prefill_mode
        self.page_size = page_size
        self.prefill_decode_ratio = prefill_decode_ratio
        self.admit_lookahead = admit_lookahead
        self._seed = seed
        if page_size is not None:
            pages_per_slot = -(-max_len // page_size)
            if num_pages is None:
                num_pages = num_slots * pages_per_slot
            self.paging = paged_cache.PagedSpec(
                page_size=page_size,
                num_pages=num_pages,
                pages_per_slot=pages_per_slot,
                num_state_pages=num_slots,
                quantized=quantized,
            )
            self.caches = model.init_caches(
                params, num_slots, max_len, cache_dtype, paging=self.paging
            )
            self._alloc = paged_cache.PageAllocator(num_pages)
            self._table = np.full((num_slots, pages_per_slot), -1, np.int32)
            self._sidx = np.full((num_slots,), -1, np.int32)
            self._free_sidx = list(range(num_slots - 1, -1, -1))
        else:
            self.paging = None
            self.caches = model.init_caches(
                params, num_slots, max_len, cache_dtype
            )
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: list[Request] = []
        # admitted but not yet prefilled (interleaved admission)
        self._pending: list[tuple[int, Request]] = []
        self._ready = np.zeros(num_slots, bool)  # prefilled, decoding
        self._pos = np.zeros(num_slots, np.int64)  # tokens written so far
        self._next_token = np.zeros((num_slots, 1), np.int32)
        self._budget = np.zeros(num_slots, np.int64)
        self._since_prefill = 0
        self._step_idx = 0
        # fault tolerance: every live request is registered by uid so a
        # snapshot restore can rebind engine state to the caller's
        # Request objects; done uids never get resurrected
        self.failure_injector = failure_injector
        self._requests: dict[int, Request] = {}
        self._done_uids: set[int] = set()
        self._submit_seq = 0
        self.events: list[dict] = []  # structured log (census degrades, ...)
        # census-triggered degradation: one monitor for the engine's
        # lifetime (jitted traces bind it permanently), drained per window
        self.census_watch = census_watch
        self._census = (
            dispatch.CensusMonitor() if census_watch is not None else None
        )
        self._census_steps = 0
        self._degraded: set[str] = set()
        # consecutive clean windows per degraded site (un-degrade path)
        self._clean_windows: dict[str, int] = {}
        self.last_census_rates: dict[str, float] = {}
        # device-step accounting: admission latency is prefill_steps per
        # cohort (1 on the batched path, max prompt length - 1 on the
        # token-by-token path); queue_wait_steps sums engine steps each
        # request spent queued before admission, hol_skips counts
        # requests skip-scanned past for page-budget backpressure
        self.stats = {
            "prefill_steps": 0,
            "decode_steps": 0,
            "cohorts": 0,
            "hol_skips": 0,
            "queue_wait_steps": 0,
            "pages_in_use": 0,
            "pages_peak": 0,
            "census_degrades": 0,
            "census_undegrades": 0,
        }

        self._build_step_fns()

    def _build_step_fns(self) -> None:
        """(Re)build and re-jit the decode/prefill/reset step functions.

        jax.jit caches by function object, so anything the closures bake
        in at trace time — the ``int_lin`` config (census degradation
        hot-swaps it), the mesh (elastic remesh replaces it) — requires
        fresh function objects to force a retrace. Called from __init__
        and again after every hot-swap/remesh.
        """
        model = self.model

        def _int_ctx():
            # trace-time context: QTensor projections lower to true
            # integer dot products through pqs_dot under this policy
            # (sharded over the mesh when one is configured); the census
            # monitor context makes every site report overflow counts
            stack = contextlib.ExitStack()
            if self.int_lin is not None:
                stack.enter_context(dispatch.integer_lin(self.int_lin))
            if self._census is not None:
                stack.enter_context(dispatch.census_monitor(self._census))
            return stack

        def step(params, tok, caches, active):
            with _int_ctx():
                logits, new_caches = model.decode(params, tok, caches)
            return logits, model.merge_caches(caches, new_caches, active)

        def prefill_step(params, toks, caches, lengths, active):
            with _int_ctx():
                _, new_caches = model.prefill(params, toks, caches, lengths)
            # match cache leaf dtypes (e.g. f32 conv rings fed bf16
            # activations) so merged caches keep the decode signature
            new_caches = jax.tree_util.tree_map(
                lambda o, n: n.astype(o.dtype), caches, new_caches
            )
            return model.merge_caches(caches, new_caches, active)

        self._step = jax.jit(step)
        self._prefill_step = jax.jit(prefill_step)
        self._reset = jax.jit(
            lambda caches, mask: model.merge_caches(
                caches,
                jax.tree_util.tree_map(jnp.zeros_like, caches),
                mask,
            )
        )

    # -- calibration ---------------------------------------------------------

    def calibrate(
        self,
        batches: list[Any],
        act_bits: int = 8,
        symmetric: bool = True,
        decay: float = 0.9,
    ) -> dict:
        """Calibrate→freeze static activation ranges for integer decode.

        Runs the model forward over ``batches`` (training-style batch
        dicts) with the activation-range observer active, freezes the
        bias-corrected per-site bounds into static QParams, and attaches
        them to this engine's QTensor params (``QTensor.act_qparams``).
        Subsequent decode steps quantize activations with the frozen
        scales — no per-call absmax reduction (the jitted steps retrace
        automatically because the param pytree structure changed).
        Returns the frozen site → QParams dict.
        """
        from repro.core.quant import ActCalibrator
        from repro.core.qtensor import attach_act_qparams

        cal = ActCalibrator(decay=decay)
        with dispatch.calibration(cal):
            # jit keeps the pass fast; the range observations ride
            # jax.debug.callback, which fires at runtime under jit/scan.
            # The lambda (not the bound method) matters: bound methods of
            # a shared model compare equal across engines, so a second
            # engine's jit would hit the first's trace cache and leave
            # its observation callbacks bound to the first (dead) store
            fwd = jax.jit(lambda p, b: self.model.forward(p, b))
            for batch in batches:
                jax.block_until_ready(fwd(self.params, batch))
        frozen = cal.freeze(bits=act_bits, symmetric=symmetric)
        self.params = attach_act_qparams(self.params, frozen)
        return frozen

    # -- request lifecycle ---------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Worst-case pages for a request: its prompt (minus the held-
        back final token) plus every token its budget may decode."""
        tokens = max(len(req.prompt) + req.max_new_tokens - 1, 1)
        return -(-tokens // self.page_size)

    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            # past max_len the per-slot write index leaves the cache and
            # scatters are silently dropped — refuse loudly instead
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) = {total} exceeds "
                f"max_len={self.max_len}"
            )
        if self.paging is not None:
            need = self._pages_needed(req)
            if need > self.paging.num_pages:
                # could never be admitted — backpressure would deadlock
                raise ValueError(
                    f"request {req.uid}: needs {need} pages, pool has "
                    f"{self.paging.num_pages} (page_size={self.page_size})"
                )
        req.t_submit = time.perf_counter()
        req._submit_step = self._step_idx
        # per-request sampling stream: reproducible under any batch
        # composition / admission order
        req._rng = np.random.default_rng((self._seed, req.uid))
        # registry + submission order: a snapshot restore rebinds slots
        # to these objects and re-queues post-snapshot submissions in
        # their original order
        req._submit_seq = self._submit_seq
        self._submit_seq += 1
        self._requests[req.uid] = req
        self._done_uids.discard(req.uid)
        self.queue.append(req)

    def _admit(self) -> None:
        """Claim free slots from the queue; reserve + allocate pages.

        Paged backpressure: a request only leaves the queue once its
        worst-case page count is reservable, so the lazy per-step
        ``alloc`` calls during decode can never fail. A blocked request
        does not block shorter ones behind it — the scan skips past it
        (up to ``admit_lookahead`` skips) and counts ``hol_skips``.
        """
        free = [i for i in range(self.num_slots) if self.slots[i] is None]
        admitted: list[tuple[int, Request]] = []
        qi = 0
        skipped = 0
        while free and qi < len(self.queue):
            req = self.queue[qi]
            if self.paging is not None:
                need = self._pages_needed(req)
                if not self._alloc.can_reserve(need):
                    self.stats["hol_skips"] += 1
                    skipped += 1
                    if skipped >= self.admit_lookahead:
                        break
                    qi += 1
                    continue
            slot = free.pop(0)
            self.queue.pop(qi)
            if self.paging is not None:
                self._alloc.reserve(slot, need)
                # prompt pages up front (prefill scatters the whole
                # prompt at once); decode pages allocate lazily
                n_prefill = max(len(req.prompt) - 1, 0)
                for j in range(-(-n_prefill // self.page_size)):
                    self._table[slot, j] = self._alloc.alloc(slot)
                self._sidx[slot] = self._free_sidx.pop()
            self.slots[slot] = req
            self._ready[slot] = False
            self._pos[slot] = 0
            self.stats["queue_wait_steps"] += self._step_idx - getattr(
                req, "_submit_step", self._step_idx
            )
            admitted.append((slot, req))
        if not admitted:
            return
        # clear stale cache lanes (KV pages, SSM state, positions) of
        # the re-used slots; on the paged path the new page tables go
        # live first so the reset zeroes the freshly claimed pages
        mask = np.zeros(self.num_slots, bool)
        for slot, _ in admitted:
            mask[slot] = True
        if self.paging is not None:
            self.caches = paged_cache.set_tables(
                self.caches, self._table, self._sidx
            )
        self.caches = self._reset(self.caches, jnp.asarray(mask))
        self._pending.extend(admitted)
        self._maybe_prefill()

    def _maybe_prefill(self) -> None:
        """Prefill the pending cohort, subject to the interleave budget.

        ``prefill_decode_ratio=0`` (default): immediately. Ratio N > 0:
        only after N decode steps since the last prefill — unless
        nothing is mid-decode, in which case waiting helps no one.
        """
        if not self._pending:
            return
        have_ready = any(
            self.slots[i] is not None and self._ready[i]
            for i in range(self.num_slots)
        )
        if have_ready and self._since_prefill < self.prefill_decode_ratio:
            return
        cohort, self._pending = self._pending, []
        self._prefill(cohort)
        self._since_prefill = 0
        for slot, req in cohort:
            self._pos[slot] = len(req.prompt) - 1
            self._ready[slot] = True

    def _prefill(self, admitted: list[tuple[int, Request]]) -> None:
        """Consume the admitted prompts into their slots' cache lanes.

        The final prompt token is always held back — it is fed by the
        first decode step, which produces the first sampled token.
        """
        self.stats["cohorts"] += 1
        if self.prefill_mode == "batched":
            self._prefill_batched(admitted)
        else:
            self._prefill_steps(admitted)
        for slot, req in admitted:
            self._next_token[slot, 0] = int(req.prompt[-1])
            self._budget[slot] = req.max_new_tokens

    def _prefill_batched(self, admitted: list[tuple[int, Request]]) -> None:
        """ONE jitted batched prefill step for the whole admission cohort.

        Prompts are left-aligned into a (num_slots, S) buffer with
        per-slot lengths; S is padded to a power-of-two bucket so the
        number of distinct compiled shapes stays logarithmic in max_len.
        Non-admitted slots carry length 0 and are additionally masked
        out of the cache merge, so mid-generation lanes are untouched.
        """
        longest = max(len(req.prompt) for _, req in admitted) - 1
        if longest <= 0:
            return  # single-token prompts: nothing to prefill
        s = 1 << (longest - 1).bit_length()  # pow2 bucket >= longest
        toks = np.zeros((self.num_slots, s), np.int32)
        lengths = np.zeros(self.num_slots, np.int32)
        active = np.zeros(self.num_slots, bool)
        for slot, req in admitted:
            n = len(req.prompt) - 1
            toks[slot, :n] = req.prompt[:-1]
            lengths[slot] = n
            active[slot] = True
        self.caches = self._prefill_step(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(lengths), jnp.asarray(active),
        )
        self.stats["prefill_steps"] += 1

    def _prefill_steps(self, admitted: list[tuple[int, Request]]) -> None:
        """Legacy path: prompts through the decode step token-by-token.

        At step t every admitted slot with a t-th prompt token is
        active; all other slots (both mid-generation and idle) are
        masked out, so their caches do not advance. Kept as the parity
        oracle for the batched path (tests/test_prefill_parity.py and
        the paged suite).
        """
        longest = max(len(req.prompt) for _, req in admitted)
        for t in range(longest - 1):
            active = np.zeros(self.num_slots, bool)
            tok = self._next_token.copy()
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    active[slot] = True
                    tok[slot, 0] = int(req.prompt[t])
            if active.any():
                _, self.caches = self._step(
                    self.params, jnp.asarray(tok), self.caches,
                    jnp.asarray(active),
                )
                self.stats["prefill_steps"] += 1

    # -- decode loop ----------------------------------------------------------

    def _ensure_decode_pages(self, active: list[int]) -> None:
        """Lazily claim the page each active slot's next write lands in.

        Guaranteed to succeed: admission reserved the worst case. Only
        pushes the table to the device when something actually changed.
        """
        dirty = False
        for slot in active:
            lp = int(self._pos[slot]) // self.page_size
            if self._table[slot, lp] < 0:
                self._table[slot, lp] = self._alloc.alloc(slot)
                dirty = True
        if dirty:
            self.caches = paged_cache.set_tables(
                self.caches, self._table, self._sidx
            )

    def _free_slot(self, slot: int) -> None:
        self.slots[slot] = None
        self._ready[slot] = False
        if self.paging is not None:
            self._alloc.free_slot(slot)
            self._table[slot, :] = -1
            self._free_sidx.append(int(self._sidx[slot]))
            self._sidx[slot] = -1
            # the stale device-side table row is harmless (the slot is
            # inactive, so merges revert anything it could touch); the
            # next admission's set_tables overwrites it

    def _sample(self, logits: np.ndarray, slot: int) -> int:
        req = self.slots[slot]
        row = logits[slot, -1]
        if req.temperature <= 0:
            return int(row.argmax())
        z = row / req.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        rng = getattr(req, "_rng", None)
        if rng is None:  # request bypassed submit(); still per-request
            rng = req._rng = np.random.default_rng((self._seed, req.uid))
        return int(rng.choice(len(p), p=p))

    def step(self) -> int:
        """One batched decode step (plus admission/prefill bookkeeping).

        Returns the number of slots that decoded plus the number of
        admitted-but-pending prefills — 0 means the engine is idle.
        """
        self._step_idx += 1
        if self.failure_injector is not None:
            self.failure_injector.maybe_fail(self._step_idx)
        self._admit()
        self._maybe_prefill()
        active = [
            i for i, r in enumerate(self.slots)
            if r is not None and self._ready[i]
        ]
        if not active:
            return len(self._pending)
        if self.paging is not None:
            self._ensure_decode_pages(active)
        mask = np.zeros(self.num_slots, bool)
        mask[active] = True
        logits, self.caches = self._step(
            self.params, jnp.asarray(self._next_token), self.caches,
            jnp.asarray(mask),
        )
        self.stats["decode_steps"] += 1
        self._since_prefill += 1
        logits = np.asarray(logits.astype(jnp.float32))
        for slot in active:
            req = self.slots[slot]
            nxt = self._sample(logits, slot)
            req.output.append(nxt)
            self._next_token[slot, 0] = nxt
            self._pos[slot] += 1
            self._budget[slot] -= 1
            if self._budget[slot] <= 0 or (
                req.eos_id is not None and nxt == req.eos_id
            ):
                req.done = True
                req.t_done = time.perf_counter()
                self._done_uids.add(req.uid)
                self._free_slot(slot)
        if self.paging is not None:
            self.stats["pages_in_use"] = self._alloc.in_use
            self.stats["pages_peak"] = self._alloc.peak_in_use
        if self.census_watch is not None:
            self._check_census()
        return len(active) + len(self._pending)

    def drain(self, requests: list[Request], max_steps: int = 100_000) -> None:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break

    def cache_nbytes(self) -> int:
        """Current cache footprint in bytes (pools + tables + state)."""
        return paged_cache.cache_nbytes(self.caches)

    # -- census-triggered graceful degradation --------------------------------

    def _check_census(self) -> None:
        """Window check: hot-swap any site saturating its accumulator.

        Drains the per-site overflow census every ``window`` decode
        steps. A site over threshold degrades exactly once: its policy
        flips to ``wide`` (or its ``acc_bits`` widens), the step
        functions re-jit against the new config, and a structured event
        is logged. Degraded-to-wide sites keep reporting dots with zero
        events, so the next window observably reads rate 0.0 — and,
        when ``undegrade_after`` is set, those clean windows accumulate
        toward the reverse transition: after N consecutive clean
        windows the site's overrides are dropped (``census_undegrade``
        event) and it re-narrows to the engine-wide config, back under
        full watch (it can re-degrade if the workload is still hot).

        Certified sites (``int_lin.certificate``) never appear here at
        all: `dispatch.qtensor_dot` dispatches them census-free, so the
        monitor has nothing to drain for them and the watch can never
        degrade a provably-safe site — that is the certified fast path's
        contract, enforced by construction rather than by filtering.
        """
        self._census_steps += 1
        if self._census_steps < self.census_watch.window:
            return
        self._census_steps = 0
        totals = self._census.drain()
        self.last_census_rates = {
            s: (e / d if d else 0.0) for s, (d, e) in totals.items()
        }
        changed = False
        # reverse transition first: a site whose census stayed clean for
        # N consecutive windows drops its overrides and re-narrows
        after = self.census_watch.undegrade_after
        if after is not None:
            for site in sorted(self._degraded):
                dots, events = totals.get(site, (0, 0))
                if dots < self.census_watch.min_dots:
                    continue  # no evidence either way: freeze the streak
                rate = events / dots
                if rate > self.census_watch.threshold:
                    self._clean_windows[site] = 0
                    continue
                streak = self._clean_windows.get(site, 0) + 1
                self._clean_windows[site] = streak
                if streak < after:
                    continue
                self.int_lin = self.int_lin.without_site(site)
                self._degraded.discard(site)
                self._clean_windows.pop(site, None)
                self.stats["census_undegrades"] += 1
                changed = True
                event = {
                    "event": "census_undegrade",
                    "site": site,
                    "clean_windows": streak,
                    "rate": rate,
                    "dots": dots,
                    "step": self._step_idx,
                }
                self.events.append(event)
                logger.info(
                    "census_undegrade site=%s after %d clean windows "
                    "(rate=%.4f over %d dots) at step %d",
                    site, streak, rate, dots, self._step_idx,
                )
        for site, (dots, events) in sorted(totals.items()):
            if dots < self.census_watch.min_dots or site in self._degraded:
                continue
            rate = events / dots
            if rate <= self.census_watch.threshold:
                continue
            if self.census_watch.mode == "widen":
                self.int_lin = self.int_lin.with_site_acc_bits(
                    site, self.census_watch.widen_to
                )
                action = {"acc_bits": self.census_watch.widen_to}
            else:
                self.int_lin = self.int_lin.with_site_policy(site, "wide")
                action = {"policy": "wide"}
            self._degraded.add(site)
            self.stats["census_degrades"] += 1
            changed = True
            event = {
                "event": "census_degrade",
                "site": site,
                "rate": rate,
                "dots": dots,
                "overflows": events,
                "step": self._step_idx,
                **action,
            }
            self.events.append(event)
            logger.warning(
                "census_degrade site=%s rate=%.4f (%d/%d dots) -> %s "
                "at step %d",
                site, rate, events, dots, action, self._step_idx,
            )
        if changed:
            self._build_step_fns()

    # -- fault tolerance: cancel / snapshot / restore / remesh ----------------

    def cancel(self, uid: int) -> bool:
        """Remove a live request wherever it is (queue, pending, slot).

        Frees the slot/pages and unregisters the uid, so a later
        snapshot restore will not resurrect it — the fleet's deadline
        path re-queues the prompt itself. Returns False for unknown or
        already-finished uids.
        """
        for qi, req in enumerate(self.queue):
            if req.uid == uid:
                self.queue.pop(qi)
                self._requests.pop(uid, None)
                return True
        for pi, (slot, req) in enumerate(self._pending):
            if req.uid == uid:
                self._pending.pop(pi)
                self._free_slot(slot)
                self._requests.pop(uid, None)
                return True
        for slot, req in enumerate(self.slots):
            if req is not None and req.uid == uid:
                self._free_slot(slot)
                self._requests.pop(uid, None)
                return True
        return False

    def snapshot(self) -> dict:
        """Serving-state snapshot: everything a mid-decode resume needs.

        Two leaves, sized for ``checkpoint.save_checkpoint``:
          "caches"  the cache pytree on host (page pools + tables +
                    positions + scales)
          "meta"    a pickled uint8 blob: per-slot request bindings
                    (uid, emitted output, sampling RNG state), queue and
                    pending order, decode cursors (pos/budget/
                    next_token/ready), page-allocator state, stats,
                    census-degradation overrides.
        Restoring on a fresh or crashed engine resumes decode such that
        in-flight requests continue bit-identically to a failure-free
        run (same caches, same next token, same RNG stream position).
        """

        def req_state(req: Request) -> dict:
            return {
                "uid": req.uid,
                "output": list(req.output),
                "rng": req._rng.bit_generator.state
                if getattr(req, "_rng", None) is not None
                else None,
            }

        meta: dict[str, Any] = {
            "step_idx": self._step_idx,
            "submit_seq": self._submit_seq,
            "slots": [
                None if r is None else req_state(r) for r in self.slots
            ],
            "queue": [req_state(r) for r in self.queue],
            "pending": [(slot, r.uid) for slot, r in self._pending],
            "ready": self._ready.copy(),
            "pos": self._pos.copy(),
            "next_token": self._next_token.copy(),
            "budget": self._budget.copy(),
            "since_prefill": self._since_prefill,
            "stats": dict(self.stats),
            "done_uids": set(self._done_uids),
            "degraded": set(self._degraded),
            "clean_windows": dict(self._clean_windows),
            "site_policies": self.int_lin.site_policies
            if self.int_lin is not None
            else (),
            "site_acc_bits": self.int_lin.site_acc_bits
            if self.int_lin is not None
            else (),
        }
        if self.paging is not None:
            meta["paging"] = {
                "table": self._table.copy(),
                "sidx": self._sidx.copy(),
                "free_sidx": list(self._free_sidx),
                "alloc_free": list(self._alloc._free),
                "alloc_owned": {
                    k: list(v) for k, v in self._alloc._owned.items()
                },
                "alloc_pending": dict(self._alloc._pending),
                "alloc_peak": self._alloc.peak_in_use,
            }
        return {
            "caches": paged_cache.snapshot(self.caches),
            "meta": np.frombuffer(pickle.dumps(meta), np.uint8),
        }

    def restore(self, snap: dict) -> None:
        """Resume from a ``snapshot()`` after a crash (or on a twin engine).

        Request objects are rebound from the live registry by uid:
        covered in-flight requests get their emitted output truncated to
        the snapshot point and their RNG stream rewound, so replayed
        decode re-emits the identical continuation — no duplicate and no
        lost tokens. Requests that finished since the snapshot stay
        finished (their slots are freed; delivered output is never
        regenerated). Requests submitted after the snapshot restart from
        their prompt, re-queued in original submission order.
        """
        meta = pickle.loads(np.asarray(snap["meta"]).tobytes())
        self.caches = paged_cache.restore(self.caches, snap["caches"])
        self._step_idx = int(meta["step_idx"])
        self._submit_seq = max(self._submit_seq, int(meta["submit_seq"]))
        self._ready = np.asarray(meta["ready"]).copy()
        self._pos = np.asarray(meta["pos"]).copy()
        self._next_token = np.asarray(meta["next_token"]).copy()
        self._budget = np.asarray(meta["budget"]).copy()
        self._since_prefill = int(meta["since_prefill"])
        self.stats = dict(meta["stats"])
        self._done_uids |= set(meta["done_uids"])
        if self.paging is not None:
            pg = meta["paging"]
            self._table = np.asarray(pg["table"]).copy()
            self._sidx = np.asarray(pg["sidx"]).copy()
            self._free_sidx = list(pg["free_sidx"])
            alloc = paged_cache.PageAllocator(self.paging.num_pages)
            alloc._free = list(pg["alloc_free"])
            alloc._owned = {k: list(v) for k, v in pg["alloc_owned"].items()}
            alloc._pending = dict(pg["alloc_pending"])
            alloc.peak_in_use = int(pg["alloc_peak"])
            self._alloc = alloc
            self.caches = paged_cache.set_tables(
                self.caches, self._table, self._sidx
            )

        def rebind(st: Optional[dict]) -> Optional[Request]:
            if st is None:
                return None
            req = self._requests.get(st["uid"])
            if req is None or req.done:
                # finished (and delivered) since the snapshot, or
                # cancelled by the fleet — never resurrect
                return None
            req.output[:] = st["output"]
            req.done = False
            if st["rng"] is not None:
                req._rng = np.random.default_rng((self._seed, req.uid))
                req._rng.bit_generator.state = st["rng"]
            return req

        covered: set[int] = set()
        self.slots = [rebind(st) for st in meta["slots"]]
        for slot, req in enumerate(self.slots):
            if req is None:
                if meta["slots"][slot] is not None:
                    # occupied at snapshot, finished since: release the
                    # restored pages/state index for this slot
                    self.slots[slot] = object.__new__(Request)  # placeholder
                    self.slots[slot].uid = meta["slots"][slot]["uid"]
                    self._free_slot(slot)
                self.slots[slot] = None
                self._ready[slot] = False
            else:
                covered.add(req.uid)
        self.queue = []
        for st in meta["queue"]:
            req = rebind(st)
            if req is not None:
                self.queue.append(req)
                covered.add(req.uid)
        self._pending = []
        for slot, uid in meta["pending"]:
            req = self.slots[slot]
            if req is not None and req.uid == uid:
                self._pending.append((slot, req))
        # post-snapshot submissions (and anything else live but not in
        # the snapshot): restart from the prompt, original order
        missing = sorted(
            (
                r
                for uid, r in self._requests.items()
                if uid not in covered and uid not in self._done_uids
                and not r.done
            ),
            key=lambda r: getattr(r, "_submit_seq", 0),
        )
        for req in missing:
            req.output.clear()
            req._rng = np.random.default_rng((self._seed, req.uid))
            self.queue.append(req)
        # census degradation state: adopt the snapshot's overrides on
        # top of any the engine already applied (union — recovery never
        # narrows a site the snapshot or the engine holds degraded; a
        # site un-degraded *before* the snapshot appears in neither, so
        # its removal survives the restore)
        if self.int_lin is not None:
            cfg = self.int_lin
            for site, pol in meta["site_policies"]:
                if cfg.policy_for(site) != pol:
                    cfg = cfg.with_site_policy(site, pol)
            for site, bits in meta["site_acc_bits"]:
                if cfg.acc_bits_for(site) < bits:
                    cfg = cfg.with_site_acc_bits(site, bits)
            if cfg is not self.int_lin:
                self.int_lin = cfg
                self._build_step_fns()
            self._degraded |= set(meta["degraded"])
            # clean-window streaks resume from the snapshot, pruned to
            # sites still degraded after the union
            cw = dict(meta.get("clean_windows", ()))
            cw.update(self._clean_windows)
            self._clean_windows = {
                s: n for s, n in cw.items() if s in self._degraded
            }
        self._census_steps = 0
        if self._census is not None:
            self._census.drain()

    def remesh(self, new_mesh) -> None:
        """Re-place the engine on a different mesh (elastic shrink/grow).

        Params and caches round-trip through host (surviving devices
        hold complete copies under the serving placement) and the step
        functions re-jit against the new mesh so the sharded integer
        projections re-partition. In-flight decode state (positions,
        tables, RNG streams) is untouched — decode resumes bit-identically
        because ``pqs_dot`` is bit-exact at any mesh shape.
        """
        self.mesh = new_mesh
        if self.int_lin is not None:
            self.int_lin = dataclasses.replace(self.int_lin, mesh=new_mesh)

        def rehost(a):
            if isinstance(a, jax.Array):
                return jnp.asarray(np.asarray(a))
            return a

        self.params = jax.tree_util.tree_map(rehost, self.params)
        self.caches = jax.tree_util.tree_map(rehost, self.caches)
        self._build_step_fns()
