"""Batched serving engine: slot-based continuous batching over decode steps.

The engine owns a batch of ``num_slots`` sequence slots backed by one
batched KV/SSM cache pytree (batch = slot axis). Requests are admitted
into free slots, prefilled, then advanced together by a single jitted
decode step per token — the slot axis stays fully batched no matter how
requests arrive/finish (continuous batching). Finished slots are freed and
refilled from the queue.

Prefill feeds the prompt through the decode path token-by-token into the
slot's cache — all newly admitted slots advance together, one batched
step per prompt position. That is the universally-correct path across
all five architecture families (attention KV, SSM state, hybrid,
cross-attn); the batched one-shot prefill used at scale is exercised by
``launch/dryrun.py``'s prefill cells, where it matters for the roofline.

Slot isolation: every jitted step takes an ``active`` (B,) mask and
merges caches through ``model.merge_caches``, so inactive slots' cache
lanes (KV, SSM state, per-sequence positions) are bit-identical before
and after the step. Decode results therefore do not depend on which
other requests happen to share the batch — greedy decode of a prompt is
reproducible under any slot occupancy.

Sampling: greedy or temperature; per-slot RNG for reproducibility.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        num_slots: int = 8,
        max_len: int = 512,
        cache_dtype=jnp.float32,
        seed: int = 0,
        int_lin: Optional["dispatch.IntegerLinConfig"] = None,
    ):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.int_lin = int_lin
        self.caches = model.init_caches(params, num_slots, max_len, cache_dtype)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: list[Request] = []
        self._next_token = np.zeros((num_slots, 1), np.int32)
        self._budget = np.zeros(num_slots, np.int64)
        self._rng = np.random.default_rng(seed)

        def step(params, tok, caches, active):
            if self.int_lin is not None:
                # trace-time context: QTensor projections lower to true
                # integer dot products through pqs_dot under this policy
                with dispatch.integer_lin(self.int_lin):
                    logits, new_caches = model.decode(params, tok, caches)
            else:
                logits, new_caches = model.decode(params, tok, caches)
            return logits, model.merge_caches(caches, new_caches, active)

        self._step = jax.jit(step)
        self._reset = jax.jit(
            lambda caches, mask: model.merge_caches(
                caches,
                jax.tree_util.tree_map(jnp.zeros_like, caches),
                mask,
            )
        )

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            # past max_len the per-slot write index leaves the cache and
            # scatters are silently dropped — refuse loudly instead
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) = {total} exceeds "
                f"max_len={self.max_len}"
            )
        self.queue.append(req)

    def _admit(self) -> None:
        admitted: list[tuple[int, Request]] = []
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                admitted.append((slot, req))
        if not admitted:
            return
        # clear stale cache lanes (KV, SSM state, positions) of the
        # re-used slots, then prefill all admissions together
        mask = np.zeros(self.num_slots, bool)
        for slot, _ in admitted:
            mask[slot] = True
        self.caches = self._reset(self.caches, jnp.asarray(mask))
        self._prefill(admitted)

    def _prefill(self, admitted: list[tuple[int, Request]]) -> None:
        """Feed prompts through the decode path into the admitted slots.

        One batched step per prompt position: at step t every admitted
        slot with a t-th prompt token is active; all other slots (both
        mid-generation and idle) are masked out, so their caches do not
        advance. The final prompt token is held back — it is fed by the
        first decode step, which produces the first sampled token.
        """
        longest = max(len(req.prompt) for _, req in admitted)
        for t in range(longest - 1):
            active = np.zeros(self.num_slots, bool)
            tok = self._next_token.copy()
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    active[slot] = True
                    tok[slot, 0] = int(req.prompt[t])
            if active.any():
                _, self.caches = self._step(
                    self.params, jnp.asarray(tok), self.caches,
                    jnp.asarray(active),
                )
        for slot, req in admitted:
            self._next_token[slot, 0] = int(req.prompt[-1])
            self._budget[slot] = req.max_new_tokens

    # -- decode loop ----------------------------------------------------------

    def _sample(self, logits: np.ndarray, slot: int) -> int:
        req = self.slots[slot]
        row = logits[slot, -1]
        if req.temperature <= 0:
            return int(row.argmax())
        z = row / req.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One batched decode step. Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        mask = np.zeros(self.num_slots, bool)
        mask[active] = True
        logits, self.caches = self._step(
            self.params, jnp.asarray(self._next_token), self.caches,
            jnp.asarray(mask),
        )
        logits = np.asarray(logits.astype(jnp.float32))
        for slot in active:
            req = self.slots[slot]
            nxt = self._sample(logits, slot)
            req.output.append(nxt)
            self._next_token[slot, 0] = nxt
            self._budget[slot] -= 1
            if self._budget[slot] <= 0 or (
                req.eos_id is not None and nxt == req.eos_id
            ):
                req.done = True
                self.slots[slot] = None
        return len(active)

    def drain(self, requests: list[Request], max_steps: int = 100_000) -> None:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
