"""Batched serving engine: slot-based continuous batching over decode steps.

The engine owns a batch of ``num_slots`` sequence slots backed by one
batched KV/SSM cache pytree (batch = slot axis). Requests are admitted
into free slots, prefilled, then advanced together by a single jitted
decode step per token — the slot axis stays fully batched no matter how
requests arrive/finish (continuous batching). Finished slots are freed and
refilled from the queue.

Prefill is ONE jitted batched step per admission cohort
(``Model.prefill``): every admitted slot's whole prompt (minus the
held-back final token) is consumed in a single full-sequence pass that
scatters per-layer K/V (or runs the length-masked SSD recurrence) into
the slot cache lanes — across all architecture families (attention KV,
SSM state, hybrid, cross-attn). Prompt lengths are padded to power-of-
two buckets so recompiles stay bounded. ``prefill_mode="steps"`` keeps
the legacy token-by-token path (the parity oracle in tests).

Slot isolation: every jitted step takes an ``active`` (B,) mask and
merges caches through ``model.merge_caches``, so inactive slots' cache
lanes (KV, SSM state, per-sequence positions) are bit-identical before
and after the step. Decode results therefore do not depend on which
other requests happen to share the batch — greedy decode of a prompt is
reproducible under any slot occupancy.

Sampling: greedy or temperature; per-slot RNG for reproducibility.

Long-K layers can opt into hierarchical K-sharded accumulation:
``int_lin=IntegerLinConfig(k_shards=S, k_shard_min_k=...)`` routes every
QTensor projection whose contraction dim reaches the threshold through
the per-shard-partials + tree-combine ``pqs_dot`` path (shorter
projections keep the bit-identical full-K path); with a serving mesh,
``k_axis=`` names the mesh axis the K shards live on — pair it with
``launch.sharding.params_shardings(..., k_axis=, k_shard_min_k=)`` so
the weight shards are already resident where the dot needs them.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        num_slots: int = 8,
        max_len: int = 512,
        cache_dtype=jnp.float32,
        seed: int = 0,
        int_lin: Optional["dispatch.IntegerLinConfig"] = None,
        mesh=None,
        prefill_mode: str = "batched",
    ):
        if prefill_mode not in ("batched", "steps"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if int_lin is not None:
            # K-sharded integer projections need a coherent (k_shards,
            # k_axis, mesh) triple before any step traces — fail at
            # construction, not on the first decode
            if int_lin.k_axis is not None:
                if mesh is None:
                    raise ValueError(
                        f"int_lin.k_axis={int_lin.k_axis!r} needs a "
                        "serving mesh (ServingEngine(..., mesh=...))"
                    )
                if int_lin.k_axis not in mesh.axis_names:
                    raise ValueError(
                        f"int_lin.k_axis={int_lin.k_axis!r} is not an "
                        f"axis of the serving mesh {mesh.axis_names}"
                    )
            elif int_lin.k_shards is not None and mesh is not None:
                raise ValueError(
                    "int_lin.k_shards on a meshed engine needs "
                    "int_lin.k_axis= naming the mesh axis the K shards "
                    "live on"
                )
        if mesh is not None and int_lin is not None:
            # distribute the integer projections over the serving mesh
            int_lin = dataclasses.replace(int_lin, mesh=mesh)
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.int_lin = int_lin
        self.mesh = mesh
        self.prefill_mode = prefill_mode
        self.caches = model.init_caches(params, num_slots, max_len, cache_dtype)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: list[Request] = []
        self._next_token = np.zeros((num_slots, 1), np.int32)
        self._budget = np.zeros(num_slots, np.int64)
        self._rng = np.random.default_rng(seed)
        # device-step accounting: admission latency is prefill_steps per
        # cohort (1 on the batched path, max prompt length - 1 on the
        # token-by-token path)
        self.stats = {"prefill_steps": 0, "decode_steps": 0, "cohorts": 0}

        def _int_ctx():
            # trace-time context: QTensor projections lower to true
            # integer dot products through pqs_dot under this policy
            # (sharded over the mesh when one is configured)
            if self.int_lin is not None:
                return dispatch.integer_lin(self.int_lin)
            return contextlib.nullcontext()

        def step(params, tok, caches, active):
            with _int_ctx():
                logits, new_caches = model.decode(params, tok, caches)
            return logits, model.merge_caches(caches, new_caches, active)

        def prefill_step(params, toks, caches, lengths, active):
            with _int_ctx():
                _, new_caches = model.prefill(params, toks, caches, lengths)
            # match cache leaf dtypes (e.g. f32 conv rings fed bf16
            # activations) so merged caches keep the decode signature
            new_caches = jax.tree_util.tree_map(
                lambda o, n: n.astype(o.dtype), caches, new_caches
            )
            return model.merge_caches(caches, new_caches, active)

        self._step = jax.jit(step)
        self._prefill_step = jax.jit(prefill_step)
        self._reset = jax.jit(
            lambda caches, mask: model.merge_caches(
                caches,
                jax.tree_util.tree_map(jnp.zeros_like, caches),
                mask,
            )
        )

    # -- calibration ---------------------------------------------------------

    def calibrate(
        self,
        batches: list[Any],
        act_bits: int = 8,
        symmetric: bool = True,
        decay: float = 0.9,
    ) -> dict:
        """Calibrate→freeze static activation ranges for integer decode.

        Runs the model forward over ``batches`` (training-style batch
        dicts) with the activation-range observer active, freezes the
        bias-corrected per-site bounds into static QParams, and attaches
        them to this engine's QTensor params (``QTensor.act_qparams``).
        Subsequent decode steps quantize activations with the frozen
        scales — no per-call absmax reduction (the jitted steps retrace
        automatically because the param pytree structure changed).
        Returns the frozen site → QParams dict.
        """
        from repro.core.quant import ActCalibrator
        from repro.core.qtensor import attach_act_qparams

        cal = ActCalibrator(decay=decay)
        with dispatch.calibration(cal):
            # jit keeps the pass fast; the range observations ride
            # jax.debug.callback, which fires at runtime under jit/scan
            fwd = jax.jit(self.model.forward)
            for batch in batches:
                jax.block_until_ready(fwd(self.params, batch))
        frozen = cal.freeze(bits=act_bits, symmetric=symmetric)
        self.params = attach_act_qparams(self.params, frozen)
        return frozen

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            # past max_len the per-slot write index leaves the cache and
            # scatters are silently dropped — refuse loudly instead
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) = {total} exceeds "
                f"max_len={self.max_len}"
            )
        self.queue.append(req)

    def _admit(self) -> None:
        admitted: list[tuple[int, Request]] = []
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                admitted.append((slot, req))
        if not admitted:
            return
        # clear stale cache lanes (KV, SSM state, positions) of the
        # re-used slots, then prefill all admissions together
        mask = np.zeros(self.num_slots, bool)
        for slot, _ in admitted:
            mask[slot] = True
        self.caches = self._reset(self.caches, jnp.asarray(mask))
        self._prefill(admitted)

    def _prefill(self, admitted: list[tuple[int, Request]]) -> None:
        """Consume the admitted prompts into their slots' cache lanes.

        The final prompt token is always held back — it is fed by the
        first decode step, which produces the first sampled token.
        """
        self.stats["cohorts"] += 1
        if self.prefill_mode == "batched":
            self._prefill_batched(admitted)
        else:
            self._prefill_steps(admitted)
        for slot, req in admitted:
            self._next_token[slot, 0] = int(req.prompt[-1])
            self._budget[slot] = req.max_new_tokens

    def _prefill_batched(self, admitted: list[tuple[int, Request]]) -> None:
        """ONE jitted batched prefill step for the whole admission cohort.

        Prompts are left-aligned into a (num_slots, S) buffer with
        per-slot lengths; S is padded to a power-of-two bucket so the
        number of distinct compiled shapes stays logarithmic in max_len.
        Non-admitted slots carry length 0 and are additionally masked
        out of the cache merge, so mid-generation lanes are untouched.
        """
        longest = max(len(req.prompt) for _, req in admitted) - 1
        if longest <= 0:
            return  # single-token prompts: nothing to prefill
        s = 1 << (longest - 1).bit_length()  # pow2 bucket >= longest
        toks = np.zeros((self.num_slots, s), np.int32)
        lengths = np.zeros(self.num_slots, np.int32)
        active = np.zeros(self.num_slots, bool)
        for slot, req in admitted:
            n = len(req.prompt) - 1
            toks[slot, :n] = req.prompt[:-1]
            lengths[slot] = n
            active[slot] = True
        self.caches = self._prefill_step(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(lengths), jnp.asarray(active),
        )
        self.stats["prefill_steps"] += 1

    def _prefill_steps(self, admitted: list[tuple[int, Request]]) -> None:
        """Legacy path: prompts through the decode step token-by-token.

        At step t every admitted slot with a t-th prompt token is
        active; all other slots (both mid-generation and idle) are
        masked out, so their caches do not advance. Kept as the parity
        oracle for the batched path (tests/test_prefill_parity.py).
        """
        longest = max(len(req.prompt) for _, req in admitted)
        for t in range(longest - 1):
            active = np.zeros(self.num_slots, bool)
            tok = self._next_token.copy()
            for slot, req in admitted:
                if t < len(req.prompt) - 1:
                    active[slot] = True
                    tok[slot, 0] = int(req.prompt[t])
            if active.any():
                _, self.caches = self._step(
                    self.params, jnp.asarray(tok), self.caches,
                    jnp.asarray(active),
                )
                self.stats["prefill_steps"] += 1

    # -- decode loop ----------------------------------------------------------

    def _sample(self, logits: np.ndarray, slot: int) -> int:
        req = self.slots[slot]
        row = logits[slot, -1]
        if req.temperature <= 0:
            return int(row.argmax())
        z = row / req.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One batched decode step. Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        mask = np.zeros(self.num_slots, bool)
        mask[active] = True
        logits, self.caches = self._step(
            self.params, jnp.asarray(self._next_token), self.caches,
            jnp.asarray(mask),
        )
        self.stats["decode_steps"] += 1
        logits = np.asarray(logits.astype(jnp.float32))
        for slot in active:
            req = self.slots[slot]
            nxt = self._sample(logits, slot)
            req.output.append(nxt)
            self._next_token[slot, 0] = nxt
            self._budget[slot] -= 1
            if self._budget[slot] <= 0 or (
                req.eos_id is not None and nxt == req.eos_id
            ):
                req.done = True
                self.slots[slot] = None
        return len(active)

    def drain(self, requests: list[Request], max_steps: int = 100_000) -> None:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
