"""Batched serving engine: slot-based continuous batching over decode steps.

The engine owns a batch of ``num_slots`` sequence slots backed by one
batched KV/SSM cache pytree (batch = slot axis). Requests are admitted
into free slots, prefilled, then advanced together by a single jitted
decode step per token — the slot axis stays fully batched no matter how
requests arrive/finish (continuous batching). Finished slots are freed and
refilled from the queue.

Prefill here feeds the prompt through the decode path token-by-token into
the slot's cache. That is the universally-correct path across all five
architecture families (attention KV, SSM state, hybrid, cross-attn);
the batched one-shot prefill used at scale is exercised by
``launch/dryrun.py``'s prefill cells, where it matters for the roofline.

Sampling: greedy or temperature; per-slot RNG for reproducibility.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        num_slots: int = 8,
        max_len: int = 512,
        cache_dtype=jnp.float32,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.caches = model.init_caches(params, num_slots, max_len, cache_dtype)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: list[Request] = []
        self._next_token = np.zeros((num_slots, 1), np.int32)
        self._budget = np.zeros(num_slots, np.int64)
        self._rng = np.random.default_rng(seed)

        def step(params, tok, caches):
            return model.decode(params, tok, caches)

        self._step = jax.jit(step)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                self._prefill(slot, req)

    def _prefill(self, slot: int, req: Request) -> None:
        """Feed the prompt through the decode path into this slot's cache.

        The batched cache is advanced with the *other* slots' tokens held
        at their last value; only this slot's cache lanes change for those
        steps because each slot's cache row is independent along batch.
        """
        for t in req.prompt[:-1]:
            tok = self._next_token.copy()
            tok[slot, 0] = int(t)
            logits, self.caches = self._step(
                self.params, jnp.asarray(tok), self.caches
            )
        self._next_token[slot, 0] = int(req.prompt[-1])
        self._budget[slot] = req.max_new_tokens

    # -- decode loop ----------------------------------------------------------

    def _sample(self, logits: np.ndarray, slot: int) -> int:
        req = self.slots[slot]
        row = logits[slot, -1]
        if req.temperature <= 0:
            return int(row.argmax())
        z = row / req.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One batched decode step. Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(self._next_token), self.caches
        )
        logits = np.asarray(logits.astype(jnp.float32))
        for slot in active:
            req = self.slots[slot]
            nxt = self._sample(logits, slot)
            req.output.append(nxt)
            self._next_token[slot, 0] = nxt
            self._budget[slot] -= 1
            if self._budget[slot] <= 0 or (
                req.eos_id is not None and nxt == req.eos_id
            ):
                req.done = True
                self.slots[slot] = None
        return len(active)

    def drain(self, requests: list[Request], max_steps: int = 100_000) -> None:
        for r in requests:
            self.submit(r)
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
