"""Fault-tolerant serving fleet: many engines, one process, failures included.

``ServingFleet`` runs several ``ServingEngine``s (different model configs,
one shared process — and therefore one shared autotune cache, since the
kernel autotuner is process-global) behind a single step loop:

- **Admission quotas.** Each engine gets a ``quota`` — the max requests
  the fleet keeps in flight (engine queue + slots) for that model at
  once. Excess submissions wait in the fleet backlog; no engine's queue
  can be starved or flooded by another model's traffic.
- **Deadlines + bounded retry.** A request can carry a deadline in
  fleet steps after forwarding (``deadline=``), wall-clock seconds
  after forwarding (``deadline_s=``), or both — a slow or stalled
  engine step cannot stretch a seconds deadline the way it stretches a
  step count. Past either limit, the fleet cancels the request out of
  the engine and re-queues the *prompt* with exponential backoff; after
  ``max_retries`` the request is marked ``failed`` (never silently
  dropped — the caller always observes done or failed).
  ``stats["deadline_cancels"]`` counts all cancels, with the per-unit
  breakdown in ``stats["deadline_cancels_steps"]`` /
  ``stats["deadline_cancels_wall"]``.
- **Snapshots.** Every ``snapshot_every`` fleet steps each engine's
  serving state (page pools, page tables, slot bindings, RNG streams,
  pending queue — see ``ServingEngine.snapshot``) is persisted through
  ``checkpoint.AsyncCheckpointer`` (or kept in memory when no
  ``snapshot_dir``). The write happens off-thread; the step loop never
  waits on disk.
- **Recovery.** ``recover()`` restores every engine that just failed
  from its latest snapshot. In-flight requests that were live at the
  snapshot resume bit-identically (same caches, same RNG stream
  position, output truncated to the snapshot point so replay re-emits
  the identical tokens — no duplicates, no losses); requests submitted
  after the snapshot restart from their prompt. Pair with
  ``runtime.ServeSupervisor`` for the catch-restore-retry loop, and its
  ``on_failure`` hook + ``remesh_engine`` for mesh-member loss.
"""

from __future__ import annotations

import time
from typing import Any, Optional

from repro.serving.engine import Request, ServingEngine


class ServingFleet:
    def __init__(
        self,
        snapshot_dir: Optional[str] = None,
        snapshot_every: int = 0,
        keep: int = 3,
        default_deadline: Optional[int] = None,
        default_deadline_s: Optional[float] = None,
        max_retries: int = 2,
        backoff_steps: int = 4,
        clock=time.monotonic,
    ):
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.keep = keep
        self.default_deadline = default_deadline
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.backoff_steps = backoff_steps
        self._clock = clock  # injectable for deterministic deadline tests
        self.engines: dict[str, ServingEngine] = {}
        self.quotas: dict[str, Optional[int]] = {}
        self._ckpt: dict[str, Any] = {}  # name -> AsyncCheckpointer
        self._last_snap: dict[str, dict] = {}  # name -> in-memory snapshot
        # backlog entry: {"req", "retries", "not_before", "deadline",
        # "deadline_s", "forwarded_at", "forwarded_time"}; forwarded
        # entries stay tracked until done
        self._backlog: dict[str, list[dict]] = {}
        self._inflight: dict[str, list[dict]] = {}
        self._step_idx = 0
        self._failed_engine: Optional[str] = None
        self.events: list[dict] = []
        self.stats = {
            "snapshots": 0,
            "recoveries": 0,
            "retries": 0,
            "deadline_cancels": 0,
            "deadline_cancels_steps": 0,
            "deadline_cancels_wall": 0,
            "failed_requests": 0,
            "recovery_s": 0.0,
        }

    # -- configuration --------------------------------------------------------

    def add_engine(
        self,
        name: str,
        engine: ServingEngine,
        quota: Optional[int] = None,
    ) -> ServingEngine:
        if name in self.engines:
            raise ValueError(f"engine {name!r} already registered")
        self.engines[name] = engine
        self.quotas[name] = quota
        self._backlog[name] = []
        self._inflight[name] = []
        if self.snapshot_dir is not None:
            from repro.checkpoint import AsyncCheckpointer
            import os

            self._ckpt[name] = AsyncCheckpointer(
                os.path.join(self.snapshot_dir, name), keep=self.keep
            )
        # a step-0 snapshot always exists, so recovery has a target even
        # before the first periodic snapshot fires
        self._snapshot_engine(name)
        return engine

    # -- request lifecycle ----------------------------------------------------

    def submit(
        self,
        name: str,
        req: Request,
        deadline: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> None:
        """Queue ``req`` for engine ``name``; forwarded under its quota.

        ``deadline`` counts fleet steps after forwarding; ``deadline_s``
        counts wall-clock seconds after forwarding. Either, both, or
        neither may be set (falling back to the fleet defaults) —
        whichever limit trips first cancels the attempt.
        """
        if name not in self.engines:
            raise KeyError(f"unknown engine {name!r}")
        self._backlog[name].append(
            {
                "req": req,
                "retries": 0,
                "not_before": 0,
                "deadline": deadline
                if deadline is not None
                else self.default_deadline,
                "deadline_s": deadline_s
                if deadline_s is not None
                else self.default_deadline_s,
                "forwarded_at": None,
                "forwarded_time": None,
            }
        )

    def _forward(self, name: str) -> None:
        eng = self.engines[name]
        quota = self.quotas[name]
        backlog = self._backlog[name]
        inflight = self._inflight[name]
        i = 0
        while i < len(backlog):
            if quota is not None and len(inflight) >= quota:
                break
            entry = backlog[i]
            if entry["not_before"] > self._step_idx:
                i += 1
                continue
            req: Request = entry["req"]
            req.output.clear()
            req.done = False
            eng.submit(req)
            entry["forwarded_at"] = self._step_idx
            entry["forwarded_time"] = self._clock()
            inflight.append(entry)
            backlog.pop(i)
        # backlog order is preserved: entries only leave when forwarded

    def _reap(self, name: str) -> None:
        inflight = self._inflight[name]
        self._inflight[name] = [e for e in inflight if not e["req"].done]

    def _deadlines(self, name: str) -> None:
        eng = self.engines[name]
        now = self._clock()
        keep = []
        for entry in self._inflight[name]:
            req: Request = entry["req"]
            dl = entry["deadline"]
            dls = entry.get("deadline_s")
            over_steps = (
                dl is not None
                and self._step_idx - entry["forwarded_at"] > dl
            )
            over_wall = (
                dls is not None
                and entry.get("forwarded_time") is not None
                and now - entry["forwarded_time"] > dls
            )
            if req.done or not (over_steps or over_wall):
                keep.append(entry)
                continue
            eng.cancel(req.uid)
            # step deadlines take attribution precedence when both trip
            # in the same sweep; the total always counts each cancel once
            unit = "steps" if over_steps else "wall"
            self.stats["deadline_cancels"] += 1
            self.stats[f"deadline_cancels_{unit}"] += 1
            entry["retries"] += 1
            entry["forwarded_at"] = None
            entry["forwarded_time"] = None
            if entry["retries"] > self.max_retries:
                req.failed = True
                self.stats["failed_requests"] += 1
                self.events.append(
                    {
                        "event": "request_failed",
                        "engine": name,
                        "uid": req.uid,
                        "retries": entry["retries"] - 1,
                        "step": self._step_idx,
                    }
                )
                continue
            entry["not_before"] = self._step_idx + self.backoff_steps * (
                2 ** (entry["retries"] - 1)
            )
            self._backlog[name].append(entry)
            self.events.append(
                {
                    "event": "deadline_retry",
                    "engine": name,
                    "uid": req.uid,
                    "retry": entry["retries"],
                    "unit": unit,
                    "not_before": entry["not_before"],
                    "step": self._step_idx,
                }
            )
        self._inflight[name] = keep

    # -- snapshots / recovery -------------------------------------------------

    def _snapshot_engine(self, name: str) -> None:
        snap = self.engines[name].snapshot()
        self._last_snap[name] = snap
        ck = self._ckpt.get(name)
        if ck is not None:
            ck.save(self._step_idx, snap)
        self.stats["snapshots"] += 1

    def recover(self, error: Optional[BaseException] = None) -> dict:
        """Restore the engine(s) that just failed from latest snapshots.

        Called by ``ServeSupervisor`` after a retryable step failure;
        restores the engine the failed step was driving (or every engine
        when attribution is unknown). Returns a recovery record with the
        wall-clock restore latency — the bench's recovery-latency metric.
        """
        t0 = time.perf_counter()
        names = (
            [self._failed_engine]
            if self._failed_engine is not None
            else list(self.engines)
        )
        for name in names:
            eng = self.engines[name]
            ck = self._ckpt.get(name)
            snap, step = self._last_snap.get(name), None
            if ck is not None:
                try:
                    ck.wait()  # surface in-flight write errors first
                finally:
                    pass
                from repro.checkpoint import load_checkpoint, unflatten_like
                import numpy as np

                flat, step = load_checkpoint(ck.ckpt_dir)
                # template supplies tree structure only (meta is a
                # variable-length blob, so its shape can't matter)
                snap = unflatten_like(
                    {"caches": eng.caches, "meta": np.zeros(0, np.uint8)},
                    flat,
                )
            if snap is None:
                raise RuntimeError(f"no snapshot to recover engine {name!r}")
            eng.restore(snap)
            # forwarded-but-rolled-back entries go back under deadline
            # accounting from the restore point
            for entry in self._inflight[name]:
                if not entry["req"].done:
                    entry["forwarded_at"] = self._step_idx
                    entry["forwarded_time"] = self._clock()
        dt = time.perf_counter() - t0
        self.stats["recoveries"] += 1
        self.stats["recovery_s"] += dt
        rec = {
            "event": "recovered",
            "engines": names,
            "error": repr(error) if error is not None else None,
            "step": self._step_idx,
            "seconds": dt,
            "snapshot_step": step,
        }
        self.events.append(rec)
        self._failed_engine = None
        return rec

    def remesh_engine(self, name: str, new_mesh) -> None:
        """Shrink/grow one engine's mesh (mesh-member loss recovery)."""
        self.engines[name].remesh(new_mesh)
        self.events.append(
            {
                "event": "remeshed",
                "engine": name,
                "devices": len(new_mesh.devices.flatten()),
                "step": self._step_idx,
            }
        )

    # -- step loop ------------------------------------------------------------

    def step(self) -> int:
        """One fleet step: forward, step every engine, reap, deadlines.

        Returns total outstanding work (engine-active + backlogged);
        0 means the fleet is drained. A crash inside an engine's step
        leaves ``self._failed_engine`` naming it for ``recover``.
        """
        self._step_idx += 1
        total = 0
        for name, eng in self.engines.items():
            self._forward(name)
            self._failed_engine = name
            n = eng.step()
            self._failed_engine = None
            self._reap(name)
            self._deadlines(name)
            total += n + len(eng.queue) + len(self._backlog[name])
            total += sum(
                1 for e in self._inflight[name] if not e["req"].done
            )
        if (
            self.snapshot_every
            and self._step_idx % self.snapshot_every == 0
        ):
            for name in self.engines:
                self._snapshot_engine(name)
        return total

    def wait(self) -> None:
        """Block on outstanding snapshot writes (surfaces write errors)."""
        for ck in self._ckpt.values():
            ck.wait()
