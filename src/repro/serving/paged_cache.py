"""Paged KV/SSM cache: fixed-size page pools + per-slot page tables.

JetStream/vLLM-style cache layout for the serving engine. Instead of one
dense ``(B, max_len, G, hd)`` lane per slot, K/V live in a shared pool of
``num_pages`` pages of ``page_size`` tokens each; a per-slot page table
``(B, pages_per_slot)`` maps logical page index -> physical page (-1 =
not allocated). Slots claim pages lazily as their sequence grows and
return them on completion, so pool memory tracks *live tokens*, not
``num_slots x max_len`` worst case — and admission can apply backpressure
(request stays queued) instead of crashing when the pool is full.

Two cache node kinds, detected structurally by key (``is_paged``):

  paged KV   {"kp","vp": (Np, pg, G, hd), ["ks","vs": (Np, pg, G) f32],
              "table": (B, P) int32, "pos": (B,) int32}
  paged SSM  {"ssdp": (Ns, H, Phd, N) f32, "convp": (Ns, K-1, D),
              "sidx": (B,) int32}

Layer-stacked variants carry a leading L axis on every leaf (the page
table is identical across layers — ``set_tables`` broadcasts it).

Quantized KV (``PagedSpec.quantized``): pools store int8 with per-token-
position, per-kv-head f32 scales (``ks``/``vs``) — scale = absmax over
head_dim / 127, computed at write, applied at gather. Finer than
per-page scaling, and single-token decode writes never requantize
previously written positions. SSM state and conv rings stay float
(recurrent state error compounds; KV read error does not).

Bit-exactness of the f32/bf16 paged path vs the dense cache: the gather
materializes the same ``(B, S_view, G, hd)`` K/V view attention already
consumed, positions past ``pos`` (stale/unallocated pages, clamped -1
table entries) are masked to -1e30 before softmax exactly as dense
masking is, and pool contents are always finite — so masked lanes
contribute exact 0.0 and greedy decode is bit-identical (locked by
tests/test_paged_cache.py).

Host-side ``PageAllocator`` is reservation-based: admission reserves the
worst-case page count up front (``can_reserve``/``reserve``), so the
lazy per-step ``alloc`` calls during decode are guaranteed to succeed —
backpressure happens only at admission, never mid-generation.

Device helpers here import only jax (models import this lazily, the
engine directly — no import cycles).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Static description of a paged cache pool.

    ``pages_per_slot`` bounds one request's logical pages (ceil(max_len /
    page_size)); ``num_pages`` is the physical pool (< num_slots *
    pages_per_slot oversubscribes — admission backpressure keeps it
    safe). ``num_state_pages`` sizes the SSM/conv state pool (one page
    per concurrently active slot).
    """

    page_size: int
    num_pages: int
    pages_per_slot: int
    num_state_pages: int
    quantized: bool = False


def is_paged(node: Any) -> bool:
    """True for paged cache dict nodes (KV or SSM state)."""
    return isinstance(node, dict) and ("kp" in node or "ssdp" in node)


# ---------------------------------------------------------------------------
# host-side page accounting
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list page allocator with per-slot worst-case reservations.

    ``reserve(slot, n)`` commits n pages to a slot before any are
    handed out; ``alloc(slot)`` draws one of them. Because admission
    only proceeds when ``can_reserve`` holds, ``alloc`` cannot run dry
    mid-decode — the no-crash half of the backpressure contract.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))
        self._owned: dict[int, list[int]] = {}
        self._pending: dict[int, int] = {}  # slot -> reserved-not-yet-drawn
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def pending_reserved(self) -> int:
        return sum(self._pending.values())

    def can_reserve(self, n: int) -> bool:
        return n <= len(self._free) - self.pending_reserved

    def reserve(self, slot: int, n: int) -> None:
        if not self.can_reserve(n):
            raise RuntimeError(
                f"reserve({slot}, {n}): only "
                f"{len(self._free) - self.pending_reserved} unreserved pages"
            )
        self._pending[slot] = self._pending.get(slot, 0) + n

    def alloc(self, slot: int) -> int:
        if self._pending.get(slot, 0) <= 0:
            raise RuntimeError(f"slot {slot} allocates past its reservation")
        self._pending[slot] -= 1
        page = self._free.pop()
        self._owned.setdefault(slot, []).append(page)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return page

    def free_slot(self, slot: int) -> None:
        """Return a finished slot's pages (and unused reservation)."""
        self._free.extend(reversed(self._owned.pop(slot, [])))
        self._pending.pop(slot, None)


# ---------------------------------------------------------------------------
# empty pools
# ---------------------------------------------------------------------------


def empty_paged_kv(
    batch: int, spec: PagedSpec, g: int, hd: int, dtype
) -> dict[str, jax.Array]:
    pool_dt = jnp.int8 if spec.quantized else dtype
    out = {
        "kp": jnp.zeros((spec.num_pages, spec.page_size, g, hd), pool_dt),
        "vp": jnp.zeros((spec.num_pages, spec.page_size, g, hd), pool_dt),
        "table": jnp.full((batch, spec.pages_per_slot), -1, jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if spec.quantized:
        out["ks"] = jnp.zeros((spec.num_pages, spec.page_size, g), jnp.float32)
        out["vs"] = jnp.zeros((spec.num_pages, spec.page_size, g), jnp.float32)
    return out


def empty_paged_ssm(
    batch: int, spec: PagedSpec, nheads: int, head_dim: int, d_state: int,
    d_conv: int, d_xbc: int, dtype
) -> dict[str, jax.Array]:
    ns = spec.num_state_pages
    return {
        "ssdp": jnp.zeros((ns, nheads, head_dim, d_state), jnp.float32),
        "convp": jnp.zeros((ns, d_conv - 1, d_xbc), dtype),
        "sidx": jnp.full((batch,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# KV pool read/write
# ---------------------------------------------------------------------------


def kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., hd) float -> (int8 values, per-(...,) f32 scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def paged_kv_read(
    cache: dict[str, jax.Array], dtype
) -> tuple[jax.Array, jax.Array]:
    """Gather the page table into dense (B, P*pg, G, hd) K/V views.

    Unallocated entries (-1) clamp to page 0; every logical position a
    clamped entry can contribute lies past ``pos`` and is masked out of
    attention, so the clamp never leaks data (and pool contents are
    finite, so masked positions contribute exact 0.0 after softmax).
    """
    table = cache["table"]  # (B, P)
    b, p = table.shape
    phys = jnp.maximum(table, 0)

    def rd(pool, spool):
        pages = pool[phys]  # (B, P, pg, G, hd)
        if spool is not None:
            pages = pages.astype(jnp.float32) * spool[phys][..., None]
        return pages.reshape(b, -1, pool.shape[-2], pool.shape[-1]).astype(
            dtype
        )

    return (rd(cache["kp"], cache.get("ks")),
            rd(cache["vp"], cache.get("vs")))


def paged_kv_write_token(
    cache: dict[str, jax.Array],
    k: jax.Array,  # (B, G, hd) post-RoPE
    v: jax.Array,
) -> dict[str, jax.Array]:
    """Scatter one decode token per slot at its ``pos``; advance ``pos``.

    Slots whose current page is unallocated (table -1: inactive lanes)
    scatter to an out-of-bounds sentinel and are dropped — the engine
    guarantees active slots always have their write page allocated.
    """
    kp = cache["kp"]
    n_pages, pg = kp.shape[0], kp.shape[1]
    pos = cache["pos"]
    lp = pos // pg
    phys = jnp.take_along_axis(cache["table"], lp[:, None], axis=1)[:, 0]
    phys = jnp.where(phys >= 0, phys, n_pages)  # OOB -> dropped
    off = pos % pg
    out = dict(cache)
    if "ks" in cache:
        qk, sk = kv_quantize(k)
        qv, sv = kv_quantize(v)
        out["kp"] = kp.at[phys, off].set(qk, mode="drop")
        out["vp"] = cache["vp"].at[phys, off].set(qv, mode="drop")
        out["ks"] = cache["ks"].at[phys, off].set(sk, mode="drop")
        out["vs"] = cache["vs"].at[phys, off].set(sv, mode="drop")
    else:
        out["kp"] = kp.at[phys, off].set(k.astype(kp.dtype), mode="drop")
        out["vp"] = cache["vp"].at[phys, off].set(
            v.astype(kp.dtype), mode="drop"
        )
    out["pos"] = pos + 1
    return out


def paged_kv_write_prefill(
    cache: dict[str, jax.Array],
    k: jax.Array,  # (B, S, G, hd) post-RoPE, from attention(return_kv=True)
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32 — tokens consumed per slot (0 = skip)
) -> dict[str, jax.Array]:
    """One-shot prefill scatter through the page table; ``pos``=lengths."""
    kp = cache["kp"]
    n_pages, pg = kp.shape[0], kp.shape[1]
    b, s = k.shape[0], k.shape[1]
    p = cache["table"].shape[1]
    t = jnp.arange(s)
    keep = t[None, :] < lengths[:, None]  # (B, S)
    # clamp logical pages of masked tail positions (pow2 bucket can pad
    # past pages_per_slot); kept positions are < max_len, so in range
    lp = jnp.broadcast_to(jnp.minimum(t // pg, p - 1)[None, :], (b, s))
    phys = jnp.take_along_axis(cache["table"], lp, axis=1)  # (B, S)
    phys = jnp.where(keep & (phys >= 0), phys, n_pages)  # OOB -> dropped
    off = jnp.broadcast_to((t % pg)[None, :], (b, s))
    out = dict(cache)
    if "ks" in cache:
        qk, sk = kv_quantize(k)
        qv, sv = kv_quantize(v)
        out["kp"] = kp.at[phys, off].set(qk, mode="drop")
        out["vp"] = cache["vp"].at[phys, off].set(qv, mode="drop")
        out["ks"] = cache["ks"].at[phys, off].set(sk, mode="drop")
        out["vs"] = cache["vs"].at[phys, off].set(sv, mode="drop")
    else:
        out["kp"] = kp.at[phys, off].set(k.astype(kp.dtype), mode="drop")
        out["vp"] = cache["vp"].at[phys, off].set(
            v.astype(kp.dtype), mode="drop"
        )
    out["pos"] = jnp.broadcast_to(
        lengths.astype(jnp.int32), cache["pos"].shape
    )
    return out


# ---------------------------------------------------------------------------
# SSM state pool gather/scatter
# ---------------------------------------------------------------------------


def ssm_gather(cache: dict[str, jax.Array]):
    """Pool -> per-slot dense {"ssd","conv"} view + a scatter-back closure.

    Works on unstacked (hybrid per-layer) and layer-stacked (pure-SSM
    scan) pools; the state-page index ``sidx`` is identical across
    layers, so the stacked form reads layer 0's copy. Slots without a
    state page (-1) read page 0 — their lanes are inactive and the
    engine's merge discards whatever they compute — and scatter to an
    out-of-bounds sentinel (dropped).
    """
    stacked = cache["sidx"].ndim == 2
    sidx = cache["sidx"][0] if stacked else cache["sidx"]
    ns = cache["ssdp"].shape[1 if stacked else 0]
    gi = jnp.maximum(sidx, 0)
    if stacked:
        dense = {"ssd": cache["ssdp"][:, gi], "conv": cache["convp"][:, gi]}
    else:
        dense = {"ssd": cache["ssdp"][gi], "conv": cache["convp"][gi]}
    tgt = jnp.where(sidx >= 0, sidx, ns)  # OOB -> dropped

    def put(new: dict[str, jax.Array]) -> dict[str, jax.Array]:
        ssd = new["ssd"].astype(cache["ssdp"].dtype)
        conv = new["conv"].astype(cache["convp"].dtype)
        if stacked:
            return {
                "ssdp": cache["ssdp"].at[:, tgt].set(ssd, mode="drop"),
                "convp": cache["convp"].at[:, tgt].set(conv, mode="drop"),
                "sidx": cache["sidx"],
            }
        return {
            "ssdp": cache["ssdp"].at[tgt].set(ssd, mode="drop"),
            "convp": cache["convp"].at[tgt].set(conv, mode="drop"),
            "sidx": cache["sidx"],
        }

    return dense, put


# ---------------------------------------------------------------------------
# merge / table plumbing (slot isolation on the pool layout)
# ---------------------------------------------------------------------------


def paged_merge(
    old: dict[str, jax.Array], new: dict[str, jax.Array], active: jax.Array
) -> dict[str, jax.Array]:
    """Slot-isolation merge for one paged cache node.

    Dense caches merge per batch lane; pools merge per *page*: a pool
    page takes the freshly computed state iff an active slot owns it in
    the OLD table (the table is engine-owned — ``set_tables`` is its
    only writer, so old and new agree and old is authoritative). Pages
    owned by inactive slots — and free pages — are reverted, which is
    exactly the bit-identical-lane invariant the dense merge provides.
    ``pos`` merges per lane; ``table``/``sidx`` pass through from old.
    """
    out = dict(old)
    if "kp" in old:
        stacked = old["table"].ndim == 3
        table = old["table"][0] if stacked else old["table"]
        n_pages = old["kp"].shape[1 if stacked else 0]
        owned = jnp.where(active[:, None], table, -1).reshape(-1)
        mask = jnp.zeros((n_pages,), bool).at[
            jnp.where(owned >= 0, owned, n_pages)
        ].set(True, mode="drop")
        ax = 1 if stacked else 0
        for key in ("kp", "vp", "ks", "vs"):
            if key in old:
                o = old[key]
                m = mask.reshape(
                    (1,) * ax + (n_pages,) + (1,) * (o.ndim - ax - 1)
                )
                out[key] = jnp.where(m, new[key], o)
        amask = active[None, :] if stacked else active
        out["pos"] = jnp.where(amask, new["pos"], old["pos"])
        out["table"] = old["table"]
        return out
    stacked = old["sidx"].ndim == 2
    sidx = old["sidx"][0] if stacked else old["sidx"]
    ns = old["ssdp"].shape[1 if stacked else 0]
    owned = jnp.where(active, sidx, -1)
    mask = jnp.zeros((ns,), bool).at[
        jnp.where(owned >= 0, owned, ns)
    ].set(True, mode="drop")
    ax = 1 if stacked else 0
    for key in ("ssdp", "convp"):
        o = old[key]
        m = mask.reshape((1,) * ax + (ns,) + (1,) * (o.ndim - ax - 1))
        out[key] = jnp.where(m, new[key], o)
    out["sidx"] = old["sidx"]
    return out


def set_tables(
    caches: Any, table, sidx: Optional[Any] = None
) -> Any:
    """Install the host-side page table / state-page index device-wide.

    Walks the cache pytree and swaps the ``table`` (and ``sidx``) leaf of
    every paged node, broadcasting to stacked (L, ...) shapes. Called by
    the engine at admission (after allocation) and before lazy per-step
    page allocation takes effect.
    """
    tab = jnp.asarray(table, jnp.int32)
    sx = None if sidx is None else jnp.asarray(sidx, jnp.int32)

    def fix(node):
        if not is_paged(node):
            return node
        node = dict(node)
        if "table" in node:
            node["table"] = jnp.broadcast_to(tab, node["table"].shape)
        if "sidx" in node and sx is not None:
            node["sidx"] = jnp.broadcast_to(sx, node["sidx"].shape)
        return node

    return jax.tree_util.tree_map(fix, caches, is_leaf=is_paged)


def cache_nbytes(caches: Any) -> int:
    """Total cache footprint in bytes (the benchmark's memory metric)."""
    return sum(
        int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(caches)
        if hasattr(leaf, "size")
    )


def snapshot(caches: Any) -> Any:
    """Host copy of a cache pytree (pool contents + tables + positions).

    Every leaf is pulled to host as np.ndarray — the serving-state
    snapshot the fleet persists via AsyncCheckpointer. Works on dense
    caches too; paged pools are the interesting case (page contents,
    per-slot page tables, scales) because restoring them resumes
    mid-decode attention bit-identically.
    """
    return jax.tree_util.tree_map(np.asarray, caches)


def restore(template: Any, snap: Any) -> Any:
    """Rebuild a device cache pytree from a ``snapshot()``.

    ``template`` supplies structure, dtypes and device placement (a
    freshly built cache, or the pre-failure one); ``snap`` supplies the
    values. Leaves are shape-checked, cast to the template dtype (int8
    pools survive a float round-trip through npz untouched since values
    are exact), and device_put to the template leaf's sharding when it
    is a committed jax array — so a restore after ``elastic_remesh``
    lands pools on the new mesh.
    """
    t_leaves, tdef = jax.tree_util.tree_flatten(template)
    s_leaves = tdef.flatten_up_to(snap)
    out = []
    for t, s in zip(t_leaves, s_leaves):
        arr = np.asarray(s)
        if tuple(arr.shape) != tuple(np.shape(t)):
            raise ValueError(
                f"cache snapshot shape mismatch: {arr.shape} vs {np.shape(t)}"
            )
        arr = arr.astype(jnp.dtype(t.dtype)) if hasattr(t, "dtype") else arr
        if isinstance(t, jax.Array) and t.committed:
            out.append(jax.device_put(arr, t.sharding))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)
