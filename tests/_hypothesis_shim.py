"""Hypothesis compatibility shim for offline containers.

The property tests are written against the real ``hypothesis`` API. When
the package is installed, this module re-exports it untouched. When it is
absent (offline CI images), a minimal drop-in replacement runs each
property over a deterministic, seeded sweep of examples instead: every
``@given`` test still exercises a spread of random inputs, it just loses
shrinking and the adaptive search.

Supported surface (all the repo's tests use):
  - ``given(*strategies)`` with positional strategies filling the trailing
    test parameters
  - ``settings(max_examples=..., deadline=...)`` stacked above ``given``
  - ``strategies.integers(lo, hi)``, ``strategies.floats(lo, hi,
    allow_nan=False)``, ``strategies.lists(elem, min_size=, max_size=)``
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings  # noqa: F401 — re-exports
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    # Cap the fallback sweep: the shim is a breadth check, not a search.
    _MAX_FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, **_kw):
            def draw(rng):
                # mix endpoints and zero in occasionally, like hypothesis
                r = rng.random()
                if r < 0.05:
                    return float(min_value)
                if r < 0.10:
                    return float(max_value)
                if r < 0.15 and min_value <= 0.0 <= max_value:
                    return 0.0
                return float(rng.uniform(min_value, max_value))

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples=_MAX_FALLBACK_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            remaining = params[: len(params) - len(strats)]
            # strategies fill the TRAILING parameters; drawn values are
            # passed by name so leading params may arrive positionally
            # or as keywords (pytest.mark.parametrize passes keywords)
            drawn_names = [p.name for p in params[len(remaining):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_shim_max_examples",
                            _MAX_FALLBACK_EXAMPLES),
                    _MAX_FALLBACK_EXAMPLES,
                )
                for i in range(n):
                    rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                    drawn = {
                        name: s.example(rng)
                        for name, s in zip(drawn_names, strats)
                    }
                    fn(*args, **drawn, **kwargs)

            # Hide the drawn parameters from pytest so it does not try to
            # resolve them as fixtures.
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper

        return deco
