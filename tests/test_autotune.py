"""Measured block autotuning (kernels/autotune.py) and the block
resolution chain in kernels/ops (env override > autotune > static)."""

import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dispatch import pqs_dot
from repro.kernels import autotune, ops

TINY = ((4, 8, 32), (2, 4, 16))  # fast interpret-mode candidate set


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    """Isolated cache file + tiny candidates; restores module state."""
    cache = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE_CACHE", cache)
    monkeypatch.setattr(autotune, "CANDIDATES",
                        {p: TINY for p in ops.POLICIES})
    monkeypatch.setattr(autotune, "REPS", 1)
    autotune.reset()
    yield cache
    autotune.reset()


def _xw(m=8, k=64, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8),
            jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8))


def test_mode_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_PQS_AUTOTUNE", raising=False)
    assert autotune.mode() == "off"
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "TUNE")
    assert autotune.mode() == "tune"
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "always")
    with pytest.raises(ValueError, match="REPRO_PQS_AUTOTUNE"):
        autotune.mode()


def test_off_mode_never_touches_cache(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "off")
    x, w = _xw()
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16)
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=2, bn=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert not os.path.exists(tuner)


def test_tune_persist_readonly_roundtrip(tuner, monkeypatch):
    """The acceptance criterion: tune -> persist -> readonly reload picks
    the same blocks, and results stay bit-identical throughout."""
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    x, w = _xw()
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=2, bn=2)
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16)  # schedules
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    autotune.drain()  # background measurement lands

    data = json.load(open(tuner))
    assert data["version"] == 1
    (key, e), = data["entries"].items()
    assert key == autotune.shape_key("clip", "cpu", 8, 8, 64)
    winner = (e["bm"], e["bn"], e["bk"])
    assert winner in TINY and e["us"] > 0

    # fresh process simulation: drop memos, readonly reload
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "readonly")
    autotune.reset()
    assert autotune.best_blocks("clip", 8, 8, 64) == winner
    out2 = ops.policy_matmul(x, w, policy="clip", acc_bits=16)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    # readonly never measures: a miss answers None (static fallback)
    assert autotune.best_blocks("clip", 2048, 2048, 2048) is None
    assert json.load(open(tuner)) == data  # file untouched


def test_tune_measures_in_background(tuner, monkeypatch):
    """Tune mode must not pay measurement latency inline: the first call
    is served by the static table while a background thread measures
    (regression for the serving-path first-call stall — simulated here
    with a fake timer that stays slow until the test releases it)."""
    import threading

    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    release = threading.Event()
    timed = []

    def slow_measure(run, reps=None):
        # a candidate measurement held hostage: inline tuning would
        # block the serving call on this wait
        release.wait(timeout=30)
        timed.append(run)
        return float(len(timed))

    monkeypatch.setattr(autotune, "measure_us", slow_measure)
    x, w = _xw(seed=7)
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16)
    # the call came back while the measurement is still blocked: nothing
    # persisted yet, the result produced by the static-table blocks
    assert not os.path.exists(tuner)
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=2, bn=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    release.set()
    autotune.drain()
    data = json.load(open(tuner))
    assert autotune.shape_key("clip", "cpu", 8, 8, 64) in data["entries"]
    # the landed winner now answers without re-measuring
    n_timed = len(timed)
    assert autotune.best_blocks("clip", 8, 8, 64) is not None
    assert len(timed) == n_timed


def test_readonly_without_cache_falls_back(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "readonly")
    x, w = _xw(seed=1)
    out = ops.policy_matmul(x, w, policy="wrap", acc_bits=12)
    ref = ops.policy_matmul(x, w, policy="wrap", acc_bits=12, bm=2, bn=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert not os.path.exists(tuner)


def test_tune_covers_sort_policies(tuner, monkeypatch):
    """Sort policies tune (bm, bn) with bk pinned to None."""
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    monkeypatch.setattr(autotune, "CANDIDATES",
                        {"sorted_tiled": ((4, 8, None), (2, 4, None))})
    x, w = _xw(k=128)
    ref = pqs_dot(x, w, acc_bits=16, policy="sorted_tiled", k_tile=32,
                  backend="jnp")
    out = ops.policy_matmul(x, w, policy="sorted_tiled", acc_bits=16,
                            k_tile=32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    autotune.drain()
    (key, e), = json.load(open(tuner))["entries"].items()
    assert key.startswith("sorted_tiled|") and e["bk"] is None


def test_env_blocks_beat_autotune(tuner, monkeypatch):
    """REPRO_PQS_BLOCKS wins over the tuner (and suppresses tuning)."""
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    monkeypatch.setenv("REPRO_PQS_BLOCKS", "clip:2,4")
    x, w = _xw(seed=2)
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16)
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=2, bn=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert not os.path.exists(tuner)  # env override short-circuits


def test_partially_pinned_blocks_skip_autotune(tuner, monkeypatch):
    """Pinning one of bm/bn bypasses the tuner entirely: a winner is a
    measured (bm, bn, bk) unit, so grafting half of it onto a pinned
    other half would apply a configuration that was never timed."""
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    x, w = _xw(seed=6)
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bn=4)
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=8, bn=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert not os.path.exists(tuner)  # nothing was measured


def test_shape_bucketing():
    """Keys bucket padded shapes to pow2 so near sizes share winners."""
    a = autotune.shape_key("clip", "cpu", 100, 500, 3000)
    b = autotune.shape_key("clip", "cpu", 128, 512, 4096)
    assert a == b == "clip|cpu|128x512x4096"
    assert autotune.shape_key("clip", "cpu", 1, 1, 1) == "clip|cpu|1x1x1"


def test_traced_first_call_does_not_poison_bucket(tuner, monkeypatch):
    """A first call under jit (tracing) skips measurement but must NOT
    memoize the miss — a later eager call still tunes the bucket."""
    import jax

    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    x, w = _xw()

    @jax.jit
    def traced(x, w):
        return ops.policy_matmul(x, w, policy="clip", acc_bits=16)

    jax.block_until_ready(traced(x, w))  # first touch happens in-trace
    autotune.drain()
    assert not os.path.exists(tuner)  # nothing measured under the trace
    out = ops.policy_matmul(x, w, policy="clip", acc_bits=16)  # eager
    autotune.drain()
    assert os.path.exists(tuner)  # ...and the eager call did tune
    ref = ops.policy_matmul(x, w, policy="clip", acc_bits=16, bm=2, bn=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_concurrent_tuner_entries_merge(tuner, monkeypatch):
    """Persisting a new bucket merges with what other processes wrote to
    the shared file since our last read (no lost updates)."""
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    x, w = _xw()
    ops.policy_matmul(x, w, policy="clip", acc_bits=16)  # tune bucket 1
    autotune.drain()
    # another process lands its own bucket in the shared file
    data = json.load(open(tuner))
    foreign = {"bm": 64, "bn": 64, "bk": 512, "us": 1.0}
    data["entries"]["wide|cpu|512x512x512"] = foreign
    with open(tuner, "w") as f:
        json.dump(data, f)
    x2, w2 = _xw(m=16, k=128, n=16, seed=4)  # different bucket
    ops.policy_matmul(x2, w2, policy="clip", acc_bits=16)  # tune bucket 2
    autotune.drain()
    entries = json.load(open(tuner))["entries"]
    assert entries["wide|cpu|512x512x512"] == foreign  # survived
    assert len(entries) == 3


def test_nm_shape_key_carries_compressed_geometry():
    """nm families key on (m_group, n_keep, bucketed G), not dense K:
    equal dense K at different sparsity must not share a winner."""
    a = autotune.shape_key("nmg:clip", "cpu", 8, 8, 1024, nm=(8, 2, 64))
    assert a == "nmg:clip|cpu|8x8xg64m8k2"
    b = autotune.shape_key("nmg:clip", "cpu", 8, 8, 1024, nm=(8, 4, 64))
    assert a != b  # same dense K, different n_keep
    assert autotune.shape_key("nm:sorted", "cpu", 100, 500, 0,
                              nm=(16, 4, 100)) == "nm:sorted|cpu|128x512xg128m16k4"
    # dense families are untouched by the nm slot
    assert autotune.shape_key("clip", "cpu", 8, 8, 64) == "clip|cpu|8x8x64"


def test_nm_tune_persists_compressed_key(tuner, monkeypatch):
    """Tuning a compressed matmul lands a (m_group, n_keep, G)-shaped
    key — for the expand and the gather family independently."""
    from repro.core.pruning import nm_compress, nm_prune_mask

    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "tune")
    monkeypatch.setattr(autotune, "CANDIDATES",
                        {"nm:clip": ((4, 8, 32), (2, 4, 16)),
                         "nmg:clip": ((4, 8, 32), (2, 4, 16))})
    rng = np.random.default_rng(9)
    k, n_keep, mg = 512, 2, 8
    wd = rng.integers(-127, 127, (8, k)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
    vals, idx = nm_compress((wd * mask).astype(np.int8), n_keep, mg)
    vals = jnp.asarray(vals, jnp.int8)
    idx = jnp.asarray(idx, jnp.int32)
    x = jnp.asarray(rng.integers(-127, 127, (8, k)), jnp.int8)
    outs = {
        impl: np.asarray(ops.nm_policy_matmul(
            x, vals, idx, m_group=mg, policy="clip", acc_bits=16,
            nm_impl=impl))
        for impl in ("expand", "gather")
    }
    np.testing.assert_array_equal(outs["expand"], outs["gather"])
    autotune.drain()
    keys = set(json.load(open(tuner))["entries"])
    assert any(key.startswith("nm:clip|") and "xg64m8k2" in key
               for key in keys), keys
    assert any(key.startswith("nmg:clip|") and "xg64m8k2" in key
               for key in keys), keys


def test_stale_nm_keys_dropped_with_warning(tuner, monkeypatch):
    """Pre-gather nm entries (keyed on dense K) are dropped on read with
    a one-time migration warning; new-format and dense entries load."""
    entries = {
        "nm:clip|cpu|8x8x1024": {"bm": 4, "bn": 8, "bk": 32, "us": 1.0},
        "nmg:clip|cpu|8x8xg64m8k2": {"bm": 2, "bn": 4, "bk": 16, "us": 1.0},
        "clip|cpu|8x8x64": {"bm": 4, "bn": 8, "bk": 32, "us": 1.0},
    }
    with open(tuner, "w") as f:
        json.dump({"version": 1, "entries": entries}, f)
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "readonly")
    monkeypatch.setattr(autotune, "_WARNED_STALE", False)
    autotune.reset()
    with pytest.warns(UserWarning, match="stale"):
        assert autotune.best_blocks("clip", 8, 8, 64) == (4, 8, 32)
    assert autotune.best_blocks(
        "nmg:clip", 8, 8, 512, nm=(8, 2, 64)) == (2, 4, 16)


def test_corrupt_cache_is_ignored(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_PQS_AUTOTUNE", "readonly")
    with open(tuner, "w") as f:
        f.write("{not json")
    autotune.reset()
    assert autotune.best_blocks("clip", 8, 8, 64) is None
