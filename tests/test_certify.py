"""Accumulator-safety certification: train -> certify -> serve census-free.

Acceptance suite for `core.certify` + the certified serving fast path
(scripts/ci.sh's ``certify`` stage runs this file under
REPRO_FORCE_MULTIDEVICE=8):

- property (hypothesis through the shim): rows projected by
  ``a2q_quantize_project`` against a frozen activation range never exceed
  the certified accumulator caps — not at the final sum and not at ANY
  partial sum, including adversarial sign-aligned activations that drive
  every product the same way;
- certificates hash the integer weight codes only: scale drift and
  re-calibration never invalidate, a single tampered integer does —
  ``Certificate.verify`` raises and the engine refuses to serve;
- certified dispatch (``pqs_dot(..., certified=True)``) is bit-identical
  to the censused narrow-policy path on both backends wherever the
  certificate holds;
- end to end: a certified engine serves a drifted workload with ZERO
  census events and zero degradations, bit-identical to the censused
  engine on the same weights, while an uncertified engine on the same
  fleet still trips ``census_degrade``.
"""

import os

# same opt-in idiom as test_sharded_dispatch.py: only effective before
# the first jax backend init, never leaks into the single-device suite
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    _v = os.environ["REPRO_FORCE_MULTIDEVICE"]
    _n = int(_v) if _v.isdigit() and int(_v) > 1 else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from _hypothesis_shim import given, settings  # noqa: E402
from _hypothesis_shim import strategies as st  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import a2q, certify, dispatch  # noqa: E402
from repro.core.dispatch import pqs_dot  # noqa: E402
from repro.core.qtensor import is_qtensor, quantize_tree  # noqa: E402
from repro.core.quant import qrange  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.runtime import (  # noqa: E402
    QATConfig,
    a2q_finetune,
    quantize_and_certify,
)
from repro.serving import (  # noqa: E402
    CensusWatch,
    Request,
    ServingEngine,
    ServingFleet,
)

# menus, not open ranges: jit caches stay warm across drawn examples
KS = (7, 33, 64)
ACCS = (12, 16, 20)
ACTS = (4, 8)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def certified24(smoke_model):
    """Quantize + enforce + certify the smoke params at acc_bits=24."""
    _, _, params = smoke_model
    return quantize_and_certify(params, acc_bits=24)


# ---------------------------------------------------------------------------
# property: the certified bound is sound for ANY admissible activations


@settings(max_examples=25, deadline=None)
@given(
    st.integers(0, len(KS) - 1),
    st.integers(0, len(ACCS) - 1),
    st.integers(0, len(ACTS) - 1),
    st.integers(0, 10_000),
)
def test_projected_rows_never_overflow(ki, ai, bi, seed):
    """Rows projected against the frozen range stay inside the caps at
    every partial sum, for adversarial and random activation codes."""
    k, acc, act = KS[ki], ACCS[ai], ACTS[bi]
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1.5, (8, k)), jnp.float32)
    wq, _ = a2q.a2q_quantize_project(w, 8, acc, act_bits=act)
    wq = np.asarray(wq, np.int64)
    qlo, qhi = qrange(act)
    cap_pos, cap_neg = certify.acc_caps(acc)

    # the host-side authority agrees the projection landed inside
    pos, neg = certify.row_excursions(wq, act)
    assert (pos <= cap_pos).all() and (neg <= cap_neg).all()
    assert int(a2q.a2q_violations(
        jnp.asarray(wq, jnp.int32), 8, acc, act_bits=act
    )) == 0

    # adversarial sign-aligned codes reach the excursions exactly —
    # and still fit the register
    x_up = np.where(wq > 0, qhi, qlo).astype(np.int64)
    x_dn = np.where(wq > 0, qlo, qhi).astype(np.int64)
    assert ((wq * x_up).sum(-1) == pos).all()
    assert ((wq * x_dn).sum(-1) == -neg).all()
    assert pos.max(initial=0) <= cap_pos
    assert neg.max(initial=0) <= cap_neg

    # every PARTIAL sum of any admissible activation, in natural and a
    # shuffled accumulation order, stays inside [-cap_neg, cap_pos]
    x = rng.integers(qlo, qhi + 1, size=k).astype(np.int64)
    perm = rng.permutation(k)
    for order in (np.arange(k), perm):
        partials = np.cumsum(wq[:, order] * x[order], axis=-1)
        assert partials.max(initial=0) <= cap_pos
        assert partials.min(initial=0) >= -cap_neg


@settings(max_examples=25, deadline=None)
@given(st.integers(0, len(KS) - 1), st.integers(0, 10_000))
def test_min_acc_bits_is_minimal(ki, seed):
    """min_acc_bits returns a width that fits — and p-1 does not."""
    rng = np.random.default_rng(seed)
    wq = rng.integers(-127, 128, (4, KS[ki])).astype(np.int64)
    pos, neg = certify.row_excursions(wq, 8)
    p = certify.min_acc_bits(pos, neg)
    cap_pos, cap_neg = certify.acc_caps(p)
    assert pos.max() <= cap_pos and neg.max() <= cap_neg
    if p > 2:
        cap_pos, cap_neg = certify.acc_caps(p - 1)
        assert pos.max() > cap_pos or neg.max() > cap_neg


def test_truncate_rows_enforces_exactly():
    """truncate_rows lands inside the caps and leaves safe rows alone."""
    rng = np.random.default_rng(0)
    wq = rng.integers(-127, 128, (16, 256)).astype(np.int32)
    out = certify.truncate_rows(wq, 14, 8)
    pos, neg = certify.row_excursions(out, 8)
    cap_pos, cap_neg = certify.acc_caps(14)
    assert (pos <= cap_pos).all() and (neg <= cap_neg).all()
    # already-safe rows pass through bit-exactly
    safe = certify.truncate_rows(out, 14, 8)
    np.testing.assert_array_equal(safe, out)


# ---------------------------------------------------------------------------
# certificate identity: hashes cover integer codes, nothing else


def _drift_scale(params, factor, needle="w_up"):
    def fix(path, leaf):
        if is_qtensor(leaf) and any(needle in str(p) for p in path):
            return dataclasses.replace(leaf, scale=leaf.scale * factor)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params, is_leaf=is_qtensor)


def _tamper_values(params, needle="w_up"):
    def fix(path, leaf):
        if is_qtensor(leaf) and any(needle in str(p) for p in path):
            v = np.asarray(leaf.values).copy()
            v.flat[0] = v.flat[0] + 1 if v.flat[0] < 127 else v.flat[0] - 1
            return dataclasses.replace(leaf, values=jnp.asarray(v))
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params, is_leaf=is_qtensor)


def test_certificate_verify_and_tamper(certified24):
    qparams, cert = certified24
    assert cert.acc_bits == 24
    for sc in cert.sites:
        assert sc.acc_bits_safe <= 24 and sc.slack > 0.0
    cert.verify(qparams)  # fresh params verify
    cert.verify(_drift_scale(qparams, 8))  # scale drift never invalidates
    with pytest.raises(certify.CertificateError):
        cert.verify(_tamper_values(qparams))  # one integer code does


def test_certificate_covers_semantics(certified24):
    _, cert = certified24
    sc = cert.site("w_out")
    assert sc is not None
    assert cert.covers("w_out", 24, 8)
    assert cert.covers("w_out", 30, 8)  # wider register: still safe
    assert cert.covers("w_out", 24, 4)  # narrower activations: subset
    assert not cert.covers("w_out", sc.acc_bits_safe - 1, 8)
    assert not cert.covers("w_out", 24, 9)  # wider codes than certified
    assert not cert.covers("nonexistent_site", 24, 8)


def test_certificate_leaf_roundtrip(certified24):
    """to_leaf/from_leaf: the certificate rides on checkpoints."""
    qparams, cert = certified24
    leaf = cert.to_leaf()
    assert isinstance(leaf, np.ndarray) and leaf.dtype == np.uint8
    back = certify.Certificate.from_leaf(leaf)
    assert back.acc_bits == cert.acc_bits
    assert back.sites == cert.sites
    back.verify(qparams)


# ---------------------------------------------------------------------------
# certified dispatch: census-free and bit-identical where the cert holds


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("policy", ["sorted_tiled_seq", "sorted", "clip"])
def test_certified_dispatch_bit_identical(policy, backend):
    acc = 14
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-127, 128, (5, 96)), jnp.int8)
    w = certify.truncate_rows(
        rng.integers(-127, 128, (7, 96)).astype(np.int32), acc, 8
    ).astype(np.int8)
    kw = dict(acc_bits=acc, policy=policy, k_tile=32, backend=backend)
    ref, cns = pqs_dot(x, jnp.asarray(w), with_census=True, **kw)
    out = pqs_dot(x, jnp.asarray(w), certified=True, **kw)
    assert int(cns.n_any) == 0  # the certificate is telling the truth
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_certified_dispatch_bit_identical_ksharded():
    acc = 14
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(-127, 128, (3, 128)), jnp.int8)
    w = certify.truncate_rows(
        rng.integers(-127, 128, (4, 128)).astype(np.int32), acc, 8
    ).astype(np.int8)
    kw = dict(acc_bits=acc, policy="sorted_tiled_seq", k_tile=32,
              backend="jnp", k_shards=4)
    ref = pqs_dot(x, jnp.asarray(w), **kw)
    out = pqs_dot(x, jnp.asarray(w), certified=True, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_certified_rejects_census():
    x = jnp.zeros((2, 16), jnp.int8)
    w = jnp.zeros((2, 16), jnp.int8)
    with pytest.raises(ValueError, match="certified"):
        pqs_dot(x, w, certified=True, with_census=True)


# ---------------------------------------------------------------------------
# train: the accumulator-aware fine-tuning loop


def test_a2q_finetune_smoke(smoke_model):
    cfg, model, params = smoke_model
    rng = np.random.default_rng(7)

    def next_batch(_i):
        tok = rng.integers(1, cfg.vocab_size, size=(2, 16)).astype(np.int32)
        return {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}

    qcfg = QATConfig(acc_bits=16, census_rows=2)
    p2, history = a2q_finetune(model, params, next_batch, steps=2, cfg=qcfg)
    assert len(history) == 2
    assert all(np.isfinite(h["loss"]) for h in history)
    # the census signal is live: every QAT site reported a rate
    rates = history[-1]["census_rates"]
    assert set(rates) >= {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_out"}
    assert all(0.0 <= v <= 1.0 for v in rates.values())
    # params actually moved under the projected update
    moved = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree_util.tree_map(
            lambda a, b: jnp.asarray(a, jnp.float32)
            - jnp.asarray(b, jnp.float32),
            p2, params,
        ),
        0.0,
    )
    assert moved > 0.0
    # and the fine-tuned weights certify at the trained width after the
    # integer-domain enforcement
    _, cert = quantize_and_certify(p2, acc_bits=16)
    assert all(sc.acc_bits_safe <= 16 for sc in cert.sites)


# ---------------------------------------------------------------------------
# serve: certified engines are census-free on drifted workloads


def _reqs():
    return [
        Request(
            uid=i, prompt=np.asarray([1 + i, 2, 3 + i, 5], np.int32),
            max_new_tokens=20,
        )
        for i in range(4)
    ]


CAL = {"tokens": jnp.asarray((np.arange(32).reshape(2, 16) % 97 + 1),
                             jnp.int32)}


def test_engine_refuses_tampered_certificate(smoke_model, certified24):
    _, model, _ = smoke_model
    qparams, cert = certified24
    il = dispatch.IntegerLinConfig(
        policy="sorted_tiled_seq", acc_bits=24, k_tile=64, backend="jnp",
        certificate=cert,
    )
    with pytest.raises(certify.CertificateError):
        ServingEngine(model, _tamper_values(qparams), num_slots=2,
                      max_len=48, int_lin=il)


def test_certified_fleet_census_free_and_bit_identical(
    smoke_model, smoke_qparams17, certified24
):
    """The acceptance gate: on one fleet serving a drifted workload, the
    certified engine decodes with zero census events and zero
    degradations — bit-identical to the censused engine on the same
    weights — while the uncertified engine still trips census_degrade."""
    _, model, _ = smoke_model
    qparams, cert = certified24
    watch = CensusWatch(threshold=0.01, window=4)

    def build(params, acc_bits, certificate):
        il = dispatch.IntegerLinConfig(
            policy="sorted_tiled_seq", acc_bits=acc_bits, k_tile=64,
            backend="jnp", certificate=certificate,
        )
        eng = ServingEngine(
            model, params, num_slots=4, max_len=48,
            int_lin=il, census_watch=watch,
        )
        eng.calibrate([CAL])
        # inflate w_up's dequant scale post-calibration: w_out's input
        # leaves the frozen static range on every engine equally
        eng.params = _drift_scale(eng.params, 8)
        return eng

    certified = build(qparams, 24, cert)
    censused = build(qparams, 24, None)
    uncert = build(smoke_qparams17, 17, None)

    fleet = ServingFleet()
    fleet.add_engine("cert", certified)
    fleet.add_engine("plain", uncert)
    reqs_cert, reqs_plain = _reqs(), _reqs()
    for r in reqs_cert:
        fleet.submit("cert", r)
    for r in reqs_plain:
        fleet.submit("plain", r)
    while fleet.step():
        pass
    fleet.wait()
    assert all(r.done for r in reqs_cert + reqs_plain)

    # certified engine: census-free by construction — zero events, zero
    # degradations, not even a census rate observed
    assert certified.stats["census_degrades"] == 0
    assert certified.events == []
    assert certified._degraded == set()
    assert certified.last_census_rates == {}

    # uncertified engine on the same fleet, same drift: the guardrail
    # still fires exactly as in test_serving_fleet
    assert uncert._degraded == {"w_out"}
    (event,) = [e for e in uncert.events if e["event"] == "census_degrade"]
    assert event["site"] == "w_out"

    # bit-identity: the censused engine decodes the same tokens
    reqs_ref = _reqs()
    censused.drain(reqs_ref)
    assert censused.stats["census_degrades"] == 0
    assert {r.uid: list(r.output) for r in reqs_ref} == \
        {r.uid: list(r.output) for r in reqs_cert}


@pytest.fixture(scope="module")
def smoke_qparams17(smoke_model):
    """Plain (unenforced, uncertified) int8 quantization — the drifted
    acc_bits=17 configuration test_serving_fleet degrades under."""
    _, _, params = smoke_model
    return quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
