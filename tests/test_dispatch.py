"""Parity matrix for the unified accumulation-policy dispatch layer.

The contract: every policy produces bit-identical int32 results on the
jnp reference backend and the Pallas(interpret) kernel backend, for any
shape — including ragged, non-power-of-2 M/N/K — and the optional census
output equals the overflow library's oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import overflow
from repro.core.dispatch import (
    IntegerLinConfig,
    default_backend,
    integer_lin,
    pqs_dot,
    qtensor_dot,
)
from repro.core.qtensor import quantize_weight

POLICIES = ("wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq")
# ragged, non-power-of-2 shapes on purpose — padding is the dispatch
# layer's job now, not the caller's
SHAPES = ((5, 300, 70), (8, 64, 16), (3, 100, 9))


def _xw(m, k, n, seed=0, lo=-127, hi=127):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(lo, hi, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(lo, hi, (n, k)), jnp.int8)
    return x, w


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("acc_bits", [12, 16, 24])
def test_backend_parity_ragged(policy, acc_bits):
    for m, k, n in SHAPES:
        x, w = _xw(m, k, n, seed=acc_bits * 31 + m)
        a = pqs_dot(x, w, acc_bits=acc_bits, policy=policy, k_tile=64,
                    backend="jnp")
        b = pqs_dot(x, w, acc_bits=acc_bits, policy=policy, k_tile=64,
                    backend="pallas", block_m=4, block_n=8)
        assert a.dtype == jnp.int32 and a.shape == (m, n)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{policy} acc_bits={acc_bits} shape={(m, k, n)}",
        )


def test_backend_parity_multi_round():
    """Two sorting rounds (the overflow library's default) also agree."""
    x, w = _xw(5, 192, 9, seed=21)
    for policy in ("sorted", "sorted_tiled", "sorted_tiled_seq"):
        a = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=32, rounds=2,
                    backend="jnp")
        b = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=32, rounds=2,
                    backend="pallas", block_m=4, block_n=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=policy)


def test_wide_matches_exact_matmul():
    x, w = _xw(7, 130, 11, seed=5)
    out = pqs_dot(x, w, acc_bits=30, policy="wide")
    expect = x.astype(jnp.int32) @ w.astype(jnp.int32).T
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_matches_overflow_accumulate_oracle():
    """jnp backend == raw overflow-library semantics, policy by policy."""
    x, w = _xw(4, 128, 6, seed=9)
    prods = overflow.partial_products(w, x)
    for policy in POLICIES:
        out = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=32,
                      rounds=1, backend="jnp")
        expect = overflow.accumulate(prods, 14, policy, 32, 1)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(expect), err_msg=policy
        )


def test_census_equals_matmul_census():
    x, w = _xw(6, 200, 10, seed=3)
    _, c = pqs_dot(x, w, acc_bits=16, policy="clip", backend="jnp",
                   batch_chunk=2, with_census=True)
    ref = overflow.matmul_census(w, x, 16, batch_chunk=4)
    for field in ("n_dots", "n_persistent", "n_transient", "n_any"):
        assert int(getattr(c, field)) == int(getattr(ref, field)), field
    # census rides along unchanged for the pallas backend too
    _, cp = pqs_dot(x, w, acc_bits=16, policy="clip", backend="pallas",
                    block_m=2, block_n=2, with_census=True)
    for field in ("n_dots", "n_persistent", "n_transient", "n_any"):
        assert int(getattr(cp, field)) == int(getattr(ref, field)), field


def test_leading_batch_dims():
    """(..., K) leading dims flatten and restore transparently."""
    x, w = _xw(12, 96, 5, seed=7)
    x3 = x.reshape(2, 6, 96)
    flat = pqs_dot(x, w, acc_bits=16, policy="sorted", backend="jnp")
    shaped = pqs_dot(x3, w, acc_bits=16, policy="sorted", backend="jnp",
                     batch_chunk=4)
    assert shaped.shape == (2, 6, 5)
    np.testing.assert_array_equal(
        np.asarray(shaped).reshape(12, 5), np.asarray(flat)
    )


def test_quantized_matmul_sim_routes_through_dispatch():
    """The overflow-library entry point and pqs_dot are the same function."""
    x, w = _xw(5, 80, 7, seed=11)
    a = overflow.quantized_matmul_sim(w, x, 13, "sorted_tiled", k_tile=16,
                                      batch_chunk=2)  # legacy default rounds=2
    b = pqs_dot(x, w, acc_bits=13, policy="sorted_tiled", k_tile=16,
                rounds=2, backend="jnp")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_validation_errors():
    x, w = _xw(2, 32, 3)
    with pytest.raises(ValueError):
        pqs_dot(x, w, policy="bogus")
    with pytest.raises(ValueError):
        pqs_dot(x, w, backend="cuda")
    with pytest.raises(ValueError):
        pqs_dot(x, w, acc_bits=31)
    with pytest.raises(ValueError):
        pqs_dot(x, w, policy="sorted_tiled", k_tile=48)
    with pytest.raises(ValueError):
        pqs_dot(x, jnp.zeros((3, 33), jnp.int8))


def test_default_backend_is_platform_appropriate():
    assert default_backend() in ("jnp", "pallas")


def test_integer_lin_context_and_qtensor_dot(rng):
    """The serving path: QTensor projections as integer PQS dots."""
    from repro.models.layers import lin

    w = jnp.asarray(rng.normal(size=(64, 24)), jnp.float32) * 0.1
    x = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    qt = quantize_weight(w, bits=8)
    dequant = np.asarray(x @ qt.dequant(jnp.float32))

    cfg = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                           k_tile=64, backend="jnp")
    direct = np.asarray(qtensor_dot(x, qt, cfg))
    # wide-enough accumulator: integer path tracks the dequant matmul to
    # activation-quantization error
    assert np.abs(direct - dequant).max() < 0.1 * np.abs(dequant).max() + 0.05

    assert np.allclose(np.asarray(lin(x, qt)), dequant)  # default: dequant
    with integer_lin(cfg):
        inside = np.asarray(lin(x, qt))
    np.testing.assert_array_equal(inside, direct)
    assert np.allclose(np.asarray(lin(x, qt)), dequant)  # context restored
