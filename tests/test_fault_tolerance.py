"""Fault-tolerance runtime: supervisors, stragglers, remesh, async ckpt.

Single-device-safe throughout; the elastic-remesh resume test needs >= 4
devices and self-skips otherwise (scripts/ci.sh's ``fault`` stage runs
this file under REPRO_FORCE_MULTIDEVICE=8, where it is live).
"""

import os

# same opt-in idiom as test_sharded_dispatch.py: only effective before
# the first jax backend init, never leaks into the single-device suite
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    _v = os.environ["REPRO_FORCE_MULTIDEVICE"]
    _n = int(_v) if _v.isdigit() and int(_v) > 1 else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.checkpoint import (  # noqa: E402
    AsyncCheckpointer,
    load_checkpoint,
    save_checkpoint,
    unflatten_like,
)
from repro.data import TokenStream  # noqa: E402
from repro.runtime import (  # noqa: E402
    FailureInjector,
    StragglerMonitor,
    TrainSupervisor,
    default_retryable,
    elastic_remesh,
)


# --- retryable-exception policy ---------------------------------------------


def test_default_retryable_covers_device_loss():
    types = default_retryable()
    assert RuntimeError in types
    # device loss surfaces as jaxlib's XlaRuntimeError — must be listed
    # explicitly, not assumed to stay a RuntimeError subclass forever
    from jaxlib.xla_extension import XlaRuntimeError

    assert any(issubclass(XlaRuntimeError, t) for t in types)


def test_supervisor_retryable_is_configurable():
    class Flaky(Exception):
        pass

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            raise Flaky("transient")
        return {"x": state["x"] + batch}, {}

    # not in the retryable set -> propagates immediately
    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(d, step_fn, ckpt_every=2)
        with pytest.raises(Flaky):
            sup.run({"x": jnp.asarray(0.0)}, lambda: jnp.asarray(1.0), 6)

    # listed -> recovered like any node failure
    calls["n"] = 0
    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(
            d, step_fn, ckpt_every=2, retryable=(RuntimeError, Flaky)
        )
        state, step = sup.run(
            {"x": jnp.asarray(0.0)}, lambda: jnp.asarray(1.0), 6
        )
        assert step == 6 and float(state["x"]) == 6.0 and sup.restarts == 1


def test_supervisor_restart_budget_resets_after_clean_steps():
    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    # three sporadic failures, each separated by >= 3 clean steps: a
    # max_restarts=1 budget only survives if it refills between them
    def run(reset_after):
        with tempfile.TemporaryDirectory() as d:
            inj = FailureInjector({2, 7, 12})
            sup = TrainSupervisor(
                d, step_fn, ckpt_every=1, failure_injector=inj,
                max_restarts=1, reset_after=3,
            ) if reset_after else TrainSupervisor(
                d, step_fn, ckpt_every=1, failure_injector=inj,
                max_restarts=1,
            )
            return sup.run(
                {"x": jnp.asarray(0.0)}, lambda: jnp.asarray(1.0), 16
            )

    state, step = run(reset_after=True)
    assert step == 16 and float(state["x"]) == 16.0
    with pytest.raises(RuntimeError):
        run(reset_after=False)


# --- resume semantics --------------------------------------------------------


def _consume_stream(num_steps, fail_at, ckpt_every, max_restarts=3):
    """Drive a supervisor over a TokenStream, recording every batch the
    step function actually *applied* to the state. The state accumulates
    a checksum, so replayed-but-discarded work cannot hide."""
    data = TokenStream(vocab_size=50, seq_len=4, batch_size=2, seed=7)
    applied = []

    def step_fn(state, batch):
        tok = int(batch["tokens"][0, 0])
        applied.append(tok)
        return {"sum": state["sum"] + jnp.asarray(float(tok))}, {}

    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(
            d, step_fn, ckpt_every=ckpt_every, max_restarts=max_restarts,
            failure_injector=FailureInjector(fail_at),
        )
        state, step = sup.run(
            {"sum": jnp.asarray(0.0)}, data.next_batch, num_steps, data=data
        )
    return float(state["sum"]), step, applied


def test_supervisor_resume_replays_no_batch_twice():
    clean_sum, _, clean_applied = _consume_stream(10, set(), ckpt_every=2)
    # unique batches in the clean run (sanity on the fixture itself)
    assert len(clean_applied) == 10

    faulty_sum, step, _ = _consume_stream(10, {3, 7}, ckpt_every=2)
    # every batch contributes exactly once to the final state: failures
    # rewind both the params AND the data stream to the checkpoint
    assert step == 10
    assert faulty_sum == clean_sum


def test_supervisor_scratch_restart_rewinds_state_and_data():
    # no checkpoint exists when the failure hits (ckpt_every huge):
    # restart-from-scratch must rewind to the ENTRY state and data
    # position, not keep the mid-failure state or a advanced stream
    clean_sum, _, _ = _consume_stream(6, set(), ckpt_every=100)
    faulty_sum, step, _ = _consume_stream(6, {3}, ckpt_every=100)
    assert step == 6
    assert faulty_sum == clean_sum


# --- straggler monitor -------------------------------------------------------


def test_straggler_deadline_tracks_rolling_median():
    mon = StragglerMonitor(k=2.0, window=4)
    for step in range(6):
        rep = mon.observe(step, {0: 0.10, 1: 0.10, 2: 0.10})
    assert rep.deadline == pytest.approx(0.20)
    assert rep.stragglers == []
    # a slow host is flagged against the fleet's deadline...
    rep = mon.observe(6, {0: 0.10, 1: 0.25, 2: 0.10})
    assert rep.stragglers == [1]
    # ...and a fleet-wide slowdown raises the deadline instead of
    # flagging everyone: after the window fills with slow steps the
    # same times stop being straggler-worthy
    for step in range(7, 12):
        rep = mon.observe(step, {0: 0.30, 1: 0.31, 2: 0.29})
    assert rep.deadline == pytest.approx(0.60)
    assert rep.stragglers == []


# --- async checkpointer error surfacing -------------------------------------


def test_async_checkpointer_surfaces_write_error_on_next_wait():
    with tempfile.TemporaryDirectory() as d:
        # point the checkpointer at a path occupied by a FILE: the
        # background mkdir/rename fails, and the failure must surface on
        # the next wait() instead of vanishing with the thread
        blocked = os.path.join(d, "ckpts")
        with open(blocked, "w") as f:
            f.write("not a directory")
        ck = AsyncCheckpointer(blocked)
        ck.save(1, {"x": np.ones(3)})
        with pytest.raises(OSError):
            ck.wait()
        # the error is consumed — the checkpointer is reusable after
        os.unlink(blocked)
        ck.save(2, {"x": np.ones(3)})
        ck.wait()
        flat, step = load_checkpoint(blocked)
        assert step == 2 and flat["['x']"].shape == (3,)


def test_load_checkpoint_target_free_roundtrip():
    tree = {
        "a": np.arange(6, dtype=np.int8).reshape(2, 3),
        "blob": np.frombuffer(b"variable-length", np.uint8),
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree)
        flat, step = load_checkpoint(d)
        assert step == 3
        # unflatten_like rebuilds the structure even when the template's
        # leaf SHAPES differ (the variable-length-blob use case)
        template = {"a": np.zeros((2, 3), np.int8), "blob": np.zeros(0, np.uint8)}
        out = unflatten_like(template, flat)
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["blob"].tobytes() == b"variable-length"
        with pytest.raises(KeyError):
            unflatten_like({"missing": np.zeros(1)}, flat)


# --- elastic remesh: reshard and RESUME -------------------------------------


@pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >= 4 devices (fault CI stage)"
)
def test_elastic_remesh_reshard_and_resume():
    """Lose half the fleet mid-run; training resumes on the survivors
    with bit-identical math (the step is a pure elementwise update)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def make_mesh(n):
        return jax.sharding.Mesh(np.asarray(jax.devices()[:n]), ("data",))

    def rule(mesh):
        return {
            "w": NamedSharding(mesh, P("data")),
            "step": NamedSharding(mesh, P()),
        }

    @jax.jit
    def train_step(state):
        return {
            "w": state["w"] * 1.5 + 1.0,
            "step": state["step"] + 1,
        }

    def run(n_devices, switch_at=None, switch_to=None):
        state = {
            "w": jnp.arange(8, dtype=jnp.float32),
            "step": jnp.asarray(0),
        }
        state = jax.device_put(state, rule(make_mesh(n_devices)))
        for i in range(6):
            if switch_at is not None and i == switch_at:
                state, _mesh = elastic_remesh(
                    state, make_mesh, switch_to, rule
                )
            state = train_step(state)
        return np.asarray(state["w"]), int(state["step"])

    w_ref, s_ref = run(4)
    w_el, s_el = run(4, switch_at=3, switch_to=2)
    assert s_ref == s_el == 6
    np.testing.assert_array_equal(w_ref, w_el)
