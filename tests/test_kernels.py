"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.pruning import nm_prune_mask
from repro.kernels import ops, ref
from repro.kernels.bitonic import (
    bitonic_sort,
    pairwise_round_bitonic,
    sorted_order_bitonic,
)
from repro.core.sorted_accum import pairwise_round, sorted_order


@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_bitonic_matches_sort(n, rng):
    x = jnp.asarray(rng.integers(-(2**28), 2**28, (6, n)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x)), np.sort(np.asarray(x), -1)
    )
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x, ascending=False)),
        np.sort(np.asarray(x), -1)[..., ::-1],
    )


def test_bitonic_with_duplicates():
    x = jnp.asarray([[3, 3, 1, 1, 2, 2, 0, 0]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x))[0], [0, 0, 1, 1, 2, 2, 3, 3]
    )


def test_bitonic_rejects_non_pow2():
    with pytest.raises(ValueError):
        bitonic_sort(jnp.zeros((2, 12), jnp.int32))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_pairwise_bitonic_equals_core(seed):
    r = np.random.default_rng(seed)
    p = jnp.asarray(r.integers(-(2**20), 2**20, (3, 64)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(pairwise_round(p)), np.asarray(pairwise_round_bitonic(p))
    )
    np.testing.assert_array_equal(
        np.asarray(sorted_order(p, 2)), np.asarray(sorted_order_bitonic(p, 2))
    )


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [(16, 64, 16, 8, 8, 32), (32, 128, 24, 16, 8, 64), (7, 50, 9, 8, 8, 32)],
)
def test_quant_matmul_sweep(m, k, n, bm, bn, bk, rng):
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    out = ops.quant_matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.quant_matmul_ref(x, w))
    )


@pytest.mark.parametrize("acc_bits", [12, 16, 20])
@pytest.mark.parametrize("rounds", [1, 2])
def test_sorted_matmul_sweep(acc_bits, rounds, rng):
    x = jnp.asarray(rng.integers(0, 127, (8, 64)), jnp.int8)  # post-ReLU
    w = jnp.asarray(rng.integers(-127, 127, (12, 64)), jnp.int8)
    out = ops.sorted_matmul(
        x, w, acc_bits=acc_bits, rounds=rounds, bm=4, bn=4, bk=32
    )
    expect = ref.sorted_matmul_ref(
        x, w, acc_bits=acc_bits, rounds=rounds, k_tile=32
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_sorted_matmul_ragged_padding(rng):
    """Zero padding must be inert through sort + saturation."""
    x = jnp.asarray(rng.integers(-50, 50, (5, 48)), jnp.int8)
    w = jnp.asarray(rng.integers(-50, 50, (6, 48)), jnp.int8)
    out = ops.sorted_matmul(x, w, acc_bits=18, bm=4, bn=4, bk=16)
    expect = ref.sorted_matmul_ref(x, w, acc_bits=18, rounds=1, k_tile=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_clip_matmul_matches_ref(rng):
    x = jnp.asarray(rng.integers(0, 127, (6, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (10, 64)), jnp.int8)
    out = ops.clip_matmul(x, w, acc_bits=14, bm=2, bn=2, bk=32)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.clip_matmul_ref(x, w, acc_bits=14))
    )


def test_sorted_resolves_transients_where_clip_fails(rng):
    """End-to-end kernel-level PQS claim: with a narrow accumulator the
    sorted kernel recovers the exact (wide) result on dot products whose
    natural order transiently overflows."""
    x = jnp.asarray(rng.integers(0, 127, (16, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (32, 128)), jnp.int8)
    wide = np.asarray(ref.quant_matmul_ref(x, jnp.asarray(np.asarray(w).T)))
    bits = 18
    qmin, qmax = -(2**17), 2**17 - 1
    fits = (wide >= qmin) & (wide <= qmax)
    srt = np.asarray(ops.sorted_matmul(x, w, acc_bits=bits, bm=8, bn=8, bk=128))
    clp = np.asarray(ops.clip_matmul(x, w, acc_bits=bits, bm=8, bn=8, bk=128))
    exact_sorted = (srt == wide)[fits].mean()
    exact_clip = (clp == wide)[fits].mean()
    assert exact_sorted >= exact_clip
    assert exact_sorted > 0.999  # sorting eliminates ~all transients


@pytest.mark.parametrize("n_keep,m_group", [(4, 16), (8, 16), (2, 8)])
def test_nm_spmm_sweep(n_keep, m_group, rng):
    n, k = 16, 128
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, m_group))
    wd = (wd * mask).astype(np.int8)
    vals, idx = ops.compress_nm_weights(wd, n_keep, m_group)
    x = jnp.asarray(rng.integers(-127, 127, (12, k)), jnp.int8)
    out = ops.nm_spmm(x, vals, idx, m_group=m_group, bm=4, bn=8, bg=2)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref.quant_matmul_ref(x, jnp.asarray(wd.T))),
    )


def test_nm_spmm_bandwidth_model():
    """The compressed form streams n_keep/m of the dense weight bytes —
    the decode-bandwidth saving in DESIGN.md §2 (plus small index cost)."""
    n, k, n_keep, m = 128, 1024, 4, 16
    dense_bytes = n * k  # int8
    vals_bytes = n * (k // m) * n_keep
    idx_bytes = n * (k // m) * n_keep  # int8-packable positions (< m = 16)
    assert vals_bytes == dense_bytes * n_keep / m
    assert (vals_bytes + idx_bytes) <= dense_bytes / 2
