"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.pruning import nm_prune_mask
from repro.kernels import ops, ref
from repro.kernels.bitonic import (
    bitonic_sort,
    pairwise_round_bitonic,
    sorted_order_bitonic,
)
from repro.core.sorted_accum import pairwise_round, sorted_order


@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_bitonic_matches_sort(n, rng):
    x = jnp.asarray(rng.integers(-(2**28), 2**28, (6, n)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x)), np.sort(np.asarray(x), -1)
    )
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x, ascending=False)),
        np.sort(np.asarray(x), -1)[..., ::-1],
    )


def test_bitonic_with_duplicates():
    x = jnp.asarray([[3, 3, 1, 1, 2, 2, 0, 0]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bitonic_sort(x))[0], [0, 0, 1, 1, 2, 2, 3, 3]
    )


def test_bitonic_rejects_non_pow2():
    with pytest.raises(ValueError):
        bitonic_sort(jnp.zeros((2, 12), jnp.int32))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_pairwise_bitonic_equals_core(seed):
    r = np.random.default_rng(seed)
    p = jnp.asarray(r.integers(-(2**20), 2**20, (3, 64)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(pairwise_round(p)), np.asarray(pairwise_round_bitonic(p))
    )
    np.testing.assert_array_equal(
        np.asarray(sorted_order(p, 2)), np.asarray(sorted_order_bitonic(p, 2))
    )


def test_next_pow2():
    """next_pow2(1) must be 1 — a K=1 dot is already bitonic-sortable;
    padding it to 2 over-padded every K=1 `sorted` dot."""
    assert ops.next_pow2(1) == 1
    assert ops.next_pow2(2) == 2
    assert ops.next_pow2(3) == 4
    assert ops.next_pow2(4) == 4
    assert ops.next_pow2(4097) == 8192
    for n in range(1, 300):
        p = ops.next_pow2(n)
        assert p >= n and p & (p - 1) == 0 and (p == 1 or p // 2 < n), n


def test_padded_k():
    # sorted: one bitonic stage over the whole axis -> power of two
    assert ops.padded_k(1, "sorted", 256) == 1
    assert ops.padded_k(300, "sorted", 256) == 512
    assert ops.padded_k(4096, "sorted", 256) == 4096
    # tiled policies: whole number of k_tile tiles
    assert ops.padded_k(300, "sorted_tiled", 256) == 512
    assert ops.padded_k(300, "sorted_tiled_seq", 64) == 320
    assert ops.padded_k(256, "sorted_tiled", 256) == 256
    # unsorted policies: no K padding at all
    for policy in ("wide", "clip", "wrap"):
        assert ops.padded_k(300, policy, 256) == 300


def test_pad_to(rng):
    x = jnp.asarray(rng.integers(-5, 5, (5, 6)), jnp.int32)
    same = ops._pad_to(x, 3, 1)
    assert same is x  # already a multiple: no copy
    p0 = ops._pad_to(x, 4, 0)
    assert p0.shape == (8, 6)
    np.testing.assert_array_equal(np.asarray(p0[:5]), np.asarray(x))
    assert int(jnp.abs(p0[5:]).sum()) == 0
    p1 = ops._pad_to(x, 4, 1)
    assert p1.shape == (5, 8) and int(jnp.abs(p1[:, 6:]).sum()) == 0


def test_env_blocks_forms(monkeypatch):
    monkeypatch.delenv("REPRO_PQS_BLOCKS", raising=False)
    assert ops.env_blocks("clip") is None
    monkeypatch.setenv("REPRO_PQS_BLOCKS", "16,64")
    assert ops.env_blocks("clip") == (16, 64)
    assert ops.env_blocks("wide") == (16, 64)  # bare form: every policy
    monkeypatch.setenv("REPRO_PQS_BLOCKS", "sorted:8,128;wide:128,128")
    assert ops.env_blocks("sorted") == (8, 128)
    assert ops.env_blocks("wide") == (128, 128)
    assert ops.env_blocks("clip") is None  # no entry -> fall through
    # mixed: bare entry is the default for policies without their own
    monkeypatch.setenv("REPRO_PQS_BLOCKS", "16,64;sorted:8,128")
    assert ops.env_blocks("sorted") == (8, 128)
    assert ops.env_blocks("clip") == (16, 64)
    assert ops.default_blocks("clip") == (16, 64)  # flows into defaults


@pytest.mark.parametrize("bad", ["8", "8,x", "1,2,3", "bogus:1,2",
                                 "sorted:1", "sorted=8,128"])
def test_env_blocks_malformed(monkeypatch, bad):
    monkeypatch.setenv("REPRO_PQS_BLOCKS", bad)
    with pytest.raises(ValueError, match="REPRO_PQS_BLOCKS"):
        ops.env_blocks("clip")


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [(16, 64, 16, 8, 8, 32), (32, 128, 24, 16, 8, 64), (7, 50, 9, 8, 8, 32)],
)
def test_quant_matmul_sweep(m, k, n, bm, bn, bk, rng):
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (k, n)), jnp.int8)
    out = ops.quant_matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.quant_matmul_ref(x, w))
    )


@pytest.mark.parametrize("acc_bits", [12, 16, 20])
@pytest.mark.parametrize("rounds", [1, 2])
def test_sorted_matmul_sweep(acc_bits, rounds, rng):
    x = jnp.asarray(rng.integers(0, 127, (8, 64)), jnp.int8)  # post-ReLU
    w = jnp.asarray(rng.integers(-127, 127, (12, 64)), jnp.int8)
    out = ops.sorted_matmul(
        x, w, acc_bits=acc_bits, rounds=rounds, bm=4, bn=4, bk=32
    )
    expect = ref.sorted_matmul_ref(
        x, w, acc_bits=acc_bits, rounds=rounds, k_tile=32
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_sorted_matmul_ragged_padding(rng):
    """Zero padding must be inert through sort + saturation."""
    x = jnp.asarray(rng.integers(-50, 50, (5, 48)), jnp.int8)
    w = jnp.asarray(rng.integers(-50, 50, (6, 48)), jnp.int8)
    out = ops.sorted_matmul(x, w, acc_bits=18, bm=4, bn=4, bk=16)
    expect = ref.sorted_matmul_ref(x, w, acc_bits=18, rounds=1, k_tile=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_clip_matmul_matches_ref(rng):
    x = jnp.asarray(rng.integers(0, 127, (6, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (10, 64)), jnp.int8)
    out = ops.clip_matmul(x, w, acc_bits=14, bm=2, bn=2, bk=32)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.clip_matmul_ref(x, w, acc_bits=14))
    )


def test_sorted_resolves_transients_where_clip_fails(rng):
    """End-to-end kernel-level PQS claim: with a narrow accumulator the
    sorted kernel recovers the exact (wide) result on dot products whose
    natural order transiently overflows."""
    x = jnp.asarray(rng.integers(0, 127, (16, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (32, 128)), jnp.int8)
    wide = np.asarray(ref.quant_matmul_ref(x, jnp.asarray(np.asarray(w).T)))
    bits = 18
    qmin, qmax = -(2**17), 2**17 - 1
    fits = (wide >= qmin) & (wide <= qmax)
    srt = np.asarray(ops.sorted_matmul(x, w, acc_bits=bits, bm=8, bn=8, bk=128))
    clp = np.asarray(ops.clip_matmul(x, w, acc_bits=bits, bm=8, bn=8, bk=128))
    exact_sorted = (srt == wide)[fits].mean()
    exact_clip = (clp == wide)[fits].mean()
    assert exact_sorted >= exact_clip
    assert exact_sorted > 0.999  # sorting eliminates ~all transients


@pytest.mark.parametrize("n_keep,m_group", [(4, 16), (8, 16), (2, 8)])
def test_nm_spmm_sweep(n_keep, m_group, rng):
    n, k = 16, 128
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, m_group))
    wd = (wd * mask).astype(np.int8)
    vals, idx = ops.compress_nm_weights(wd, n_keep, m_group)
    x = jnp.asarray(rng.integers(-127, 127, (12, k)), jnp.int8)
    out = ops.nm_spmm(x, vals, idx, m_group=m_group, bm=4, bn=8, bg=2)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref.quant_matmul_ref(x, jnp.asarray(wd.T))),
    )


def test_nm_spmm_bandwidth_model():
    """The compressed form streams n_keep/m of the dense weight bytes —
    the decode-bandwidth saving in DESIGN.md §2 (plus small index cost)."""
    n, k, n_keep, m = 128, 1024, 4, 16
    dense_bytes = n * k  # int8
    vals_bytes = n * (k // m) * n_keep
    idx_bytes = n * (k // m) * n_keep  # int8-packable positions (< m = 16)
    assert vals_bytes == dense_bytes * n_keep / m
    assert (vals_bytes + idx_bytes) <= dense_bytes / 2
