"""Launcher tests: specs, census parsing, link model, sharding modes,
hints, and a real (subprocess) dry-run integration check."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.launch.hlo_census import (
    _group_size,
    _link_bytes,
    collective_census,
    parse_computations,
)
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    make_train_step,
    params_specs,
)
from repro.models.model import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_cells_for_skip_policy():
    assert "long_500k" in cells_for("mamba2-2.7b")
    assert "long_500k" in cells_for("gemma3-12b")
    assert "long_500k" not in cells_for("qwen3-32b")
    total = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert total == 33  # 40 assigned minus 7 documented skips


@pytest.mark.parametrize("arch", ["qwen2-vl-72b", "whisper-medium",
                                  "jamba-v0.1-52b", "qwen3-32b"])
def test_batch_specs_shapes(arch):
    cfg = get_config(arch)
    sp = batch_specs(cfg, SHAPES["train_4k"])
    b, s = 256, 4096
    if cfg.family == "vlm":
        assert sp["embeddings"].shape == (b, s, cfg.d_model)
        assert sp["positions"].shape == (3, b, s)
    elif cfg.is_encoder_decoder:
        assert sp["frames"].shape == (b, s, cfg.d_model)
        assert sp["tokens"].shape == (b, s)
    else:
        assert sp["tokens"].shape == (b, s)
    assert sp["labels"].shape == (b, s)


def test_cache_specs_no_allocation():
    cfg = get_config("qwen3-32b")
    model = build_model(cfg)
    cs = cache_specs(model, SHAPES["decode_32k"])
    leaves = jax.tree_util.tree_leaves(cs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    k = cs["k"]
    assert k.shape == (cfg.num_layers, 128, 32768, cfg.num_kv_heads,
                       cfg.resolved_head_dim)


_FAKE_HLO = """
HloModule test

%cond.1 (arg.1: (s32[], f32[64])) -> pred[] {
  %arg.1 = (s32[], f32[64]) parameter(0)
  %gte = s32[] get-tuple-element(%arg.1), index=0
  %c28 = s32[] constant(28)
  ROOT %cmp = pred[] compare(%gte, %c28), direction=LT
}

%body.2 (arg.2: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg.2 = (s32[], f32[64]) parameter(0)
  %gte2 = f32[64]{0} get-tuple-element(%arg.2), index=1
  %ag = f32[1024]{0} all-gather(%gte2), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %red = f32[64]{0} bitcast(%ag)
  %ar = f32[64]{0} all-reduce(%red), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%cond.1
  %i = s32[] get-tuple-element(%arg.2), index=0
  ROOT %tup = (s32[], f32[64]) tuple(%i, %ar)
}

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ar0 = f32[64]{0} all-reduce(%p0), channel_id=3, replica_groups=[16,16]<=[256], to_apply=%cond.1
  %init = (s32[], f32[64]) tuple(%p0, %ar0)
  %wh = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.2
  ROOT %out = f32[64]{0} get-tuple-element(%wh), index=1
}
"""


def test_census_trip_count_weighting():
    c = collective_census(_FAKE_HLO)
    # all-gather + all-reduce inside the 28-trip loop, one AR outside
    assert c["counts"]["all-gather"] == 1
    assert c["counts"]["all-reduce"] == 2
    assert c["weighted_counts"]["all-gather"] == 28
    assert c["weighted_counts"]["all-reduce"] == 28 + 1
    # operand bytes: 64 f32 = 256 B; AG weighted 28x
    assert c["bytes_per_device"]["all-gather"] == 28 * 256
    assert c["bytes_per_device"]["all-reduce"] == 29 * 256


def test_census_parses_computations():
    comps = parse_computations(_FAKE_HLO)
    assert any(c["is_entry"] for c in comps.values())
    ent = [c for c in comps.values() if c["is_entry"]][0]
    assert ent["whiles"] == [("cond.1", "body.2")]


def test_link_model():
    assert _link_bytes("all-gather", 100, 16) == 1500  # shard x (g-1)
    assert _link_bytes("all-reduce", 100, 16) == pytest.approx(187.5)
    assert _link_bytes("reduce-scatter", 100, 16) == pytest.approx(93.75)
    assert _link_bytes("collective-permute", 100, 2) == 100
    assert _link_bytes("all-reduce", 100, 1) == 0
    assert _group_size("all-reduce(%x), replica_groups=[32,8]<=[256]") == 8


def test_param_spec_serve_mode():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import param_spec

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    spec = param_spec(mesh, "layers/attn/wq", (64, 5120, 8192),
                      serve_mode=True)
    assert spec == P(None, None, "model")  # no FSDP axes at decode


def test_shard_hint_noop_without_mesh():
    from repro.models.hints import hint_batch, shard_hint

    x = jnp.ones((4, 8))
    assert shard_hint(x, "data") is x or (shard_hint(x, "data") == x).all()
    assert (hint_batch(jnp.ones((2, 3, 4))) == 1).all()


def test_train_steps_lower_on_host_mesh():
    """train/serve steps lower under the degenerate host mesh (the same
    code path production uses, minus fake devices)."""
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    mesh = make_host_mesh()
    step = make_train_step(model)
    p = params_specs(model)
    from repro.launch.specs import make_opt_specs

    o = make_opt_specs(model)
    b = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    with mesh:
        lowered = jax.jit(step).lower(p, o, b)
        assert lowered.cost_analysis().get("flops", 0) > 0


@pytest.mark.slow
def test_dryrun_subprocess_smallest_cell():
    """End-to-end integration: the real dry-run binary on the cheapest
    cell (mamba2 long_500k: B=1, compiles in seconds)."""
    out = "/tmp/test_dryrun_cell.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-2.7b", "--shape", "long_500k", "--mesh", "single",
         "--no-probe", "--out", out],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.load(open(out))
    assert len(data["results"]) == 1 and not data["failures"]
    cell = data["results"][0]
    assert cell["memory"]["peak_bytes"] < 16e9  # fits v5e HBM
