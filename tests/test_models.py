"""Per-architecture smoke tests + cross-path consistency checks.

Every assigned arch instantiates its reduced config, runs one forward +
train-grad step, and decodes — asserting shapes and no NaNs. Consistency:
chunked SSD == stepwise recurrence; forward logits == decode logits;
dispatch MoE == dense MoE oracle when nothing is capacity-dropped.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import encdec
from repro.models.model import build_model, param_count, active_param_count
from repro.models.moe import moe_ffn, moe_ffn_dense, moe_init
from repro.models.ssm import (
    empty_ssm_cache,
    mamba_forward,
    mamba_init,
    mamba_step,
)

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.family == "vlm":
        return {
            "embeddings": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)), jnp.float32
            ),
            "positions": jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, b, s)
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
        }
    if cfg.is_encoder_decoder:
        return {
            "frames": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)), jnp.float32
            ),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    logits = model.forward(params, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    b, max_len = 2, 16
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(
            params, jnp.zeros((b, 8, cfg.d_model), jnp.float32), cfg
        )
        caches = model.init_caches(params, b, max_len, jnp.float32,
                                   enc_out=enc_out)
    else:
        caches = model.init_caches(params, b, max_len, jnp.float32)
    tok = (
        jnp.zeros((b, 1, cfg.d_model), jnp.float32)
        if cfg.family == "vlm"
        else jnp.zeros((b, 1), jnp.int32)
    )
    for _ in range(3):
        logits, caches = model.decode(params, tok, caches)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-12b", "qwen3-32b",
                                  "mamba2-2.7b"])
def test_forward_decode_consistency(arch):
    """Teacher-forced forward logits == step-by-step decode logits."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    T = 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    caches = model.init_caches(params, 1, T, jnp.float32)
    outs = []
    for t in range(T):
        lg, caches = model.decode(params, toks[:, t : t + 1], caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    diff = float(
        jnp.max(jnp.abs(full.astype(jnp.float32) - dec.astype(jnp.float32)))
    )
    assert diff < 0.2, f"{arch}: fwd-vs-decode max diff {diff}"  # bf16 tol


def test_ssd_chunked_equals_stepwise():
    cfg = get_config("mamba2-2.7b", smoke=True)
    p = mamba_init(KEY, cfg)
    B, L = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(2), (B, L, cfg.d_model)) * 0.5
    y_full, state_full = mamba_forward(p, x, cfg)
    cache = empty_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        yt, cache = mamba_step(p, x[:, t : t + 1], cache, cfg)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    rel = float(jnp.max(jnp.abs(y_full - y_seq))) / float(
        jnp.max(jnp.abs(y_seq))
    )
    assert rel < 1e-4
    np.testing.assert_allclose(
        np.asarray(state_full), np.asarray(cache["ssd"]), rtol=1e-3,
        atol=1e-5,
    )


def test_moe_dispatch_matches_dense_oracle():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    mcfg = cfg.moe
    # generous capacity so nothing drops -> dispatch == dense
    import dataclasses

    mcfg = dataclasses.replace(mcfg, capacity_factor=8.0)
    p = moe_init(KEY, cfg, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out_d, aux_d = moe_ffn(p, x, cfg, mcfg)
    out_ref, aux_ref = moe_ffn_dense(p, x, cfg, mcfg)
    np.testing.assert_allclose(
        np.asarray(out_d), np.asarray(out_ref), rtol=2e-2, atol=2e-3
    )
    assert float(aux_d) == pytest.approx(float(aux_ref), rel=1e-5)


def test_moe_capacity_drops_are_bounded():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    mcfg = cfg.moe  # capacity_factor 1.25
    p = moe_init(KEY, cfg, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, _ = moe_ffn(p, x, cfg, mcfg)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())


def test_param_counts_full_configs():
    """Full (non-smoke) configs must hit published parameter scales."""
    expected = {
        "qwen2-1.5b": (1.3e9, 2.2e9),
        "qwen3-32b": (30e9, 36e9),
        "command-r-35b": (28e9, 39e9),  # assigned dims give 30.3B
        "gemma3-12b": (10e9, 14e9),
        "mamba2-2.7b": (2.4e9, 3.1e9),
        "jamba-v0.1-52b": (49e9, 56e9),
        "qwen2-vl-72b": (68e9, 76e9),
        "granite-moe-3b-a800m": (2.8e9, 3.8e9),
        "granite-moe-1b-a400m": (1.1e9, 1.6e9),
        "whisper-medium": (0.6e9, 0.9e9),  # medium is 769M
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, KEY)
        n = param_count(shapes)
        assert lo <= n <= hi, f"{arch}: {n:,} params outside [{lo:,},{hi:,}]"


def test_active_params_moe():
    cfg = get_config("granite-moe-3b-a800m")
    model = build_model(cfg)
    total = param_count(jax.eval_shape(model.init, KEY))
    active = active_param_count(cfg, total)
    assert active < total
    assert 0.6e9 <= active <= 1.2e9  # ~800M active


def test_moe_onehot_dispatch_matches_scatter():
    """The local-groups einsum dispatch (used under sharded vmap, §Perf
    iteration 5) must match the scatter dispatch bit-for-bit-ish."""
    import dataclasses

    from repro.models.moe import _moe_ffn_grouped, _moe_ffn_onehot

    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    mcfg = dataclasses.replace(cfg.moe, capacity_factor=2.0)
    p = moe_init(KEY, cfg, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    a, aux_a = _moe_ffn_grouped(p, x, cfg, mcfg)
    b, aux_b = _moe_ffn_onehot(p, x, cfg, mcfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
    assert float(aux_a) == pytest.approx(float(aux_b), rel=1e-6)


def test_quantize_tree_skips_stacked_biases():
    """Regression (§Perf iteration 6): stacked (L, out) biases must not be
    quantized — a per-column scale would lose the layer axis and break
    the decode scan."""
    from repro.core.qtensor import QTensor, quantize_tree

    tree = {"stacked_bias": jnp.ones((64, 5120)),
            "w": jnp.ones((64, 5120, 512))}
    out = quantize_tree(tree, min_size=1 << 10)
    assert not isinstance(out["stacked_bias"], QTensor)
    assert isinstance(out["w"], QTensor)
    assert out["w"].values.shape == (64, 5120, 512)
    assert out["w"].scale.shape == (64, 512)


def test_whisper_forward_decode_consistency():
    """Enc-dec: teacher-forced decoder logits == incremental decode."""
    cfg = get_config("whisper-medium", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, T = 1, 12
    frames = jax.random.normal(jax.random.PRNGKey(5), (B, 8, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, T), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"frames": frames, "tokens": toks})
    enc_out = encdec.encode(params, frames, cfg)
    caches = model.init_caches(params, B, T, jnp.float32, enc_out=enc_out)
    outs = []
    for t in range(T):
        lg, caches = model.decode(params, toks[:, t: t + 1], caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    diff = float(jnp.max(jnp.abs(full.astype(jnp.float32)
                                 - dec.astype(jnp.float32))))
    assert diff < 0.2, f"whisper fwd-vs-decode diff {diff}"
