"""Policy x sparse-storage composition matrix (``pqs_dot(storage="nm")``).

The contract: every accumulation policy, run directly on N:M-compressed
weights, is BIT-IDENTICAL — census included — to ``nm_decompress``
followed by the dense ``pqs_dot``, on both backends, for every
(n_keep, m) the paper's experiments sweep, at K up to 8192 (the
two-pass streaming kernels), and under a sharded mesh.

The sharded case needs forced host devices (scripts/ci.sh runs this
module inside its multi-device shard next to test_sharded_dispatch.py);
in the single-device suite it self-skips.
"""

import os

# opt-in, and only effective before the first jax backend init (same
# contract as tests/test_sharded_dispatch.py)
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.dispatch import IntegerLinConfig, pqs_dot, qtensor_dot  # noqa: E402
from repro.core.pruning import (  # noqa: E402
    nm_compress,
    nm_decompress,
    nm_prune_mask,
)
from repro.core.qtensor import (  # noqa: E402
    SparseQTensor,
    nm_compress_tree,
    qtensor_nm_compress,
    quantize_weight,
)

POLICIES = ("wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq")
NM_SHAPES = ((2, 4), (4, 8), (4, 16))  # (n_keep, m) — the paper's sweep
CENSUS_FIELDS = ("n_dots", "n_persistent", "n_transient", "n_any")


def _compressed(n, k, n_keep, m, seed=0):
    """(values, indices, dense) with dense = the decompress oracle."""
    rng = np.random.default_rng(seed)
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, m))
    wd = (wd * mask).astype(np.int8)
    vals, idx = nm_compress(wd, n_keep, m)
    dense = nm_decompress(vals, idx, m, k=k)
    np.testing.assert_array_equal(dense, wd)  # compression is lossless
    return (jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32),
            jnp.asarray(dense))


def _x(m_rows, k, seed=0):
    rng = np.random.default_rng(seed + 100)
    return jnp.asarray(rng.integers(-127, 127, (m_rows, k)), jnp.int8)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("n_keep,m", NM_SHAPES)
def test_nm_parity_matrix(policy, n_keep, m):
    """All six policies x all (n_keep, m): compressed == decompressed,
    on the jnp AND pallas backends."""
    M, K, N = 5, 96, 9  # ragged M/N on purpose — padding is dispatch's job
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=n_keep * 31 + m)
    x = _x(M, K, seed=m)
    ref = pqs_dot(x, dense, acc_bits=14, policy=policy, k_tile=32,
                  backend="jnp")
    for backend, kw in (("jnp", {}), ("pallas",
                                      dict(block_m=4, block_n=8))):
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                      policy=policy, k_tile=32, backend=backend, **kw)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref),
            err_msg=f"{policy} {n_keep}:{m} backend={backend}",
        )


@pytest.mark.parametrize("policy", ("clip", "sorted_tiled"))
def test_nm_census_parity(policy):
    """The kept-only census equals the dense census bit for bit: zero
    partial products never change a running sum's range status."""
    n_keep, m = 4, 16
    M, K, N = 6, 128, 10
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=7)
    x = _x(M, K, seed=3)
    _, ref = pqs_dot(x, dense, acc_bits=14, policy=policy, k_tile=32,
                     backend="jnp", with_census=True)
    for backend, kw in (("jnp", {}), ("pallas",
                                      dict(block_m=4, block_n=8))):
        _, out = pqs_dot(x, (vals, idx), storage="nm", m_group=m,
                         acc_bits=14, policy=policy, k_tile=32,
                         backend=backend, with_census=True, **kw)
        for field in CENSUS_FIELDS:
            assert int(getattr(out, field)) == int(getattr(ref, field)), (
                policy,
                backend,
                field,
            )


def test_nm_census_drops_with_sparsity():
    """The paper's pruning payoff, measured: at a fixed accumulator
    width, keeping fewer of every m produces no MORE censused overflow
    events (shorter effective dot products overflow less)."""
    K, N, M = 256, 12, 8
    x = _x(M, K, seed=5)
    prev = None
    for n_keep in (16, 8, 4, 2):
        vals, idx, _ = _compressed(N, K, n_keep, 16, seed=9)
        _, c = pqs_dot(x, (vals, idx), storage="nm", m_group=16,
                       acc_bits=12, policy="clip", backend="jnp",
                       with_census=True)
        if prev is not None:
            assert int(c.n_any) <= prev
        prev = int(c.n_any)


@pytest.mark.slow
def test_nm_parity_large_k():
    """K = 8192: the two-pass streaming sort kernels (tile sums computed
    from the compressed slabs) and the chunked-cube ``sorted`` path."""
    n_keep, m = 4, 16
    M, K, N = 2, 8192, 4
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=11)
    x = _x(M, K, seed=11)
    for policy in POLICIES:
        ref = pqs_dot(x, dense, acc_bits=16, policy=policy, k_tile=256,
                      backend="jnp")
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=16,
                      policy=policy, k_tile=256, backend="pallas",
                      block_m=2, block_n=4)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=policy)


def test_nm_default_blocks_resolve():
    """No explicit blocks: the ``nm:`` kernel-family entries in the
    block table / env override resolve and the result stays exact."""
    vals, idx, dense = _compressed(6, 64, 2, 8, seed=13)
    x = _x(4, 64, seed=13)
    ref = pqs_dot(x, dense, acc_bits=16, policy="clip", backend="jnp")
    out = pqs_dot(x, (vals, idx), storage="nm", m_group=8, acc_bits=16,
                  policy="clip", backend="pallas")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_nm_ragged_k_through_sparse_qtensor(rng):
    """K not divisible by m: the tail group pads inside the compressed
    form and the logical k_dim drives the x-padding."""
    w = jnp.asarray(rng.normal(size=(50, 24)), jnp.float32) * 0.1
    qt = quantize_weight(w, bits=8)  # unpruned: dense-as-sparse below
    sq = qtensor_nm_compress(qt, 16, 16)  # n_keep == m, K=50 has a tail
    assert sq.k_dim == 50 and sq.values.shape == (24, 4, 16)
    np.testing.assert_array_equal(
        np.asarray(qt.dequant(jnp.float32)),
        np.asarray(sq.dequant(jnp.float32)),
    )
    x = jnp.asarray(rng.normal(size=(3, 50)), jnp.float32)
    cfg = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                           k_tile=64, backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(qtensor_dot(x, qt, cfg)),
        np.asarray(qtensor_dot(x, sq, cfg)),
    )


def test_nm_validation_errors():
    vals, idx, _ = _compressed(4, 32, 2, 8)
    x = _x(2, 32)
    with pytest.raises(ValueError, match="storage"):
        pqs_dot(x, (vals, idx), storage="csr", m_group=8)
    with pytest.raises(ValueError, match="m_group"):
        pqs_dot(x, (vals, idx), storage="nm")  # bare pair needs m_group
    with pytest.raises(ValueError, match="k_tile"):
        pqs_dot(x, (vals, idx), storage="nm", m_group=8,
                policy="sorted_tiled", k_tile=4)  # 4 % 8 != 0
    with pytest.raises(ValueError, match="contraction"):
        pqs_dot(_x(2, 48), (vals, idx), storage="nm", m_group=8)
    with pytest.raises(ValueError, match="SparseQTensor"):
        pqs_dot(x, "bogus", storage="nm", m_group=8)


def test_nm_compress_tree_rejects_bad_args(rng):
    """Argument typos must raise, not silently return a dense tree."""
    from repro.core.qtensor import quantize_tree

    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    tree = quantize_tree({"wq": w}, bits=8, n_keep=4, m=16,
                         min_size=1, min_dim=8)
    with pytest.raises(ValueError, match="n_keep"):
        nm_compress_tree(tree, 17, 16)
    with pytest.raises(ValueError, match="m_group"):
        nm_compress_tree(tree, 4, 0)
    # valid args but a pattern no leaf matches: raise, don't silently
    # return an all-dense tree
    with pytest.raises(ValueError, match="no QTensor leaf"):
        nm_compress_tree(tree, 2, 16)  # tree is 4:16-pruned, not 2:16


def test_nm_integer_serving_engine_end_to_end():
    """A pruned-then-quantized model serves integer decode steps from
    compressed storage, token-identical to the dense-QTensor engine."""
    from repro.configs import get_config
    from repro.core.qtensor import quantize_tree
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, n_keep=4, m=16,
                            min_size=1 << 10, min_dim=16)
    sparams = nm_compress_tree(qparams, 4, 16)
    assert any(
        isinstance(leaf, SparseQTensor)
        for leaf in jax.tree_util.tree_leaves(
            sparams, is_leaf=lambda l: isinstance(l, SparseQTensor))
    )
    il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                          k_tile=64, backend="jnp")

    def run(p):
        rng = np.random.default_rng(1)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(
                        np.int32),
                    max_new_tokens=3)
            for i in range(2)
        ]
        eng = ServingEngine(model, p, num_slots=2, max_len=16, int_lin=il)
        eng.drain(reqs)
        return [r.output for r in reqs]

    assert run(qparams) == run(sparams)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs REPRO_FORCE_MULTIDEVICE (see ci.sh shard)")
@pytest.mark.parametrize("policy", POLICIES)
def test_nm_sharded_bit_identical(policy):
    """Compressed weights shard their N rows over the mesh and stay
    bit-identical to the single-device dense reference."""
    n_keep, m = 4, 16
    M, K, N = 5, 128, 6  # N=6 does not divide the model axis -> degrade
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=17)
    x = _x(M, K, seed=17)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ref = pqs_dot(x, dense, acc_bits=14, policy=policy, k_tile=32,
                  backend="jnp")
    out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                  policy=policy, k_tile=32, backend="jnp", mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                  err_msg=policy)


# ---------------------------------------------------------------------------
# fused activation-gather implementation (nm_impl="gather")
# ---------------------------------------------------------------------------


def _compressed_ragged(n, k, n_keep, m, seed=0):
    """``_compressed`` for K % m != 0: pad for the prune mask, slice
    back, let ``nm_compress`` zero-pad the tail group."""
    rng = np.random.default_rng(seed)
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    kp = k + ((-k) % m)
    wp = np.pad(wd, ((0, 0), (0, kp - k)))
    mask = np.asarray(nm_prune_mask(jnp.asarray(wp, jnp.float32), n_keep, m))
    wd = (wp * mask).astype(np.int8)[:, :k]
    vals, idx = nm_compress(wd, n_keep, m)
    dense = nm_decompress(vals, idx, m, k=k)
    np.testing.assert_array_equal(dense, wd)
    return (jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32),
            jnp.asarray(dense))


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("n_keep,m", NM_SHAPES)
def test_nm_gather_expand_bit_identity(policy, n_keep, m):
    """The fused gather kernels are bit-identical — census included — to
    the expand oracle for every policy x (n_keep, m), at the same
    dense-parity shapes the expand matrix sweeps."""
    M, K, N = 5, 96, 9
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=n_keep * 13 + m)
    x = _x(M, K, seed=m + 1)
    ref, cref = pqs_dot(x, dense, acc_bits=14, policy=policy, k_tile=32,
                        backend="jnp", with_census=True)
    outs = {}
    for impl in ("expand", "gather"):
        out, c = pqs_dot(x, (vals, idx), storage="nm", m_group=m,
                         acc_bits=14, policy=policy, k_tile=32,
                         backend="pallas", block_m=4, block_n=8,
                         nm_impl=impl, with_census=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref),
            err_msg=f"{policy} {n_keep}:{m} impl={impl}",
        )
        for field in CENSUS_FIELDS:
            assert int(getattr(c, field)) == int(getattr(cref, field)), (
                policy, impl, field)
        outs[impl] = np.asarray(out)
    np.testing.assert_array_equal(outs["expand"], outs["gather"])


@pytest.mark.slow
def test_nm_gather_parity_large_k():
    """K = 8192 through the gather twins of the two-pass streaming sort
    kernels and the chunked-cube ``sorted`` path."""
    n_keep, m = 4, 16
    M, K, N = 2, 8192, 4
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=23)
    x = _x(M, K, seed=23)
    for policy in POLICIES:
        ref = pqs_dot(x, dense, acc_bits=16, policy=policy, k_tile=256,
                      backend="jnp")
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=16,
                      policy=policy, k_tile=256, backend="pallas",
                      block_m=2, block_n=4, nm_impl="gather")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=policy)


def test_nm_gather_ragged_tail():
    """K % m != 0: the compress-time zero-pad invariant (no per-call
    tail mask in the gather kernel) keeps ragged K exact."""
    n_keep, m = 4, 16
    M, K, N = 4, 100, 6  # G = 7, tail group covers positions 96..111
    vals, idx, dense = _compressed_ragged(N, K, n_keep, m, seed=29)
    sq = SparseQTensor(values=vals, indices=idx, scale=jnp.ones((N,)),
                       m_group=m, k_dim=K)
    x = _x(M, K, seed=29)
    for policy in ("clip", "sorted_tiled_seq", "sorted"):
        ref = pqs_dot(x, dense, acc_bits=14, policy=policy, k_tile=32,
                      backend="jnp")
        out = pqs_dot(x, sq, storage="nm", acc_bits=14,
                      policy=policy, k_tile=32, backend="pallas",
                      block_m=4, block_n=8, nm_impl="gather")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=policy)


def test_nm_impl_env_knob(monkeypatch):
    """REPRO_PQS_NM_IMPL routes when no explicit nm_impl is passed, and
    malformed values raise loudly."""
    from repro.kernels import ops

    vals, idx, dense = _compressed(6, 128, 2, 8, seed=31)
    x = _x(4, 128, seed=31)
    ref = pqs_dot(x, dense, acc_bits=14, policy="clip", backend="jnp")
    for env in ("expand", "gather"):
        monkeypatch.setenv("REPRO_PQS_NM_IMPL", env)
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=8, acc_bits=14,
                      policy="clip", backend="pallas", block_m=4, block_n=8)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=env)
    monkeypatch.setenv("REPRO_PQS_NM_IMPL", "bogus")
    with pytest.raises(ValueError, match="REPRO_PQS_NM_IMPL"):
        ops.resolve_nm_impl("clip", 16, 2, 8)
    monkeypatch.delenv("REPRO_PQS_NM_IMPL")
    with pytest.raises(ValueError, match="nm_impl"):
        pqs_dot(x, (vals, idx), storage="nm", m_group=8, policy="clip",
                backend="pallas", nm_impl="bogus")
    with pytest.raises(ValueError, match="storage"):
        pqs_dot(x, dense, policy="clip", nm_impl="gather")  # dense w


def test_nm_impl_auto_heuristics():
    """``auto`` picks gather only where it can save work: real sparsity
    (n_keep < m), a policy with skippable work, enough groups."""
    from repro.kernels import ops

    assert ops.resolve_nm_impl("clip", 64, 4, 8) == "gather"
    assert ops.resolve_nm_impl("sorted", 64, 2, 4) == "gather"
    assert ops.resolve_nm_impl("wide", 64, 4, 8) == "expand"  # MXU dot
    assert ops.resolve_nm_impl("clip", 64, 8, 8) == "expand"  # dense-as-nm
    small = ops.GATHER_MIN_G - 1
    assert ops.resolve_nm_impl("clip", small, 4, 8) == "expand"  # tiny G
    # explicit choice always wins over the heuristics
    assert ops.resolve_nm_impl("wide", small, 8, 8, "gather") == "gather"
    assert ops.resolve_nm_impl("clip", 64, 4, 8, "expand") == "expand"


def test_nm_gather_kshard_composition():
    """k_shards > 1 on compressed storage: gather partials compose with
    the hierarchical combine bit-identically to expand partials."""
    n_keep, m = 4, 16
    M, K, N = 4, 512, 6
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=37)
    x = _x(M, K, seed=37)
    for policy in POLICIES:
        ref = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                      policy=policy, k_tile=32, backend="pallas",
                      block_m=4, block_n=8, k_shards=4, nm_impl="expand")
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                      policy=policy, k_tile=32, backend="pallas",
                      block_m=4, block_n=8, k_shards=4, nm_impl="gather")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=policy)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs REPRO_FORCE_MULTIDEVICE (see ci.sh shard)")
def test_nm_gather_sharded_k_axis():
    """mesh + k_axis with gather kernels inside every K shard — the
    REPRO_FORCE_MULTIDEVICE composition case from the issue."""
    n_keep, m = 4, 16
    M, K, N = 4, 512, 6
    vals, idx, dense = _compressed(N, K, n_keep, m, seed=41)
    x = _x(M, K, seed=41)
    mesh = jax.make_mesh((2, 2, 2), ("data", "model", "kdim"))
    for policy in ("clip", "sorted_tiled"):
        ref = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                      policy=policy, k_tile=32, backend="jnp",
                      k_shards=2)
        out = pqs_dot(x, (vals, idx), storage="nm", m_group=m, acc_bits=14,
                      policy=policy, k_tile=32, backend="pallas",
                      block_m=4, block_n=8, mesh=mesh, k_axis="kdim",
                      nm_impl="gather")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=policy)


# ---------------------------------------------------------------------------
# nm_compress canonical-form invariant (ragged-tail fast path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K", (96, 100))  # K % m == 0 and the ragged tail
def test_nm_compress_canonical_both_branches(K):
    """Both branches of the ceil-G packer satisfy the canonical-form
    invariant the gather kernels rely on (no per-call tail mask)."""
    from repro.core.pruning import nm_assert_canonical

    n_keep, m = 4, 8
    vals, idx, dense = _compressed_ragged(6, K, n_keep, m, seed=43)
    vals, idx = np.asarray(vals), np.asarray(idx)
    nm_assert_canonical(vals, idx, m, k=K)
    np.testing.assert_array_equal(nm_decompress(vals, idx, m, k=K),
                                  np.asarray(dense))


def test_nm_assert_canonical_catches_violations():
    from repro.core.pruning import nm_assert_canonical

    vals, idx, _ = _compressed_ragged(4, 100, 4, 8, seed=47)
    vals = np.asarray(vals).copy()
    idx = np.asarray(idx).copy()
    nm_assert_canonical(vals, idx, 8, k=100)
    bad_v, bad_i = vals.copy(), idx.copy()
    bad_v[0, -1, -1], bad_i[0, -1, -1] = 5, 7  # dense pos 103 >= k=100
    with pytest.raises(AssertionError, match="tail positions"):
        nm_assert_canonical(bad_v, bad_i, 8, k=100)
    desc = idx.copy()
    desc[0, 0] = desc[0, 0][::-1]
    with pytest.raises(AssertionError, match="ascend"):
        nm_assert_canonical(vals, desc, 8)
    with pytest.raises(AssertionError, match="out of range"):
        nm_assert_canonical(vals, idx + 8, 8)
    # zero-padded groups (index 0 repeated, value 0) ARE canonical —
    # exactly what ops' G-padding produces
    zv = np.zeros((4, 2, 4), vals.dtype)
    zi = np.zeros((4, 2, 4), idx.dtype)
    nm_assert_canonical(np.concatenate([vals, zv], 1),
                        np.concatenate([idx, zi], 1), 8)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs REPRO_FORCE_MULTIDEVICE (see ci.sh shard)")
def test_nm_sharded_census_counts_once():
    vals, idx, dense = _compressed(10, 200, 4, 8, seed=19)
    x = _x(6, 200, seed=19)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    _, ref = pqs_dot(x, dense, acc_bits=16, policy="clip", backend="jnp",
                     with_census=True)
    _, out = pqs_dot(x, (vals, idx), storage="nm", m_group=8, acc_bits=16,
                     policy="clip", backend="jnp", mesh=mesh,
                     with_census=True)
    for field in CENSUS_FIELDS:
        assert int(getattr(out, field)) == int(getattr(ref, field)), field
