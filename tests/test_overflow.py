"""Tests for the overflow-analysis library (paper §3.1, §5.0.1)."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.overflow import (
    Census,
    accumulate,
    census,
    kshard_accumulate,
    matmul_census,
    partial_products,
    quantized_matmul_sim,
)
from repro.core.quant import qrange


def test_census_classification():
    # persistent: sum 300 > 127; transient: runs to 180 then back to 50;
    # clean: stays inside.
    prods = jnp.asarray(
        [[100, 100, 100], [120, 60, -130], [10, 20, 30]], jnp.int32
    )
    c = census(prods, acc_bits=8)
    assert int(c.n_dots) == 3
    assert int(c.n_persistent) == 1
    assert int(c.n_transient) == 1
    assert int(c.n_any) == 2


def test_transient_not_counted_if_final_overflows():
    # runs beyond range AND final out of range -> persistent only
    prods = jnp.asarray([[120, 120, -10]], jnp.int32)
    c = census(prods, acc_bits=8)
    assert int(c.n_persistent) == 1 and int(c.n_transient) == 0


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-200, 200), min_size=1, max_size=32))
def test_property_census_vs_bruteforce(vals):
    acc_bits = 9
    qmin, qmax = qrange(acc_bits)
    run, any_ovf = 0, False
    for v in vals:
        run += v
        any_ovf |= not (qmin <= run <= qmax)
    persistent = not (qmin <= run <= qmax)
    c = census(jnp.asarray([vals], jnp.int32), acc_bits)
    assert int(c.n_persistent) == int(persistent)
    assert int(c.n_transient) == int(any_ovf and not persistent)


def test_accumulate_policies_agree_when_no_overflow(rng):
    prods = jnp.asarray(rng.integers(-10, 10, (8, 64)), jnp.int32)
    exact = np.asarray(prods.sum(-1))
    for policy in ("wide", "clip", "wrap", "sorted", "sorted_tiled",
                   "sorted_tiled_seq"):
        out = accumulate(prods, 20, policy, k_tile=16)
        np.testing.assert_array_equal(np.asarray(out), exact, err_msg=policy)


def test_sorted_beats_clip_under_transients():
    prods = jnp.asarray([[120, 60, -120]], jnp.int32)
    clip = int(accumulate(prods, 8, "clip")[0])
    srt = int(accumulate(prods, 8, "sorted")[0])
    assert srt == 60 and clip != 60


def test_quantized_matmul_sim_matches_matmul_when_wide(rng):
    wq = jnp.asarray(rng.integers(-127, 127, (24, 96)), jnp.int32)
    xq = jnp.asarray(rng.integers(-127, 127, (10, 96)), jnp.int32)
    out = quantized_matmul_sim(wq, xq, 30, "wide")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(xq @ wq.T)
    )
    # batch chunking must not change results
    out2 = quantized_matmul_sim(wq, xq, 30, "wide", batch_chunk=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_matmul_census_counts_all_dots(rng):
    wq = jnp.asarray(rng.integers(-127, 127, (16, 32)), jnp.int32)
    xq = jnp.asarray(rng.integers(0, 127, (20, 32)), jnp.int32)
    c = matmul_census(wq, xq, acc_bits=12, batch_chunk=7)
    assert int(c.n_dots) == 16 * 20
    assert int(c.n_any) >= int(c.n_transient)


def test_census_monotone_in_acc_bits(rng):
    """More accumulator bits never create overflow events: n_any and
    n_persistent are monotone non-increasing in the bitwidth (a running
    sum inside the wider range is inside every wider one too)."""
    prods = jnp.asarray(rng.integers(-200, 200, (64, 48)), jnp.int32)
    prev_any, prev_pers = None, None
    for bits in (8, 10, 12, 16, 20, 30):
        c = census(prods, bits)
        if prev_any is not None:
            assert int(c.n_any) <= prev_any, bits
            assert int(c.n_persistent) <= prev_pers, bits
        prev_any, prev_pers = int(c.n_any), int(c.n_persistent)
    # wide enough for any 48-term int8-squared sum: no events at all
    assert int(census(prods, 30).n_any) == 0


def test_kshard_combine_census_zero_for_wide(rng):
    """A wide register never overflows: the K-sharded combine census is
    exactly zero under policy='wide' for any data, and the combined
    value is the exact sum."""
    prods = jnp.asarray(rng.integers(-(2**20), 2**20, (8, 6, 32)), jnp.int32)
    out, novf = kshard_accumulate(prods, 8, "wide", k_shards=4)
    assert int(jnp.sum(novf)) == 0
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(prods.sum(-1)))


def test_kshard_census_decomposes(rng):
    """K-sharded total census == sum(per-shard censuses) + combine-step
    census, straight from the pqs_dot dispatch path."""
    from repro.core.dispatch import pqs_dot

    m, k, n, s, acc = 6, 128, 5, 4, 12
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    for policy in ("wide", "clip", "wrap", "sorted_tiled_seq"):
        _, tot = pqs_dot(x, w, acc_bits=acc, policy=policy, k_tile=16,
                         k_shards=s, backend="jnp", with_census=True)
        prods = partial_products(w, x)  # K=128 splits exactly: no padding
        k_local = k // s
        fields = ("n_dots", "n_persistent", "n_transient", "n_any")
        want = dict.fromkeys(fields, 0)
        for i in range(s):
            c = census(prods[..., i * k_local:(i + 1) * k_local], acc)
            for f in fields:
                want[f] += int(getattr(c, f))
        for f in fields:
            assert int(getattr(tot, f)) == want[f], (policy, f)
        assert int(tot.n_dots) == m * n * s
        _, novf = kshard_accumulate(prods, acc, policy, s, 16, 1)
        assert int(tot.n_combine) == int(jnp.sum(novf)), policy
        if policy == "wide":
            assert int(tot.n_combine) == 0
        # the non-sharded census never reports combine events
        _, flat = pqs_dot(x, w, acc_bits=acc, policy=policy, k_tile=16,
                          backend="jnp", with_census=True)
        assert int(flat.n_combine) == 0


def test_census_has_combine_field_default_zero():
    c = Census(1, 2, 3, 4)
    assert c.n_combine == 0 and len(c) == 5


def test_partial_products_shape(rng):
    wq = jnp.asarray(rng.integers(-5, 5, (3, 7)), jnp.int32)
    xq = jnp.asarray(rng.integers(-5, 5, (2, 7)), jnp.int32)
    p = partial_products(wq, xq)
    assert p.shape == (2, 3, 7)
    np.testing.assert_array_equal(
        np.asarray(p.sum(-1)), np.asarray(xq @ wq.T)
    )
