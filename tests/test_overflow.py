"""Tests for the overflow-analysis library (paper §3.1, §5.0.1)."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.overflow import (
    accumulate,
    census,
    matmul_census,
    partial_products,
    quantized_matmul_sim,
)
from repro.core.quant import qrange


def test_census_classification():
    # persistent: sum 300 > 127; transient: runs to 180 then back to 50;
    # clean: stays inside.
    prods = jnp.asarray(
        [[100, 100, 100], [120, 60, -130], [10, 20, 30]], jnp.int32
    )
    c = census(prods, acc_bits=8)
    assert int(c.n_dots) == 3
    assert int(c.n_persistent) == 1
    assert int(c.n_transient) == 1
    assert int(c.n_any) == 2


def test_transient_not_counted_if_final_overflows():
    # runs beyond range AND final out of range -> persistent only
    prods = jnp.asarray([[120, 120, -10]], jnp.int32)
    c = census(prods, acc_bits=8)
    assert int(c.n_persistent) == 1 and int(c.n_transient) == 0


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-200, 200), min_size=1, max_size=32))
def test_property_census_vs_bruteforce(vals):
    acc_bits = 9
    qmin, qmax = qrange(acc_bits)
    run, any_ovf = 0, False
    for v in vals:
        run += v
        any_ovf |= not (qmin <= run <= qmax)
    persistent = not (qmin <= run <= qmax)
    c = census(jnp.asarray([vals], jnp.int32), acc_bits)
    assert int(c.n_persistent) == int(persistent)
    assert int(c.n_transient) == int(any_ovf and not persistent)


def test_accumulate_policies_agree_when_no_overflow(rng):
    prods = jnp.asarray(rng.integers(-10, 10, (8, 64)), jnp.int32)
    exact = np.asarray(prods.sum(-1))
    for policy in ("wide", "clip", "wrap", "sorted", "sorted_tiled",
                   "sorted_tiled_seq"):
        out = accumulate(prods, 20, policy, k_tile=16)
        np.testing.assert_array_equal(np.asarray(out), exact, err_msg=policy)


def test_sorted_beats_clip_under_transients():
    prods = jnp.asarray([[120, 60, -120]], jnp.int32)
    clip = int(accumulate(prods, 8, "clip")[0])
    srt = int(accumulate(prods, 8, "sorted")[0])
    assert srt == 60 and clip != 60


def test_quantized_matmul_sim_matches_matmul_when_wide(rng):
    wq = jnp.asarray(rng.integers(-127, 127, (24, 96)), jnp.int32)
    xq = jnp.asarray(rng.integers(-127, 127, (10, 96)), jnp.int32)
    out = quantized_matmul_sim(wq, xq, 30, "wide")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(xq @ wq.T)
    )
    # batch chunking must not change results
    out2 = quantized_matmul_sim(wq, xq, 30, "wide", batch_chunk=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_matmul_census_counts_all_dots(rng):
    wq = jnp.asarray(rng.integers(-127, 127, (16, 32)), jnp.int32)
    xq = jnp.asarray(rng.integers(0, 127, (20, 32)), jnp.int32)
    c = matmul_census(wq, xq, acc_bits=12, batch_chunk=7)
    assert int(c.n_dots) == 16 * 20
    assert int(c.n_any) >= int(c.n_transient)


def test_partial_products_shape(rng):
    wq = jnp.asarray(rng.integers(-5, 5, (3, 7)), jnp.int32)
    xq = jnp.asarray(rng.integers(-5, 5, (2, 7)), jnp.int32)
    p = partial_products(wq, xq)
    assert p.shape == (2, 3, 7)
    np.testing.assert_array_equal(
        np.asarray(p.sum(-1)), np.asarray(xq @ wq.T)
    )
