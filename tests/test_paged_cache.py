"""Paged / int8-quantized cache: parity, lifecycle, isolation, admission.

The serving contract under paging: moving KV/SSM state from monolithic
per-slot lanes into a page pool with per-slot page tables must be
invisible to decode semantics — greedy f32 tokens bit-identical to the
dense engine for every architecture family — while the allocator obeys
a strict lifecycle (reserve at admission, draw lazily, free on
completion, never run dry mid-decode). int8 KV pages trade a bounded
logits perturbation for a ~4x pool-footprint cut; SSM/conv state stays
float. Admission grows backpressure (queue until pages exist), bounded
head-of-line skip, and interleaved prefill — none of which may change
what tokens any single request produces.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import PageAllocator, Request, ServingEngine

KEY = jax.random.PRNGKey(0)

FAMILY_ARCHS = ["qwen2-1.5b", "gemma3-12b", "mamba2-2.7b",
                "jamba-v0.1-52b", "granite-moe-1b-a400m", "whisper-medium"]

_MODELS: dict = {}


def _family(arch):
    """Build-once cache: f32-pinned smoke model + params per family."""
    if arch not in _MODELS:
        cfg = get_config(arch, smoke=True)
        cfg = dataclasses.replace(cfg, compute_dtype="float32",
                                  param_dtype="float32")
        model = build_model(cfg)
        _MODELS[arch] = (cfg, model, model.init(KEY))
    return _MODELS[arch]


def _requests(vocab, lens, max_new=4, temperature=0.0, uid0=0):
    rng = np.random.default_rng(7)
    return [
        Request(uid=uid0 + i,
                prompt=rng.integers(1, vocab, size=int(n)).astype(np.int32),
                max_new_tokens=max_new, temperature=temperature)
        for i, n in enumerate(lens)
    ]


def _assert_no_leaks(eng):
    assert eng._alloc.in_use == 0, "pages leaked after drain"
    assert eng._alloc.pending_reserved == 0, "reservations leaked"
    assert sorted(eng._alloc._free) == list(range(eng.paging.num_pages))
    assert (eng._table == -1).all(), "host page table leaked entries"
    assert sorted(eng._free_sidx) == list(range(eng.num_slots))


# ---------------------------------------------------------------- allocator

def test_allocator_lifecycle():
    a = PageAllocator(4)
    assert a.free_pages == 4 and a.can_reserve(4) and not a.can_reserve(5)
    a.reserve(0, 2)
    # reserved-not-drawn pages are already committed
    assert a.free_pages == 4 and not a.can_reserve(3)
    p0, p1 = a.alloc(0), a.alloc(0)
    assert p0 != p1 and a.in_use == 2 and a.peak_in_use == 2
    with pytest.raises(RuntimeError):
        a.alloc(0)  # past the reservation
    a.reserve(1, 2)
    with pytest.raises(RuntimeError):
        a.reserve(2, 1)  # pool fully committed
    a.free_slot(0)
    assert a.in_use == 0 and a.can_reserve(2)
    a.free_slot(1)  # drops the undrawn reservation too
    assert a.can_reserve(4) and a.peak_in_use == 2


# ------------------------------------------------------------------- parity

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_dense_greedy(arch):
    """f32 paged greedy decode is bit-identical to the dense engine,
    under ragged lengths, slot reuse, and page recycling."""
    cfg, model, params = _family(arch)
    lens = [5, 3, 7, 1, 6]
    dense = ServingEngine(model, params, num_slots=2, max_len=32)
    paged = ServingEngine(model, params, num_slots=2, max_len=32,
                          page_size=8)
    rd = _requests(cfg.vocab_size, lens)
    rp = _requests(cfg.vocab_size, lens)
    dense.drain(rd)
    paged.drain(rp)
    for qd, qp in zip(rd, rp):
        assert qd.output == qp.output, (
            f"{arch}: paged cache diverged from dense lanes"
        )
    assert dense.stats["decode_steps"] == paged.stats["decode_steps"]
    _assert_no_leaks(paged)


def test_paged_matches_dense_stepwise_prefill():
    """The legacy token-by-token prefill oracle also holds on pages."""
    cfg, model, params = _family("qwen2-1.5b")
    lens = [6, 4, 3]
    a = ServingEngine(model, params, num_slots=2, max_len=32)
    b = ServingEngine(model, params, num_slots=2, max_len=32,
                      page_size=8, prefill_mode="steps")
    ra = _requests(cfg.vocab_size, lens)
    rb = _requests(cfg.vocab_size, lens)
    a.drain(ra)
    b.drain(rb)
    for qa, qb in zip(ra, rb):
        assert qa.output == qb.output
    _assert_no_leaks(b)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-12b", "mamba2-2.7b"])
def test_int8_pages_close_and_smaller(arch):
    """int8 KV pages: first-decode logits within tolerance of f32 pages
    (bit-exact for pure-SSM state, which is never quantized), and the
    cache footprint strictly shrinks where KV pools exist."""
    cfg, model, params = _family(arch)
    lens = [5, 3]
    engs, logits = [], []
    for dtype in ("float32", "int8"):
        eng = ServingEngine(model, params, num_slots=2, max_len=32,
                            page_size=8, cache_dtype=dtype)
        for r in _requests(cfg.vocab_size, lens):
            eng.submit(r)
        eng._admit()  # prefill into the pools, no decode yet
        mask = np.array([True, True])
        lg, eng.caches = eng._step(
            eng.params, jnp.asarray(eng._next_token), eng.caches,
            jnp.asarray(mask),
        )
        engs.append(eng)
        logits.append(np.asarray(lg, np.float32))
    rel = np.linalg.norm(logits[1] - logits[0]) / max(
        np.linalg.norm(logits[0]), 1e-9)
    assert rel < 0.06, f"{arch}: int8 page dequant drifted {rel:.3f}"
    if arch == "mamba2-2.7b":
        assert rel == 0.0  # no KV pool to quantize
        assert engs[1].cache_nbytes() == engs[0].cache_nbytes()
    elif arch == "gemma3-12b":
        # mixed family: sliding-window layers keep dense f32 rings, so
        # only the global-attention pools shrink
        assert engs[1].cache_nbytes() < engs[0].cache_nbytes()
    else:
        assert engs[1].cache_nbytes() < 0.55 * engs[0].cache_nbytes(), (
            "int8 pages did not shrink the cache"
        )


def test_int8_requires_paging():
    _, model, params = _family("qwen2-1.5b")
    with pytest.raises(ValueError, match="page"):
        ServingEngine(model, params, num_slots=2, max_len=32,
                      cache_dtype="int8")


# ------------------------------------------------- lifecycle / backpressure

def test_page_exhaustion_backpressures():
    """A pool far smaller than slots x worst-case must still drain every
    request — admission simply waits for pages, it never crashes."""
    cfg, model, params = _family("qwen2-1.5b")
    eng = ServingEngine(model, params, num_slots=4, max_len=16,
                        page_size=8, num_pages=2)
    reqs = _requests(cfg.vocab_size, [4, 5, 3, 6, 4])
    eng.drain(reqs)
    assert all(len(r.output) == 4 for r in reqs)
    assert eng.stats["queue_wait_steps"] > 0, (
        "undersized pool produced no queueing — backpressure untested"
    )
    assert eng.stats["pages_peak"] <= 2
    _assert_no_leaks(eng)


def test_submit_rejects_impossible_request():
    cfg, model, params = _family("qwen2-1.5b")
    eng = ServingEngine(model, params, num_slots=2, max_len=32,
                        page_size=8, num_pages=2)
    req = _requests(cfg.vocab_size, [20], max_new=8)[0]  # needs 4 pages
    with pytest.raises(ValueError, match="pages"):
        eng.submit(req)


def test_hol_blocked_head_is_skipped():
    """A head-of-queue request waiting on pages must not starve a small
    request behind it (bounded skip-scan)."""
    cfg, model, params = _family("qwen2-1.5b")
    eng = ServingEngine(model, params, num_slots=2, max_len=32,
                        page_size=8, num_pages=5)
    big0 = _requests(cfg.vocab_size, [20], max_new=8, uid0=0)[0]  # 4 pages
    big1 = _requests(cfg.vocab_size, [20], max_new=8, uid0=1)[0]  # 4 pages
    small = _requests(cfg.vocab_size, [2], max_new=4, uid0=2)[0]  # 1 page
    eng.submit(big0)
    eng.step()  # big0 admitted: 4 of 5 pages committed
    eng.submit(big1)
    eng.submit(small)
    eng.step()  # big1 blocked (needs 4 > 1 free); small admits past it
    assert eng.stats["hol_skips"] >= 1
    assert any(r is small for r in eng.slots), (
        "small request should have been admitted past the blocked head"
    )
    eng.drain([])
    assert all(len(r.output) == r.max_new_tokens
               for r in (big0, big1, small))
    _assert_no_leaks(eng)


# ---------------------------------------------------------------- isolation

def test_paged_admission_respects_occupied_slots():
    """Admitting into slot 1 (prefill scatter + page claims) while slot 0
    is mid-generation must not perturb slot 0's pages or tokens."""
    cfg, model, params = _family("qwen2-1.5b")
    rng = np.random.default_rng(3)
    p0 = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    p1 = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)

    solo = ServingEngine(model, params, num_slots=2, max_len=32,
                         page_size=8)
    r_solo = Request(uid=0, prompt=p0.copy(), max_new_tokens=6)
    solo.drain([r_solo])

    eng = ServingEngine(model, params, num_slots=2, max_len=32, page_size=8)
    r0 = Request(uid=0, prompt=p0.copy(), max_new_tokens=6)
    eng.submit(r0)
    eng.step()
    eng.step()  # slot 0 is two tokens into generation
    r1 = Request(uid=1, prompt=p1.copy(), max_new_tokens=3)
    eng.submit(r1)
    eng.drain([])
    assert r0.output == r_solo.output
    _assert_no_leaks(eng)


def test_sampling_reproducible_under_batch_composition():
    """Sampled (temperature>0) output of a request depends only on
    (engine seed, request uid) — not on what else shares the batch."""
    cfg, model, params = _family("qwen2-1.5b")
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)

    def gen(extra_lens):
        eng = ServingEngine(model, params, num_slots=4, max_len=32,
                            page_size=8)
        tgt = Request(uid=42, prompt=prompt.copy(), max_new_tokens=6,
                      temperature=1.0)
        others = _requests(cfg.vocab_size, extra_lens, max_new=6,
                           temperature=0.7, uid0=100)
        eng.drain([tgt] + others)
        return tgt.output

    solo = gen([])
    crowded = gen([4, 6, 3])
    permuted = gen([6, 3])
    assert solo == crowded == permuted, (
        "sampling stream leaked across batch compositions"
    )


def test_interleaved_prefill_matches_immediate():
    """prefill_decode_ratio > 0 changes *when* prefills run, never what
    any request generates."""
    cfg, model, params = _family("qwen2-1.5b")
    lens = [5, 3, 7, 1, 6, 4]
    a = ServingEngine(model, params, num_slots=2, max_len=32, page_size=8)
    b = ServingEngine(model, params, num_slots=2, max_len=32, page_size=8,
                      prefill_decode_ratio=2)
    ra = _requests(cfg.vocab_size, lens, max_new=6)
    rb = _requests(cfg.vocab_size, lens, max_new=6)
    a.drain(ra)
    b.drain(rb)
    for qa, qb in zip(ra, rb):
        assert qa.output == qb.output
    _assert_no_leaks(b)


def test_single_token_prompts_paged():
    cfg, model, params = _family("qwen2-1.5b")
    eng = ServingEngine(model, params, num_slots=2, max_len=16, page_size=8)
    reqs = _requests(cfg.vocab_size, [1, 1])
    eng.drain(reqs)
    assert eng.stats["prefill_steps"] == 0
    assert all(len(r.output) == 4 for r in reqs)
    _assert_no_leaks(eng)


# ----------------------------------------------------------------- sharding

def test_cache_shardings_paged_serve_mode():
    """Pool leaves shard their page axis over "data" in serve mode;
    tables/indices/positions replicate everywhere."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import cache_shardings

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    shapes = {
        "kp": jax.ShapeDtypeStruct((4, 32, 16, 2, 64), jnp.int8),
        "ks": jax.ShapeDtypeStruct((4, 32, 16, 2), jnp.float32),
        "table": jax.ShapeDtypeStruct((8, 4), jnp.int32),
        "pos": jax.ShapeDtypeStruct((8,), jnp.int32),
        "ssdp": jax.ShapeDtypeStruct((32, 32, 64, 16), jnp.float32),
        "convp": jax.ShapeDtypeStruct((32, 3, 128), jnp.float32),
        "sidx": jax.ShapeDtypeStruct((8,), jnp.int32),
    }
    s = cache_shardings(mesh, shapes, serve_mode=True)
    assert s["kp"].spec == P(None, "data", None, None, "model")
    assert s["ks"].spec == P(None, "data", None, None)
    assert s["ssdp"].spec == P("data", "model", None, None)
    assert s["convp"].spec == P("data", None, "model")
    for name in ("table", "pos", "sidx"):
        assert s[name].spec == P()
    # default (dry-run) mode keeps pools replicated over data
    d = cache_shardings(mesh, shapes)
    assert d["kp"].spec == P(None, None, None, None, "model")
    assert d["ssdp"].spec == P(None, "model", None, None)
