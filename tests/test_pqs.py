"""PQS orchestration tests: schedules, QuantLinear paths, paper nets."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MLP1, MLP2, CONVNET
from repro.core.papernets import (
    evaluate_fp32,
    evaluate_int,
    init_papernet,
    overflow_profile,
    papernet_fwd,
    pqs_layer_mask,
    train_papernet,
)
from repro.core.pqs import (
    PQSConfig,
    build_schedule,
    quant_linear_freeze,
    quant_linear_init,
    quant_linear_int_fwd,
    quant_linear_train_fwd,
)
from repro.core.pruning import sparsity
from repro.data import synth_mnist

KEY = jax.random.PRNGKey(0)


def test_pqs_config_validation():
    PQSConfig().validate()
    with pytest.raises(AssertionError):
        PQSConfig(acc_bits=31).validate()
    with pytest.raises(AssertionError):
        PQSConfig(policy="bogus").validate()
    assert PQSConfig(n_keep=4, m=16).sparsity == 0.75


def test_pq_schedule_structure():
    cfg = PQSConfig(n_keep=8, m=16, order="pq")  # 50% target
    sched = build_schedule(cfg, total_epochs=20, prune_every=2, fp32_frac=0.5)
    assert len(sched) == 20
    # FP32 epochs first, QAT afterwards
    assert not sched[0].quantizing and sched[10].quantizing
    prunes = [p for p in sched if p.n_keep is not None]
    assert prunes  # pruning happens during FP32 phase
    assert all(p.epoch < 10 for p in prunes)
    assert prunes[-1].n_keep == 8


def test_qp_schedule_quantizes_throughout():
    cfg = PQSConfig(order="qp")
    sched = build_schedule(cfg, total_epochs=10, prune_every=2)
    assert all(p.quantizing for p in sched)


def test_quant_linear_train_vs_int_consistency(rng):
    """After freezing, the integer path with a wide accumulator must agree
    with the fake-quant training forward (same quantization grids)."""
    cfg = PQSConfig(weight_bits=8, act_bits=8, acc_bits=24, n_keep=16, m=16,
                    policy="wide")
    params = quant_linear_init(KEY, 64, 32)
    x = jnp.asarray(np.abs(rng.normal(size=(16, 64))), jnp.float32)
    # observe ranges, then quantizing fwd
    out_f, params = quant_linear_train_fwd(params, x, cfg, quantizing=True)
    frozen = quant_linear_freeze(params, cfg)
    out_i = quant_linear_int_fwd(frozen, x, cfg)
    np.testing.assert_allclose(
        np.asarray(out_f), np.asarray(out_i), atol=5e-2, rtol=1e-2
    )


def test_freeze_applies_nm_mask(rng):
    cfg = PQSConfig(n_keep=4, m=16)
    params = quant_linear_init(KEY, 64, 8)
    from repro.core.pruning import nm_prune_mask

    params["mask"] = nm_prune_mask(params["w"], 4, 16)
    frozen = quant_linear_freeze(params, cfg)
    wq = np.asarray(frozen["wq"]).reshape(8, 4, 16)
    assert ((wq != 0).sum(-1) <= 4).all()


@pytest.mark.parametrize("kind_cfg", [MLP1, MLP2, CONVNET],
                         ids=lambda c: c.kind)
def test_papernet_shapes(kind_cfg):
    pqs = PQSConfig()
    layers = init_papernet(KEY, kind_cfg)
    assert len(layers) == len(pqs_layer_mask(kind_cfg))
    x = jnp.zeros((4, kind_cfg.in_dim))
    logits, _ = papernet_fwd(layers, x, kind_cfg, pqs, quantizing=False)
    assert logits.shape == (4, kind_cfg.num_classes)


def test_papernet_training_learns_and_prunes():
    data = synth_mnist(n=1024, seed=2)
    pqs = PQSConfig(n_keep=8, m=16, order="pq")
    res = train_papernet(MLP1, pqs, data, epochs=8, prune_every=2,
                         fp32_frac=0.75, lr=0.1)
    assert res.fp32_acc > 0.8  # synthetic set is separable
    assert float(sparsity(res.layers[0]["mask"])) == pytest.approx(0.5)


def test_int_eval_wide_matches_fp32_closely():
    data = synth_mnist(n=1024, seed=3)
    pqs = PQSConfig(n_keep=16, m=16, order="pq")  # no pruning
    res = train_papernet(MLP1, pqs, data, epochs=6, prune_every=2, lr=0.1)
    _, test = data.split(0.9)
    fp = evaluate_fp32(res.layers, MLP1, pqs, test)
    wide = evaluate_int(res.layers, MLP1, pqs, test, "wide", 24, limit=256)
    assert abs(fp - wide) < 0.08


def test_overflow_profile_monotone_in_bits():
    data = synth_mnist(n=1024, seed=4)
    pqs = PQSConfig(order="pq")
    res = train_papernet(MLP1, pqs, data, epochs=6, prune_every=2, lr=0.1)
    _, test = data.split(0.9)
    counts = [
        int(overflow_profile(res.layers, MLP1, pqs, test, bits,
                             limit=64).n_any)
        for bits in (12, 16, 20)
    ]
    assert counts[0] >= counts[1] >= counts[2]
