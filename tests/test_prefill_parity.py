"""One-shot batched prefill parity with the token-by-token oracle.

The serving engine's admission path consumes a whole cohort of prompts
in ONE jitted batched prefill step (``Model.prefill``). The contract,
per architecture family (attention KV, SSM state, hybrid interleave,
MoE routing, cross-attention): greedy decode after batched prefill
produces exactly the same tokens as after the legacy token-by-token
prefill, under mixed prompt lengths and slot reuse — and it does so in
one device step per admission cohort instead of one per prompt
position.

The family sweep pins f32 compute: the SSD prefill is the chunked dual
form while decode is the stepwise recurrence, so in bf16 their float
reassociation can flip a near-tie argmax on random smoke weights (the
same documented tolerance as the fwd-vs-decode consistency test). The
routing/caching semantics under test are dtype-independent; a bf16
greedy case is kept for the attention-KV family where the paths share
op-for-op numerics.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)

# one per family: dense GQA KV, mixed local/global window rings, pure
# SSM state, Mamba+attention hybrid with interleaved MoE, top-k-routed
# MoE transformer, encoder-decoder cross-KV
FAMILY_ARCHS = ["qwen2-1.5b", "gemma3-12b", "mamba2-2.7b",
                "jamba-v0.1-52b", "granite-moe-1b-a400m", "whisper-medium"]


def _requests(vocab, lens, max_new=4):
    rng = np.random.default_rng(7)
    return [
        Request(uid=i,
                prompt=rng.integers(1, vocab, size=int(n)).astype(np.int32),
                max_new_tokens=max_new)
        for i, n in enumerate(lens)
    ]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_batched_prefill_matches_stepwise(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              param_dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    # ragged lengths (incl. a single-token prompt) across few slots so
    # admission cohorts mix lengths AND slots get reused mid-stream
    lens = [5, 3, 7, 1, 6]
    a = ServingEngine(model, params, num_slots=2, max_len=32,
                      prefill_mode="steps")
    b = ServingEngine(model, params, num_slots=2, max_len=32,
                      prefill_mode="batched")
    ra, rb = _requests(cfg.vocab_size, lens), _requests(cfg.vocab_size, lens)
    a.drain(ra)
    b.drain(rb)
    for qa, qb in zip(ra, rb):
        assert qa.output == qb.output, (
            f"{arch}: batched prefill diverged from token-by-token"
        )
    # admission latency: one batched step per cohort vs one per position
    assert b.stats["prefill_steps"] <= b.stats["cohorts"]
    assert a.stats["prefill_steps"] > a.stats["cohorts"]
    # identical decode work either way
    assert a.stats["decode_steps"] == b.stats["decode_steps"]


def test_batched_prefill_matches_stepwise_bf16_dense():
    """Attention-KV decode and prefill share op-for-op numerics, so the
    greedy-token contract holds at the production compute dtype too."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    lens = [5, 3, 7, 1, 6]
    a = ServingEngine(model, params, num_slots=2, max_len=32,
                      prefill_mode="steps")
    b = ServingEngine(model, params, num_slots=2, max_len=32,
                      prefill_mode="batched")
    ra, rb = _requests(cfg.vocab_size, lens), _requests(cfg.vocab_size, lens)
    a.drain(ra)
    b.drain(rb)
    assert [r.output for r in ra] == [r.output for r in rb]


def test_prefill_cache_state_matches_stepwise():
    """Beyond greedy tokens: the cache pytrees themselves line up."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    lens = [6, 4]
    a = ServingEngine(model, params, num_slots=2, max_len=16,
                      prefill_mode="steps")
    b = ServingEngine(model, params, num_slots=2, max_len=16,
                      prefill_mode="batched")
    for eng, reqs in ((a, _requests(cfg.vocab_size, lens)),
                      (b, _requests(cfg.vocab_size, lens))):
        for r in reqs:
            eng.submit(r)
        eng._admit()  # prefill only — no decode yet
    for la, lb in zip(jax.tree_util.tree_leaves(a.caches),
                      jax.tree_util.tree_leaves(b.caches)):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            rtol=0, atol=1e-4,
        )


def test_batched_prefill_respects_occupied_slots():
    """Admitting into slot 1 while slot 0 is mid-generation must not
    perturb slot 0's cache lanes or its sampled continuation."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    p0 = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    p1 = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)

    solo = ServingEngine(model, params, num_slots=2, max_len=32)
    r_solo = Request(uid=0, prompt=p0.copy(), max_new_tokens=6)
    solo.drain([r_solo])

    eng = ServingEngine(model, params, num_slots=2, max_len=32)
    r0 = Request(uid=0, prompt=p0.copy(), max_new_tokens=6)
    eng.submit(r0)
    eng.step()
    eng.step()  # slot 0 is two tokens into generation
    r1 = Request(uid=1, prompt=p1.copy(), max_new_tokens=3)
    eng.submit(r1)
    eng.drain([])
    assert r0.output == r_solo.output


def test_single_token_prompts_skip_prefill():
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServingEngine(model, params, num_slots=2, max_len=16)
    reqs = _requests(cfg.vocab_size, [1, 1])
    eng.drain(reqs)
    assert eng.stats["prefill_steps"] == 0
    assert all(len(r.output) == 4 for r in reqs)
