"""Property-based parity for the (K-sharded) ``pqs_dot`` hierarchy.

Hypothesis (through ``tests/_hypothesis_shim.py`` — real hypothesis when
installed, a deterministic seeded sweep of 25 examples per test offline)
draws shapes (including ragged M/N/K and K=1), accumulator widths, shard
counts, backends and storage forms, and asserts

  - bit-identity of every drawn configuration against the single-device
    hierarchical jnp oracle (``overflow.kshard_accumulate`` over the
    dispatch layer's exact padding), and
  - census equality — including the decomposition
    total == sum(per-shard censuses) + combine-step census.

Drawn-case budget (the CI unit stage runs this file): the oracle test
alone contributes 6 policies x 25 examples = 150 cases, the pallas
parity test 6 x 8 = 48, the nm-storage test 2 x 25 = 50 — ≥ 200 drawn
cases per run even on the offline shim. Dims come from small fixed
menus so jit caches stay warm across examples.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core import overflow
from repro.core.dispatch import pqs_dot
from repro.core.pruning import nm_compress, nm_decompress, nm_prune_mask
from repro.core.sorted_accum import tree_combine
from repro.kernels import ops

POLICIES = ("wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq")
# menus, not open ranges: examples revisit shapes so accumulate/census
# jit caches are reused across the sweep (the shim draws 25 per test)
MS = (1, 2, 3, 5)
KS = (1, 2, 7, 16, 33, 64)
NS = (1, 2, 4, 7)
SHARDS = (1, 2, 3, 4)
ACCS = (10, 14, 18)
K_TILE = 16


def _xw(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    return x, w


def _oracle(x, w, acc_bits, policy, k_shards):
    """The hierarchical jnp oracle on dispatch's exact padding: pad K to
    k_shards equal policy-padded slices, per-shard ``accumulate``, merge
    through ``tree_combine`` (via ``overflow.kshard_accumulate``)."""
    k = x.shape[-1]
    k_local = ops.padded_k(-(-k // k_shards), policy, K_TILE)
    kp = k_shards * k_local
    xp = jnp.pad(x, ((0, 0), (0, kp - k)))
    wp = jnp.pad(w, ((0, 0), (0, kp - k)))
    prods = overflow.partial_products(wp, xp)  # (M, N, kp)
    out, novf = overflow.kshard_accumulate(
        prods, acc_bits, policy, k_shards, K_TILE, 1)
    return out, novf, prods, k_local


def _draws():
    return (
        st.integers(0, len(MS) - 1), st.integers(0, len(KS) - 1),
        st.integers(0, len(NS) - 1), st.integers(0, len(SHARDS) - 1),
        st.integers(0, len(ACCS) - 1), st.integers(0, 10**6),
    )


@pytest.mark.parametrize("policy", POLICIES)
@settings(max_examples=25, deadline=None)
@given(*_draws())
def test_property_kshard_matches_oracle(policy, mi, ki, ni, si, ai, seed):
    """jnp-backend K-sharded pqs_dot == the hierarchical oracle, and the
    census decomposes as sum(per-shard) + combine steps."""
    m, k, n = MS[mi], KS[ki], NS[ni]
    s, acc = SHARDS[si], ACCS[ai]
    x, w = _xw(m, k, n, seed)
    out, cns = pqs_dot(x, w, acc_bits=acc, policy=policy, k_tile=K_TILE,
                       k_shards=s, backend="jnp", with_census=True)
    ref, novf, prods, k_local = _oracle(x, w, acc, policy, s)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref),
        err_msg=f"{policy} s={s} shape={(m, k, n)} acc={acc}",
    )
    # census decomposition: every shard's local dot is an examined dot
    per_shard = [
        overflow.census(prods[..., i * k_local:(i + 1) * k_local], acc)
        for i in range(s)
    ]
    for field in ("n_dots", "n_persistent", "n_transient", "n_any"):
        want = sum(int(getattr(c, field)) for c in per_shard)
        assert int(getattr(cns, field)) == want, (policy, s, field)
    assert int(cns.n_dots) == m * n * s
    assert int(cns.n_combine) == int(jnp.sum(novf))
    if policy == "wide":
        assert int(cns.n_combine) == 0  # a wide register never overflows


@pytest.mark.parametrize("policy", POLICIES)
@settings(max_examples=8, deadline=None)
@given(*_draws())
def test_property_pallas_parity(policy, mi, ki, ni, si, ai, seed):
    """The pallas backend (per-shard kernel partials) is bit-identical
    to the jnp oracle path, census included."""
    m, k, n = MS[mi], KS[ki], NS[ni]
    s, acc = SHARDS[si], ACCS[ai]
    x, w = _xw(m, k, n, seed + 1)
    a, ca = pqs_dot(x, w, acc_bits=acc, policy=policy, k_tile=K_TILE,
                    k_shards=s, backend="jnp", with_census=True)
    b, cb = pqs_dot(x, w, acc_bits=acc, policy=policy, k_tile=K_TILE,
                    k_shards=s, backend="pallas", block_m=2, block_n=4,
                    with_census=True)
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b),
        err_msg=f"{policy} s={s} shape={(m, k, n)} acc={acc}",
    )
    for field in overflow.Census._fields:
        assert int(getattr(ca, field)) == int(getattr(cb, field)), (
            policy, s, field)


NM_MENU = ((2, 4), (4, 16))  # (n_keep, m_group)


@pytest.mark.parametrize("policy", ("sorted_tiled", "sorted_tiled_seq"))
@settings(max_examples=25, deadline=None)
@given(st.integers(0, len(MS) - 1), st.integers(1, len(KS) - 1),
       st.integers(0, len(NS) - 1), st.integers(0, len(SHARDS) - 1),
       st.integers(0, len(NM_MENU) - 1), st.integers(0, 3),
       st.integers(0, 1), st.integers(0, 10**6))
def test_property_nm_storage_parity(policy, mi, ki, ni, si, nmi, bi, impi,
                                    seed):
    """storage="nm" under K-sharding == decompress-then-dense at the
    same shard count, on a drawn backend AND a drawn sparse kernel
    implementation (expand oracle vs fused gather), census included.

    The tiled policies are the ones whose dense per-shard padded length
    is guaranteed group-aligned (k_tile % m_group == 0), so the nm
    whole-group shard boundaries coincide with the dense ones for EVERY
    drawn (K, k_shards) — the strongest form of the equivalence. The
    other policies' nm/dense boundaries only coincide when ceil(K/S)
    lands on a group multiple (see test_kshard_nm_backend_parity)."""
    m, k, n = MS[mi], KS[ki], NS[ni]
    s = SHARDS[si]
    n_keep, mg = NM_MENU[nmi]
    backend = "pallas" if bi == 0 else "jnp"  # pallas ~1 in 4 draws
    nm_impl = ("expand", "gather")[impi]  # only the pallas path branches
    g = -(-k // mg)
    kd = g * mg  # bare (values, indices) pairs cover whole groups
    rng = np.random.default_rng(seed + 2)
    wd = np.zeros((n, kd), np.int8)
    wd[:, :k] = rng.integers(-127, 127, (n, k))
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
    wd = (wd * mask).astype(np.int8)
    vals, idx = nm_compress(wd, n_keep, mg)
    dense = jnp.asarray(nm_decompress(vals, idx, mg, k=kd))
    x = jnp.zeros((m, kd), jnp.int8)
    x = x.at[:, :k].set(
        jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8))
    kw = dict(acc_bits=14, policy=policy, k_tile=K_TILE, k_shards=s,
              backend=backend, with_census=True)
    if backend == "pallas":
        kw.update(block_m=2, block_n=4)
    ref, cr = pqs_dot(x, dense, **kw)
    out, co = pqs_dot(
        x, (jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)),
        storage="nm", m_group=mg, nm_impl=nm_impl, **kw)
    np.testing.assert_array_equal(
        np.asarray(ref), np.asarray(out),
        err_msg=f"{policy} s={s} nm={n_keep}:{mg} {backend} {nm_impl}",
    )
    for field in overflow.Census._fields:
        assert int(getattr(cr, field)) == int(getattr(co, field)), (
            policy, s, field)


def test_kshard_nm_backend_parity():
    """All six policies on nm storage: the per-shard kernel path equals
    the nm jnp oracle (both slice K in whole groups), bit-identical with
    census, at shard counts where whole groups are the only legal cut."""
    n_keep, mg = 4, 16
    m, k, n = 3, 96, 5
    rng = np.random.default_rng(11)
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    mask = np.asarray(nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
    wd = (wd * mask).astype(np.int8)
    vals, idx = nm_compress(wd, n_keep, mg)
    vals, idx = jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    for policy in POLICIES:
        for s in (2, 3):
            kw = dict(storage="nm", m_group=mg, acc_bits=14, policy=policy,
                      k_tile=K_TILE, k_shards=s, with_census=True)
            a, ca = pqs_dot(x, (vals, idx), backend="jnp", **kw)
            for impl in ("expand", "gather"):
                b, cb = pqs_dot(x, (vals, idx), backend="pallas", block_m=2,
                                block_n=4, nm_impl=impl, **kw)
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{policy} s={s} {impl}")
                for field in overflow.Census._fields:
                    assert int(getattr(ca, field)) == int(
                        getattr(cb, field)), (policy, s, impl, field)


def test_kshard_edges():
    """Deterministic edge sweep: K=1, k_shards > K, validation errors."""
    x, w = _xw(2, 1, 3, seed=0)
    exact = np.asarray(
        x.astype(jnp.int32) @ w.astype(jnp.int32).T)
    for policy in POLICIES:
        for s in (1, 2, 4):
            out = pqs_dot(x, w, acc_bits=18, policy=policy, k_tile=K_TILE,
                          k_shards=s, backend="jnp")
            # one real product, every padded shard contributes zero: all
            # policies reduce to the exact sum at a wide-enough register
            np.testing.assert_array_equal(
                np.asarray(out), exact, err_msg=f"{policy} s={s}")
    with pytest.raises(ValueError):
        pqs_dot(x, w, k_shards=0)
    with pytest.raises(ValueError):
        pqs_dot(x, w, k_axis="k")  # k_axis without a mesh


def test_tree_combine_is_exact_when_wide_enough():
    """tree_combine == plain sum whenever no step can overflow, for any
    policy; and wrap/wide are order-invariant under any sharding."""
    rng = np.random.default_rng(3)
    parts = jnp.asarray(rng.integers(-50, 50, (4, 5, 6)), jnp.int32)
    want = np.asarray(parts.sum(-1))
    for policy in POLICIES:
        got, novf = tree_combine(parts, 30, policy)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=policy)
        assert int(jnp.sum(novf)) == 0
