"""Tests for N:M pruning, schedules, compression, and the A2Q baseline."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.a2q import (
    a2q_fake_quant,
    a2q_l1_bound,
    a2q_quantize_project,
    a2q_sparsity,
    a2q_violations,
)
from repro.core.pruning import (
    filter_prune_mask,
    iterative_nm_schedule,
    low_rank_approx,
    nm_compress,
    nm_decompress,
    nm_prune_mask,
    sparsity,
)


def test_nm_mask_keeps_largest(rng):
    w = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    mask = nm_prune_mask(w, n_keep=4, m=16)
    groups = np.asarray((w * mask)).reshape(8, 2, 16)
    orig = np.asarray(w).reshape(8, 2, 16)
    for r in range(8):
        for g in range(2):
            kept = np.nonzero(groups[r, g])[0]
            assert len(kept) == 4
            thresh = np.sort(np.abs(orig[r, g]))[-4]
            assert np.all(np.abs(orig[r, g][kept]) >= thresh - 1e-7)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 16))
def test_property_nm_sparsity(n_keep):
    w = jnp.asarray(np.random.default_rng(1).normal(size=(4, 64)), jnp.float32)
    mask = nm_prune_mask(w, n_keep, 16)
    assert float(sparsity(mask)) == pytest.approx(1 - n_keep / 16)


def test_nm_mask_bad_shapes():
    w = jnp.ones((4, 30))
    with pytest.raises(ValueError):
        nm_prune_mask(w, 4, 16)
    with pytest.raises(ValueError):
        nm_prune_mask(jnp.ones((4, 32)), 17, 16)


def test_iterative_schedule_reaches_target():
    steps = iterative_nm_schedule(200, 10, 16, 0.8)
    epochs, keeps = zip(*steps)
    assert keeps[-1] == round(16 * 0.2)
    assert all(a < b for a, b in zip(epochs, epochs[1:]))
    assert all(a >= b for a, b in zip(keeps, keeps[1:]))


def test_compress_roundtrip(rng):
    w = rng.normal(size=(6, 64)).astype(np.float32)
    mask = np.asarray(nm_prune_mask(jnp.asarray(w), 4, 16))
    wp = w * mask
    vals, idx = nm_compress(wp, 4, 16)
    assert vals.shape == (6, 4, 4) and idx.shape == (6, 4, 4)
    np.testing.assert_allclose(nm_decompress(vals, idx, 16), wp)


def test_compress_dense_as_sparse_roundtrip(rng):
    """n_keep == m: no pruning assumption — any matrix round-trips."""
    w = rng.normal(size=(5, 48)).astype(np.float32)  # fully dense
    vals, idx = nm_compress(w, 16, 16)
    assert vals.shape == (5, 3, 16)
    np.testing.assert_array_equal(nm_decompress(vals, idx, 16), w)


def test_compress_tail_group_roundtrip(rng):
    """K not divisible by m: the tail group zero-pads inside the
    compressed form and k= trims it back exactly."""
    w = rng.normal(size=(4, 50)).astype(np.float32)
    mask = np.asarray(nm_prune_mask(jnp.asarray(w[:, :48]), 4, 16))
    wp = np.concatenate([w[:, :48] * mask, w[:, 48:50] * 0], axis=1)
    wp[:, 48] = 1.5  # one kept value in the 2-wide tail group
    vals, idx = nm_compress(wp, 4, 16)
    assert vals.shape == (4, 4, 4)  # G = ceil(50/16) = 4
    np.testing.assert_array_equal(nm_decompress(vals, idx, 16, k=50), wp)
    # the padded variant covers G*m columns, with an all-zero tail
    full = nm_decompress(vals, idx, 16)
    assert full.shape == (4, 64)
    assert np.abs(full[:, 50:]).sum() == 0


def test_compress_validation_errors(rng):
    w = rng.normal(size=(4, 32)).astype(np.float32)
    with pytest.raises(ValueError, match="n_keep"):
        nm_compress(w, 0, 16)
    with pytest.raises(ValueError, match="n_keep"):
        nm_compress(w, 17, 16)
    with pytest.raises(ValueError, match="m_group"):
        nm_compress(w, 1, 0)
    with pytest.raises(ValueError, match="2-D"):
        nm_compress(w.reshape(4, 4, 8), 4, 16)
    with pytest.raises(ValueError, match="empty"):
        nm_compress(w[:, :0], 4, 16)
    # a denser-than-n_keep:m matrix would compress lossily -> loud error
    with pytest.raises(ValueError, match="not 4:16 sparse"):
        nm_compress(w, 4, 16)


def test_compress_jax_matches_numpy(rng):
    """The device-side packer (used by qtensor_nm_compress on stacked
    leaves) agrees with the host packer bit for bit."""
    from repro.core.pruning import nm_compress_jax, nm_decompress_jax

    w = rng.normal(size=(6, 40)).astype(np.float32)
    mask = np.asarray(nm_prune_mask(jnp.asarray(np.pad(w, ((0, 0), (0, 8)))),
                                    2, 8))[:, :40]
    wp = w * mask
    vn, idxn = nm_compress(wp, 2, 8)
    vj, idxj = nm_compress_jax(jnp.asarray(wp), 2, 8)
    np.testing.assert_array_equal(vn, np.asarray(vj))
    np.testing.assert_array_equal(idxn, np.asarray(idxj))
    np.testing.assert_array_equal(
        nm_decompress(vn, idxn, 8, k=40),
        np.asarray(nm_decompress_jax(vj, idxj, 8, k=40)),
    )
    with pytest.raises(ValueError, match="not 2:8 sparse"):
        nm_compress_jax(jnp.asarray(w), 2, 8)


def test_filter_prune_zeroes_rows(rng):
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    mask = filter_prune_mask(w, keep_frac=0.25)
    row_alive = np.asarray(mask).reshape(16, -1).any(axis=1)
    assert row_alive.sum() == 4


def test_low_rank_exact_at_full_rank(rng):
    w = jnp.asarray(rng.normal(size=(12, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(low_rank_approx(w, 8)), np.asarray(w), atol=1e-4
    )
    w1 = low_rank_approx(w, 1)
    assert np.linalg.matrix_rank(np.asarray(w1), tol=1e-4) == 1


# --- A2Q baseline ----------------------------------------------------------


@pytest.mark.parametrize("wb,ab", [(8, 16), (8, 12), (5, 14)])
def test_a2q_bound_enforced(wb, ab, rng):
    w = jnp.asarray(rng.normal(size=(32, 256)) * 3.0, jnp.float32)
    wq, scale = a2q_quantize_project(w, wb, ab)
    l1 = np.abs(np.asarray(wq)).sum(axis=-1)
    assert (l1 <= a2q_l1_bound(wb, ab) + 1e-6).all()
    assert int(a2q_violations(wq, wb, ab)) == 0


def test_a2q_induces_sparsity(rng):
    """Paper §3.1: the L1 bound pulls weights to zero (unstructured)."""
    w = jnp.asarray(rng.normal(size=(16, 512)), jnp.float32)
    wq, _ = a2q_quantize_project(w, 8, 12)  # tight accumulator
    assert float(a2q_sparsity(wq)) > 0.5


def test_a2q_fake_quant_identity_when_loose(rng):
    w = jnp.asarray(rng.normal(size=(8, 16)) * 0.01, jnp.float32)
    out = a2q_fake_quant(w, 8, 32)  # loose bound: plain per-channel quant
    err = np.abs(np.asarray(out - w))
    assert err.max() < 0.01 / 127 + 1e-5
