"""Unit + property tests for core/quant.py (paper §2.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.quant import (
    EmaRange,
    activation_qparams,
    dequantize,
    fake_quant,
    qrange,
    quantize,
    quantized_dot_terms,
    weight_qparams,
)


def test_qrange():
    assert qrange(8) == (-128, 127)
    assert qrange(16) == (-32768, 32767)


@pytest.mark.parametrize("bits", [4, 5, 6, 7, 8])
def test_weight_roundtrip_error_bound(bits, rng):
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    qp = weight_qparams(w, bits)
    err = jnp.abs(dequantize(quantize(w, qp), qp) - w)
    assert float(err.max()) <= float(qp.scale) / 2 + 1e-6


def test_weight_symmetric_offset_zero(rng):
    w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    assert int(weight_qparams(w, 8).offset) == 0


def test_activation_zero_maps_to_integer(rng):
    x = jnp.asarray(rng.uniform(0.0, 5.0, size=(128,)), jnp.float32)
    qp = activation_qparams(jnp.min(x), jnp.max(x), 8)
    z = quantize(jnp.zeros(()), qp)
    assert float(jnp.abs(dequantize(z, qp))) < 1e-6  # exact zero point


@pytest.mark.parametrize("bits", [5, 8])
def test_activation_range_covers(bits, rng):
    x = jnp.asarray(rng.uniform(-2.0, 7.0, size=(1000,)), jnp.float32)
    qp = activation_qparams(jnp.min(x), jnp.max(x), bits)
    q = quantize(x, qp)
    qmin, qmax = qrange(bits)
    assert int(q.min()) >= qmin and int(q.max()) <= qmax
    err = jnp.abs(dequantize(q, qp) - x)
    assert float(err.max()) <= float(qp.scale) / 2 + 1e-5


def test_fake_quant_ste_gradient(rng):
    w = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    qp = weight_qparams(w, 8)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, qp) ** 2))(w)
    # STE: grad ~= 2*fake_quant(w) inside range (identity through rounding)
    expect = 2 * fake_quant(w, qp)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-5)


def test_ema_range_update():
    r = EmaRange.init()
    r = r.update(jnp.asarray([0.0, 10.0]))
    assert float(r.hi) == pytest.approx(0.1, rel=1e-5)  # 0.99*0 + 0.01*10


def test_quantized_dot_terms_match_eq3(rng):
    """Integer dot + offset correction == dequantized-domain dot (Eq. 3)."""
    w = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    x = jnp.asarray(rng.uniform(0, 3, size=(64,)), jnp.float32)
    w_qp = weight_qparams(w, 8)
    x_qp = activation_qparams(jnp.zeros(()), jnp.max(x), 8)
    wq, xq = quantize(w, w_qp), quantize(x, x_qp)
    prods, corr = quantized_dot_terms(wq, xq, x_qp)
    z_int = (jnp.sum(prods, -1) - corr).astype(jnp.float32)
    z = z_int * w_qp.scale * x_qp.scale
    expect = dequantize(wq, w_qp) @ dequantize(xq, x_qp)
    np.testing.assert_allclose(np.asarray(z), np.asarray(expect), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=64),
    st.integers(4, 8),
)
def test_property_quantize_within_half_scale(vals, bits):
    x = jnp.asarray(vals, jnp.float32)
    qp = weight_qparams(x, bits)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    assert float(err.max()) <= float(qp.scale) / 2 * (1 + 1e-5) + 1e-6
