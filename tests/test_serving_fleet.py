"""Fault-tolerant serving fleet: crash recovery, degradation, quotas.

Acceptance suite for the fleet layer (serving/fleet.py + the engine's
snapshot/restore/census paths):

- an injected engine crash mid-decode recovers from the latest snapshot
  with token streams BIT-IDENTICAL to a failure-free run — untouched
  requests unaffected, interrupted ones with no lost or duplicated
  emissions;
- a workload driven past its calibrated activation range trips the
  census guardrail: the saturating site hot-swaps to the wide policy
  (event logged, rate observably 0.0 afterward) while in-range sites
  keep their narrow accumulators;
- quotas bound per-model admission; deadlines cancel + retry with
  backoff and never silently drop a request;
- a mesh-member drop remeshes onto the survivors and resumes
  bit-identically (>= 4 devices; scripts/ci.sh's ``fault`` stage).
"""

import os

# same opt-in idiom as test_sharded_dispatch.py: only effective before
# the first jax backend init, never leaks into the single-device suite
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    _v = os.environ["REPRO_FORCE_MULTIDEVICE"]
    _n = int(_v) if _v.isdigit() and int(_v) > 1 else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import dataclasses  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import dispatch  # noqa: E402
from repro.core.qtensor import is_qtensor, quantize_tree  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.runtime import FailureInjector, ServeSupervisor  # noqa: E402
from repro.serving import (  # noqa: E402
    CensusWatch,
    Request,
    ServingEngine,
    ServingFleet,
)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def smoke_qparams(smoke_model):
    _, _, params = smoke_model
    return quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)


def _requests():
    # mixed greedy/temperature, mixed lengths: exercises RNG-state
    # restore and unequal completion times around the failure point
    return [
        Request(
            uid=i,
            prompt=np.asarray([1 + i, 2, 3 + i], np.int32),
            max_new_tokens=4 + (i % 3),
            temperature=0.0 if i % 2 == 0 else 0.8,
        )
        for i in range(6)
    ]


def _drive(fleet, reqs, schedule, max_steps=500, **sup_kw):
    """Supervised fleet loop with submissions staged by loop index."""
    sup = ServeSupervisor(fleet, **sup_kw)
    last_submit = max(schedule)
    for step in range(max_steps):
        for i in schedule.get(step, ()):
            fleet.submit("m", reqs[i])
        if sup.step() == 0 and step >= last_submit:
            return sup
    raise AssertionError("fleet failed to drain")


def test_fleet_crash_recovery_bit_identical(smoke_model, tmp_path):
    """FailureInjector kills the engine mid-decode (twice); recovery from
    the snapshot reproduces the failure-free token streams exactly."""
    _, model, params = smoke_model
    schedule = {0: (0, 1, 2, 3), 5: (4, 5)}  # some submitted post-snapshot

    def run(inject):
        reqs = _requests()
        eng = ServingEngine(
            model, params, num_slots=2, max_len=32, page_size=8,
            num_pages=8,
            failure_injector=FailureInjector({5, 11}) if inject else None,
        )
        fleet = ServingFleet(
            snapshot_dir=str(tmp_path / "snaps") if inject else None,
            snapshot_every=3 if inject else 0,
        )
        fleet.add_engine("m", eng)
        sup = _drive(fleet, reqs, schedule)
        fleet.wait()
        assert all(r.done and not r.failed for r in reqs)
        return {r.uid: list(r.output) for r in reqs}, fleet, sup

    base, _, _ = run(inject=False)
    out, fleet, sup = run(inject=True)
    assert fleet.stats["recoveries"] == 2 and len(sup.recoveries) == 2
    assert [e["event"] for e in fleet.events].count("recovered") == 2
    # bit-identical streams: no lost, duplicated, or diverged emissions
    assert out == base


def test_engine_snapshot_restore_replays_identical_tokens(smoke_model):
    """Restore rewinds emitted output to the snapshot point; replay
    re-emits the identical continuation (no dupes, no gaps)."""
    _, model, params = smoke_model
    reqs = _requests()
    eng = ServingEngine(
        model, params, num_slots=2, max_len=32, page_size=8, num_pages=8
    )
    for r in reqs[:4]:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    snap = eng.snapshot()
    mid = {r.uid: len(r.output) for r in reqs[:4]}
    while eng.step() or eng.queue:
        pass
    first = {r.uid: list(r.output) for r in reqs[:4]}
    assert all(r.done for r in reqs[:4])

    eng.restore(snap)
    # output really was truncated back to the snapshot point
    for r in reqs[:4]:
        if not r.done:  # in-flight at snapshot
            assert len(r.output) == mid[r.uid]
    while eng.step() or eng.queue:
        pass
    second = {r.uid: list(r.output) for r in reqs[:4]}
    assert second == first


def test_census_degradation_fires_on_drifted_workload(smoke_qparams, smoke_model):
    """Workload past the calibrated activation range: the saturating
    site (w_out — its input is the unnormalized silu(gate)*up) degrades
    to wide, in-range sites keep their narrow policy, and the overflow
    rate observably drops to zero."""
    _, model, _ = smoke_model
    il = dispatch.IntegerLinConfig(
        policy="sorted_tiled_seq", acc_bits=17, k_tile=64, backend="jnp"
    )
    watch = CensusWatch(threshold=0.01, window=4)
    cal_batch = {
        "tokens": jnp.asarray(
            (np.arange(32).reshape(2, 16) % 97 + 1), jnp.int32
        )
    }

    def drift(params, factor):
        # inflate w_up's dequant scale post-calibration: w_out's input
        # (silu(gate) * up) leaves the frozen static range while every
        # rmsnorm-shielded site stays in calibration
        def fix(path, leaf):
            if is_qtensor(leaf) and any("w_up" in str(p) for p in path):
                return dataclasses.replace(leaf, scale=leaf.scale * factor)
            return leaf

        return jax.tree_util.tree_map_with_path(
            fix, params, is_leaf=is_qtensor
        )

    def run(drifted):
        eng = ServingEngine(
            model, smoke_qparams, num_slots=4, max_len=48,
            int_lin=il, census_watch=watch,
        )
        eng.calibrate([cal_batch])
        if drifted:
            eng.params = drift(eng.params, 8)
        reqs = [
            Request(
                uid=i, prompt=np.asarray([1 + i, 2, 3 + i, 5], np.int32),
                max_new_tokens=20,
            )
            for i in range(4)
        ]
        eng.drain(reqs)
        assert all(r.done for r in reqs)
        return eng

    # in-range traffic: nothing degrades
    eng = run(drifted=False)
    assert eng.stats["census_degrades"] == 0 and eng.events == []

    # drifted traffic: exactly w_out degrades, with a structured event
    eng = run(drifted=True)
    assert eng._degraded == {"w_out"}
    assert eng.stats["census_degrades"] == 1
    (event,) = [e for e in eng.events if e["event"] == "census_degrade"]
    assert event["site"] == "w_out" and event["rate"] > 0.01
    assert eng.int_lin.policy_for("w_out") == "wide"
    # in-range layers keep the narrow accumulator policy
    for site in ("wq", "wk", "wv", "wo", "w_gate", "w_up"):
        assert eng.int_lin.policy_for(site) == "sorted_tiled_seq"
    # post-swap the degraded site's overflow rate reads zero
    assert eng.last_census_rates["w_out"] == 0.0


def test_fleet_quota_bounds_inflight(smoke_model):
    _, model, params = smoke_model
    reqs = _requests()
    eng = ServingEngine(model, params, num_slots=4, max_len=32)
    fleet = ServingFleet()
    fleet.add_engine("m", eng, quota=2)
    for r in reqs:
        fleet.submit("m", r)
    peak = 0
    for _ in range(300):
        n = fleet.step()
        peak = max(peak, len(fleet._inflight["m"]))
        if n == 0:
            break
    assert n == 0 and all(r.done for r in reqs)
    assert peak <= 2  # quota held at every step


def test_fleet_deadline_retry_and_failure(smoke_model):
    _, model, params = smoke_model

    # one slot: the long request occupies it and the short one's
    # deadline expires while it queues; the retry (after backoff) lands
    # once the slot frees, and the request completes — never dropped
    long_req = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=12)
    short = Request(uid=2, prompt=np.asarray([4, 5], np.int32),
                    max_new_tokens=2)
    eng = ServingEngine(model, params, num_slots=1, max_len=32)
    fleet = ServingFleet(max_retries=3, backoff_steps=2)
    fleet.add_engine("m", eng)
    fleet.submit("m", long_req)
    fleet.submit("m", short, deadline=4)
    for _ in range(300):
        if fleet.step() == 0:
            break
    assert long_req.done and short.done and not short.failed
    assert fleet.stats["deadline_cancels"] >= 1
    assert any(e["event"] == "deadline_retry" for e in fleet.events)

    # impossible deadline: retries exhaust, the request is marked
    # failed (observable), and the fleet still drains
    doomed = Request(uid=3, prompt=np.asarray([1, 2, 3], np.int32),
                     max_new_tokens=12)
    eng2 = ServingEngine(model, params, num_slots=1, max_len=32)
    fleet2 = ServingFleet(max_retries=2, backoff_steps=1)
    fleet2.add_engine("m", eng2)
    fleet2.submit("m", doomed, deadline=2)
    for _ in range(300):
        if fleet2.step() == 0:
            break
    assert doomed.failed and not doomed.done
    assert fleet2.stats["failed_requests"] == 1
    assert any(e["event"] == "request_failed" for e in fleet2.events)
    # step-only deadlines never count against the wall-clock bucket
    assert fleet2.stats["deadline_cancels_wall"] == 0
    assert (
        fleet2.stats["deadline_cancels_steps"]
        == fleet2.stats["deadline_cancels"]
    )


def test_fleet_wall_clock_deadline_cancels_and_retries(smoke_model):
    """A wall-clock-seconds deadline trips while the request queues
    behind a slow engine even though no step deadline is set; the cancel
    is attributed to the ``wall`` bucket and the retry still lands."""
    _, model, params = smoke_model
    t = {"now": 0.0}  # injected clock: the test owns time

    long_req = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=12)
    short = Request(uid=2, prompt=np.asarray([4, 5], np.int32),
                    max_new_tokens=2)
    eng = ServingEngine(model, params, num_slots=1, max_len=32)
    fleet = ServingFleet(
        max_retries=3, backoff_steps=2, clock=lambda: t["now"]
    )
    fleet.add_engine("m", eng)
    fleet.submit("m", long_req)  # no deadline at all: immune
    fleet.submit("m", short, deadline_s=1.5)  # seconds only, no steps
    for _ in range(300):
        if not long_req.done:
            t["now"] += 1.0  # each contended step "takes" one second
        if fleet.step() == 0:
            break
    assert long_req.done and short.done and not short.failed
    assert fleet.stats["deadline_cancels"] >= 1
    assert fleet.stats["deadline_cancels_wall"] >= 1
    assert fleet.stats["deadline_cancels_steps"] == 0
    retries = [e for e in fleet.events if e["event"] == "deadline_retry"]
    assert retries and all(e["unit"] == "wall" for e in retries)

    # both limits tripping in the same sweep attribute to "steps"
    # (precedence), and the total still counts the cancel exactly once
    blocker = Request(uid=3, prompt=np.asarray([1, 2, 3], np.int32),
                      max_new_tokens=12)
    both = Request(uid=4, prompt=np.asarray([4, 5], np.int32),
                   max_new_tokens=2)
    eng2 = ServingEngine(model, params, num_slots=1, max_len=32)
    fleet2 = ServingFleet(
        max_retries=0, backoff_steps=1, clock=lambda: t["now"]
    )
    fleet2.add_engine("m", eng2)
    fleet2.submit("m", blocker)
    fleet2.submit("m", both, deadline=0, deadline_s=0.5)
    for _ in range(300):
        t["now"] += 1.0
        if fleet2.step() == 0:
            break
    assert blocker.done and both.failed
    assert fleet2.stats["deadline_cancels"] == 1
    assert fleet2.stats["deadline_cancels_steps"] == 1
    assert fleet2.stats["deadline_cancels_wall"] == 0


def test_census_undegrade_after_clean_windows(smoke_model, smoke_qparams):
    """``undegrade_after=N``: a degraded site whose census stays clean
    for N consecutive windows drops its overrides and re-narrows, with
    dirty windows resetting the streak and low-traffic windows freezing
    it; the removal survives snapshot/restore."""
    _, model, _ = smoke_model
    il = dispatch.IntegerLinConfig(
        policy="sorted_tiled_seq", acc_bits=17, k_tile=64, backend="jnp"
    )
    watch = CensusWatch(
        threshold=0.01, window=1, min_dots=10, undegrade_after=2
    )
    eng = ServingEngine(
        model, smoke_qparams, num_slots=2, max_len=32,
        int_lin=il, census_watch=watch,
    )
    # hot window: w_out saturates and degrades to wide
    eng._census.observe("w_out", 1000, 100)
    eng._check_census()
    assert eng._degraded == {"w_out"}
    assert eng.int_lin.policy_for("w_out") == "wide"

    # clean window: streak advances but N=2 not reached — still degraded
    eng._census.observe("w_out", 1000, 0)
    eng._check_census()
    assert eng._degraded == {"w_out"}
    assert eng._clean_windows["w_out"] == 1

    # low-traffic window (< min_dots): no evidence — streak frozen
    eng._census.observe("w_out", watch.min_dots - 1, 0)
    eng._check_census()
    assert eng._clean_windows["w_out"] == 1

    # dirty window: streak resets, the site stays degraded
    eng._census.observe("w_out", 1000, 500)
    eng._check_census()
    assert eng._degraded == {"w_out"}
    assert eng._clean_windows["w_out"] == 0

    # N consecutive clean windows: the reverse transition fires
    for _ in range(2):
        eng._census.observe("w_out", 1000, 0)
        eng._check_census()
    assert eng._degraded == set()
    assert eng.stats["census_undegrades"] == 1
    assert eng.stats["census_degrades"] == 1
    (ev,) = [e for e in eng.events if e["event"] == "census_undegrade"]
    assert ev["site"] == "w_out" and ev["clean_windows"] == 2
    # overrides dropped: back under the engine-wide narrow config
    assert eng.int_lin.policy_for("w_out") == "sorted_tiled_seq"
    assert ("w_out", "wide") not in eng.int_lin.site_policies

    # a snapshot taken after the un-degrade carries no override, so
    # restoring it never resurrects the wide swap
    snap = eng.snapshot()
    eng.restore(snap)
    assert eng._degraded == set()
    assert eng.int_lin.policy_for("w_out") == "sorted_tiled_seq"


@pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >= 4 devices (fault CI stage)"
)
def test_mesh_member_drop_remesh_recovery(smoke_model, smoke_qparams):
    """Kill a mesh-sharded engine mid-decode, drop half its devices,
    remesh onto the survivors, recover from the snapshot: every token
    stream matches the failure-free run on the original full mesh.
    (The baseline keeps the same mesh: the dynamic-quant absmax
    reduction is mesh-shape-sensitive at the last ulp, so bit-exactness
    is guaranteed against the same starting topology, which is exactly
    the recovery contract.)"""
    from repro.launch.mesh import make_host_serve_mesh, shrink_serve_mesh

    cfg, model, _ = smoke_model
    il = dispatch.IntegerLinConfig(
        policy="sorted_tiled_seq", acc_bits=24, k_tile=64, backend="jnp"
    )

    def mk_reqs():
        rng = np.random.default_rng(1)
        return [
            Request(
                uid=i,
                prompt=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=3,
            )
            for i in range(3)
        ]

    def run(mesh, crash):
        reqs = mk_reqs()
        eng = ServingEngine(
            model, smoke_qparams, num_slots=2, max_len=16,
            int_lin=il, mesh=mesh,
            failure_injector=FailureInjector({4}) if crash else None,
        )
        fleet = ServingFleet(snapshot_every=2 if crash else 0)
        fleet.add_engine("m", eng)
        for r in reqs:
            fleet.submit("m", r)

        def lose_half(fl, err):
            survivors = shrink_serve_mesh(mesh, lost=len(jax.devices()) // 2)
            fl.remesh_engine("m", survivors)

        sup = ServeSupervisor(fleet, on_failure=lose_half if crash else None)
        sup.run()
        assert all(r.done for r in reqs)
        return {r.uid: list(r.output) for r in reqs}, fleet

    base, _ = run(make_host_serve_mesh(), crash=False)
    out, fleet = run(make_host_serve_mesh(), crash=True)
    assert fleet.stats["recoveries"] == 1
    assert any(e["event"] == "remeshed" for e in fleet.events)
    assert out == base
