"""Sharded ``pqs_dot``: multi-device CPU mesh vs single-device reference.

Run with forced host devices (scripts/ci.sh does this as its own shard):

    REPRO_FORCE_MULTIDEVICE=1 python -m pytest tests/test_sharded_dispatch.py

The contract: for every accumulation policy and every sharding layout
(data-only, model-only, full 2-D, degraded/non-dividing), the mesh
execution is BIT-IDENTICAL to the single-device reference — each shard
accumulates its (M_shard, N_shard) block over the whole K axis with the
unmodified single-device routine, so distribution never changes the
narrow-accumulation order. Inside the normal single-device suite this
module self-skips (forcing 8 host devices there would change every
other test's topology).
"""

import os

# opt-in, and only effective before the first jax backend init — the
# flag must not leak a 2-device-topology into the single-device suite
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

if len(jax.devices()) < 2:
    pytest.skip(
        "needs a multi-device backend (XLA_FLAGS="
        "--xla_force_host_platform_device_count=8 before jax init)",
        allow_module_level=True,
    )

from repro.core.dispatch import IntegerLinConfig, pqs_dot  # noqa: E402
from repro.core.qtensor import QTensor, quantize_tree  # noqa: E402

POLICIES = ("wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq")
# ragged shapes on purpose: M=5 does not divide the 4-way data axis and
# N=6 does not divide the 2-way model axis -> sanitize degradation path
SHAPES = ((8, 300, 6), (5, 128, 16), (4, 96, 8))


def _mesh(data, model):
    return jax.make_mesh((data, model), ("data", "model"))


def _xw(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    return x, w


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mesh_shape", [(4, 2), (8, 1), (1, 8), (2, 2)])
def test_sharded_bit_identical(policy, mesh_shape):
    mesh = _mesh(*mesh_shape)
    for i, (m, k, n) in enumerate(SHAPES):
        x, w = _xw(m, k, n, seed=i)
        ref = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=64,
                      backend="jnp")
        out = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=64,
                      backend="jnp", mesh=mesh)
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(out),
            err_msg=f"{policy} mesh={mesh_shape} shape={(m, k, n)}",
        )


def test_sharded_pallas_backend():
    """The interpret-mode Pallas kernels also run inside shard_map."""
    mesh = _mesh(4, 2)
    x, w = _xw(8, 128, 16, seed=3)
    ref = pqs_dot(x, w, acc_bits=14, policy="sorted_tiled_seq", k_tile=64,
                  backend="jnp")
    out = pqs_dot(x, w, acc_bits=14, policy="sorted_tiled_seq", k_tile=64,
                  backend="pallas", block_m=4, block_n=8, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_sharded_census_counts_once():
    """Census counters psum only over the partitioning axes — a dot is
    never double-counted by replicated shards."""
    mesh = _mesh(4, 2)
    x, w = _xw(6, 200, 10, seed=5)
    _, ref = pqs_dot(x, w, acc_bits=16, policy="clip", backend="jnp",
                     with_census=True)
    _, out = pqs_dot(x, w, acc_bits=16, policy="clip", backend="jnp",
                     mesh=mesh, with_census=True)
    for field in ("n_dots", "n_persistent", "n_transient", "n_any"):
        assert int(getattr(out, field)) == int(getattr(ref, field)), field


def test_sharded_under_jit_and_leading_dims():
    mesh = _mesh(2, 4)
    x, w = _xw(12, 96, 8, seed=9)
    x3 = x.reshape(2, 6, 96)
    ref = pqs_dot(x, w, acc_bits=16, policy="sorted", backend="jnp")
    f = jax.jit(lambda a, b: pqs_dot(a, b, acc_bits=16, policy="sorted",
                                     backend="jnp", mesh=mesh))
    out = f(x3, w)
    assert out.shape == (2, 6, 8)
    np.testing.assert_array_equal(np.asarray(out).reshape(12, 8),
                                  np.asarray(ref))


def test_qtensor_param_shardings_on_mesh():
    """QTensor pytrees shard values+scales together through the rules."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import params_shardings

    mesh = _mesh(4, 2)
    params = {
        "layers": {
            "attn": {
                "wq": QTensor(jnp.zeros((4, 128, 256), jnp.int8),
                              jnp.zeros((4, 256)),
                              None),
                "wo": QTensor(jnp.zeros((4, 256, 128), jnp.int8),
                              jnp.zeros((4, 128)),
                              None),
            }
        },
        "norm": jnp.zeros((128,)),
    }
    sh = params_shardings(mesh, params)
    wq = sh["layers"]["attn"]["wq"]
    assert isinstance(wq, QTensor)
    assert wq.values.spec == P(None, "data", "model")
    # scale follows the values' output-channel entry
    assert wq.scale.spec == P(None, "model")
    # out-type projections reverse -> scale rides the data axes
    wo = sh["layers"]["attn"]["wo"]
    assert wo.values.spec == P(None, "model", "data")
    assert wo.scale.spec == P(None, "data")


def test_integer_serving_engine_on_mesh():
    """End-to-end: quantized engine decode with the integer projections
    distributed over the mesh reproduces the single-device outputs."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
    il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24, k_tile=64,
                          backend="jnp")

    def run(mesh):
        rng = np.random.default_rng(1)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=3)
            for i in range(3)
        ]
        eng = ServingEngine(model, qparams, num_slots=2, max_len=16,
                            int_lin=il, mesh=mesh)
        eng.drain(reqs)
        return [r.output for r in reqs]

    assert run(None) == run(_mesh(4, 2))
