"""Sharded ``pqs_dot``: multi-device CPU mesh vs single-device reference.

Run with forced host devices (scripts/ci.sh does this as its own shard):

    REPRO_FORCE_MULTIDEVICE=1 python -m pytest tests/test_sharded_dispatch.py

The contract: for every accumulation policy and every sharding layout
(data-only, model-only, full 2-D, degraded/non-dividing), the mesh
execution is BIT-IDENTICAL to the single-device reference — each shard
accumulates its (M_shard, N_shard) block over the whole K axis with the
unmodified single-device routine, so distribution never changes the
narrow-accumulation order. Inside the normal single-device suite this
module self-skips (forcing 8 host devices there would change every
other test's topology).
"""

import os

# opt-in, and only effective before the first jax backend init — the
# flag must not leak a 2-device-topology into the single-device suite.
# A numeric value > 1 forces that many host devices (scripts/ci.sh uses
# 8); "1" or a non-numeric truthy value keeps the historical 8.
if os.environ.get("REPRO_FORCE_MULTIDEVICE") and (
    "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    _v = os.environ["REPRO_FORCE_MULTIDEVICE"]
    _n = int(_v) if _v.isdigit() and int(_v) > 1 else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

if len(jax.devices()) < 2:
    pytest.skip(
        "needs a multi-device backend (XLA_FLAGS="
        "--xla_force_host_platform_device_count=8 before jax init)",
        allow_module_level=True,
    )

from repro.core.dispatch import IntegerLinConfig, pqs_dot  # noqa: E402
from repro.core.qtensor import QTensor, quantize_tree  # noqa: E402

POLICIES = ("wide", "clip", "wrap", "sorted", "sorted_tiled",
            "sorted_tiled_seq")
# ragged shapes on purpose: M=5 does not divide the 4-way data axis and
# N=6 does not divide the 2-way model axis -> sanitize degradation path
SHAPES = ((8, 300, 6), (5, 128, 16), (4, 96, 8))


def _mesh(data, model):
    return jax.make_mesh((data, model), ("data", "model"))


def _xw(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    return x, w


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mesh_shape", [(4, 2), (8, 1), (1, 8), (2, 2)])
def test_sharded_bit_identical(policy, mesh_shape):
    mesh = _mesh(*mesh_shape)
    for i, (m, k, n) in enumerate(SHAPES):
        x, w = _xw(m, k, n, seed=i)
        ref = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=64,
                      backend="jnp")
        out = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=64,
                      backend="jnp", mesh=mesh)
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(out),
            err_msg=f"{policy} mesh={mesh_shape} shape={(m, k, n)}",
        )


def test_sharded_pallas_backend():
    """The interpret-mode Pallas kernels also run inside shard_map."""
    mesh = _mesh(4, 2)
    x, w = _xw(8, 128, 16, seed=3)
    ref = pqs_dot(x, w, acc_bits=14, policy="sorted_tiled_seq", k_tile=64,
                  backend="jnp")
    out = pqs_dot(x, w, acc_bits=14, policy="sorted_tiled_seq", k_tile=64,
                  backend="pallas", block_m=4, block_n=8, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_sharded_census_counts_once():
    """Census counters psum only over the partitioning axes — a dot is
    never double-counted by replicated shards."""
    mesh = _mesh(4, 2)
    x, w = _xw(6, 200, 10, seed=5)
    _, ref = pqs_dot(x, w, acc_bits=16, policy="clip", backend="jnp",
                     with_census=True)
    _, out = pqs_dot(x, w, acc_bits=16, policy="clip", backend="jnp",
                     mesh=mesh, with_census=True)
    for field in ("n_dots", "n_persistent", "n_transient", "n_any"):
        assert int(getattr(out, field)) == int(getattr(ref, field)), field


def test_sharded_under_jit_and_leading_dims():
    mesh = _mesh(2, 4)
    x, w = _xw(12, 96, 8, seed=9)
    x3 = x.reshape(2, 6, 96)
    ref = pqs_dot(x, w, acc_bits=16, policy="sorted", backend="jnp")
    f = jax.jit(lambda a, b: pqs_dot(a, b, acc_bits=16, policy="sorted",
                                     backend="jnp", mesh=mesh))
    out = f(x3, w)
    assert out.shape == (2, 6, 8)
    np.testing.assert_array_equal(np.asarray(out).reshape(12, 8),
                                  np.asarray(ref))


CENSUS_FIELDS = ("n_dots", "n_persistent", "n_transient", "n_any",
                 "n_combine")


def _mesh3(data, model, k):
    return jax.make_mesh((data, model, k), ("data", "model", "k"))


@pytest.mark.parametrize("policy", POLICIES)
def test_kshard_mesh_matches_oracle(policy):
    """K partitioned across a mesh axis: each device accumulates its
    K/S slice, partials all-gather and tree-combine — bit-identical to
    the single-device k_shards=S hierarchy, census (incl. combine
    steps) equal. M/N shard alongside on their own axes."""
    mesh = _mesh3(2, 2, 2)
    for i, (m, k, n) in enumerate(((3, 500, 5), (2, 96, 4))):
        x, w = _xw(m, k, n, seed=40 + i)
        ref, cr = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=32,
                          backend="jnp", k_shards=2, with_census=True)
        out, co = pqs_dot(x, w, acc_bits=14, policy=policy, k_tile=32,
                          backend="jnp", mesh=mesh, k_axis="k",
                          with_census=True)
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(out),
            err_msg=f"{policy} shape={(m, k, n)}",
        )
        for field in CENSUS_FIELDS:
            assert int(getattr(cr, field)) == int(getattr(co, field)), (
                policy, field)


@pytest.mark.parametrize("policy", POLICIES)
def test_kshard_mesh_nm_storage(policy):
    """The K-shard sweep on N:M compressed storage: compressed slabs
    shard whole groups over the K axis, identical to the single-device
    nm hierarchy (which itself equals decompress-then-dense at aligned
    boundaries — tests/test_property_parity.py)."""
    from repro.core.pruning import nm_compress, nm_prune_mask

    mesh = _mesh3(2, 2, 2)
    n_keep, mg = 4, 16
    m, k, n = 3, 192, 4
    rng = np.random.default_rng(7)
    wd = rng.integers(-127, 127, (n, k)).astype(np.int8)
    mask = np.asarray(
        nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
    wd = (wd * mask).astype(np.int8)
    vals, idx = nm_compress(wd, n_keep, mg)
    vals, idx = jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    kw = dict(storage="nm", m_group=mg, acc_bits=14, policy=policy,
              k_tile=32, backend="jnp", with_census=True)
    ref, cr = pqs_dot(x, (vals, idx), k_shards=2, **kw)
    out, co = pqs_dot(x, (vals, idx), mesh=mesh, k_axis="k", **kw)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                  err_msg=policy)
    for field in CENSUS_FIELDS:
        assert int(getattr(cr, field)) == int(getattr(co, field)), (
            policy, field)


@pytest.mark.parametrize("policy", POLICIES)
def test_kshard_mesh_long_k_past_stream_bound(policy):
    """The acceptance case: total K = 2 x MAX_STREAM_K — past what any
    single compiled sort kernel may stream — split across the K axis so
    each device holds exactly MAX_STREAM_K. Bit-identical to the
    hierarchical jnp oracle, combine census reported."""
    from repro.kernels.ops import MAX_STREAM_K

    mesh = _mesh3(1, 2, 2)
    k = 2 * MAX_STREAM_K
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(-127, 127, (2, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (4, k)), jnp.int8)
    ref, cr = pqs_dot(x, w, acc_bits=20, policy=policy, k_tile=256,
                      backend="jnp", k_shards=2, with_census=True)
    out, co = pqs_dot(x, w, acc_bits=20, policy=policy, k_tile=256,
                      backend="jnp", mesh=mesh, k_axis="k",
                      with_census=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                  err_msg=policy)
    for field in CENSUS_FIELDS:
        assert int(getattr(cr, field)) == int(getattr(co, field)), (
            policy, field)


def test_kshard_mesh_long_k_nm_storage():
    """Long-K acceptance on compressed storage (one policy end-to-end:
    sorted_tiled_seq, the production default)."""
    from repro.core.pruning import nm_compress, nm_prune_mask
    from repro.kernels.ops import MAX_STREAM_K

    mesh = _mesh3(1, 2, 2)
    n_keep, mg = 4, 16
    k = 2 * MAX_STREAM_K
    rng = np.random.default_rng(17)
    wd = rng.integers(-127, 127, (2, k)).astype(np.int8)
    mask = np.asarray(
        nm_prune_mask(jnp.asarray(wd, jnp.float32), n_keep, mg))
    wd = (wd * mask).astype(np.int8)
    vals, idx = nm_compress(wd, n_keep, mg)
    vals, idx = jnp.asarray(vals, jnp.int8), jnp.asarray(idx, jnp.int32)
    x = jnp.asarray(rng.integers(-127, 127, (2, k)), jnp.int8)
    kw = dict(storage="nm", m_group=mg, acc_bits=20,
              policy="sorted_tiled_seq", k_tile=256, backend="jnp",
              with_census=True)
    ref, cr = pqs_dot(x, (vals, idx), k_shards=2, **kw)
    out, co = pqs_dot(x, (vals, idx), mesh=mesh, k_axis="k", **kw)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    for field in CENSUS_FIELDS:
        assert int(getattr(cr, field)) == int(getattr(co, field)), field


@pytest.mark.parametrize("policy", POLICIES)
def test_kshard_mesh_four_way_butterfly(policy):
    """S=4: the exchange really is a multi-level butterfly (two ppermute
    rounds), still bit-identical to the single-device hierarchy with the
    exact census decomposition."""
    mesh = _mesh3(1, 2, 4)
    x, w = _xw(3, 448, 4, seed=51)
    kw = dict(acc_bits=14, policy=policy, k_tile=32, backend="jnp",
              with_census=True)
    ref, cr = pqs_dot(x, w, k_shards=4, **kw)
    out, co = pqs_dot(x, w, mesh=mesh, k_axis="k", **kw)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                  err_msg=policy)
    for field in CENSUS_FIELDS:
        assert int(getattr(cr, field)) == int(getattr(co, field)), (
            policy, field)


@pytest.mark.parametrize("policy", ("wide", "clip", "sorted_tiled_seq"))
def test_defer_combine_matches_eager(policy):
    """defer_combine=True: the PendingCombine's .combine() reproduces
    the eager K-sharded result exactly — census included — on both the
    mesh-less hierarchy and the mesh exchange, in and out of jit."""
    mesh = _mesh3(1, 2, 4)
    x, w = _xw(3, 448, 4, seed=61)
    kw = dict(acc_bits=14, policy=policy, k_tile=32, backend="jnp",
              with_census=True)
    ref, cr = pqs_dot(x, w, k_shards=4, **kw)

    for extra in (dict(k_shards=4), dict(mesh=mesh, k_axis="k")):
        out, co = pqs_dot(x, w, defer_combine=True, **extra, **kw).combine()
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                      err_msg=f"{policy} {extra.keys()}")
        for field in CENSUS_FIELDS:
            assert int(getattr(cr, field)) == int(getattr(co, field)), (
                policy, field)

    # both phases trace into one jitted computation — the overlap form
    f = jax.jit(
        lambda a, b: pqs_dot(
            a, b, mesh=mesh, k_axis="k", defer_combine=True,
            acc_bits=14, policy=policy, k_tile=32, backend="jnp",
        ).combine()
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(f(x, w)))


def test_defer_combine_needs_k_sharding():
    x, w = _xw(2, 64, 3, seed=1)
    with pytest.raises(ValueError, match="K-sharded"):
        pqs_dot(x, w, defer_combine=True, backend="jnp")


def test_overlap_combine_engine_bit_identical():
    """IntegerLinConfig(overlap_combine=True): the engine's K-sharded
    decode routes through the deferred two-phase combine and stays
    bit-identical to the eager path."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)

    def run(overlap):
        il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                              k_tile=64, backend="jnp", k_shards=2,
                              k_axis="k", k_shard_min_k=64,
                              overlap_combine=overlap)
        rng = np.random.default_rng(4)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=3)
            for i in range(3)
        ]
        eng = ServingEngine(model, qparams, num_slots=2, max_len=16,
                            int_lin=il, mesh=_mesh3(2, 2, 2))
        eng.drain(reqs)
        return [r.output for r in reqs]

    assert run(False) == run(True)


def test_cache_pool_sharded_decode_bit_identical():
    """cache_shardings(serve_mode=True) on a real 8-device mesh: the
    paged KV pool page-sharded over the data axis (each member owns a
    page shard) decodes bit-identically to serve_mode=False's
    replicated pool under the same mesh placement. serve_mode only
    toggles the pool-axis spec, and that axis is pure indirection
    (gather/scatter through the page table, no arithmetic) — so page
    sharding must never change a bit. (The head_dim "model" entry,
    common to both modes, is excluded from the contract: re-tiling a
    float contraction may legally reassociate.)"""
    from repro.configs import get_config
    from repro.launch.sharding import cache_shardings, place_tree
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = _mesh(4, 2)

    def run(serve_mode):
        rng = np.random.default_rng(3)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=3)
            for i in range(3)
        ]
        eng = ServingEngine(model, params, num_slots=2, max_len=16,
                            page_size=8, num_pages=8)
        sh = cache_shardings(mesh, eng.caches, serve_mode=serve_mode)
        specs = [
            s.spec for s in jax.tree_util.tree_leaves(
                sh, is_leaf=lambda l: hasattr(l, "spec"))
        ]
        if serve_mode:  # the pool axis really is split over "data"
            assert any("data" in str(sp) for sp in specs), (
                "serve_mode placed no pool shard")
        else:
            assert not any("data" in str(sp) for sp in specs)
        eng.caches = place_tree(eng.caches, sh)
        eng.drain(reqs)
        return [list(r.output) for r in reqs]

    assert run(False) == run(True)


def test_kshard_mesh_validation():
    x, w = _xw(2, 64, 3, seed=1)
    mesh = _mesh(4, 2)
    with pytest.raises(ValueError, match="k_axis"):
        pqs_dot(x, w, mesh=mesh, k_shards=2)  # mesh needs a named K axis
    with pytest.raises(ValueError, match="not on the mesh"):
        pqs_dot(x, w, mesh=mesh, k_axis="k")
    mesh3 = _mesh3(2, 2, 2)
    with pytest.raises(ValueError, match="k_shards"):
        pqs_dot(x, w, mesh=mesh3, k_axis="k", k_shards=4)  # axis is 2-way


def test_kshard_integer_serving_engine():
    """End-to-end: the engine's integer decode with long-K projections
    opted into K-sharding on the serving mesh reproduces the
    single-device K-sharded outputs (and the full-K outputs of layers
    below the threshold are untouched by construction)."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)

    def run(mesh, k_axis):
        il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24,
                              k_tile=64, backend="jnp", k_shards=2,
                              k_axis=k_axis, k_shard_min_k=64)
        rng = np.random.default_rng(2)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=3)
            for i in range(3)
        ]
        eng = ServingEngine(model, qparams, num_slots=2, max_len=16,
                            int_lin=il, mesh=mesh)
        eng.drain(reqs)
        return [r.output for r in reqs]

    assert run(None, None) == run(_mesh3(2, 2, 2), "k")


def test_kshard_min_k_gate_applies_with_axis_only():
    """k_shard_min_k must gate the hierarchy even when the shard count
    is implied by the mesh axis (k_axis= with k_shards=None): short-K
    projections keep the bit-identical full-K path."""
    from repro.core.dispatch import qtensor_dot
    from repro.core.qtensor import quantize_weight

    rng = np.random.default_rng(21)
    w = jnp.asarray(rng.normal(size=(64, 24)), jnp.float32) * 0.1
    x = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    qt = quantize_weight(w, bits=8)
    mesh = _mesh3(2, 2, 2)
    base = dict(policy="sorted_tiled_seq", acc_bits=12, k_tile=16,
                backend="jnp", mesh=mesh)
    full = qtensor_dot(x, qt, IntegerLinConfig(**base))
    gated = qtensor_dot(x, qt, IntegerLinConfig(
        k_axis="k", k_shard_min_k=4096, **base))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(gated))
    # sanity: below the threshold the hierarchy actually engages (a
    # 12-bit register saturates differently under the combine tree)
    sharded = qtensor_dot(x, qt, IntegerLinConfig(
        k_axis="k", k_shard_min_k=0, **base))
    assert sharded.shape == full.shape


def test_kshard_param_placement():
    """params_shardings(k_axis=) puts long-K QTensor leaves' input dim
    on the K axis (serve mode) so the K-sharded dot finds its weight
    shards resident; short-K leaves keep the plain rule."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import params_shardings

    mesh = _mesh3(2, 2, 2)
    params = {
        "attn": {
            "wq": QTensor(jnp.zeros((256, 128), jnp.int8),
                          jnp.zeros((128,)), None),
            "small": QTensor(jnp.zeros((64, 128), jnp.int8),
                             jnp.zeros((128,)), None),
        },
    }
    sh = params_shardings(mesh, params, serve_mode=True, k_axis="k",
                          k_shard_min_k=256)
    assert sh["attn"]["wq"].values.spec == P("k", "model")
    assert sh["attn"]["small"].values.spec == P(None, "model")
    # scales stay on the out entry either way
    assert sh["attn"]["wq"].scale.spec == P("model")


def test_qtensor_param_shardings_on_mesh():
    """QTensor pytrees shard values+scales together through the rules."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import params_shardings

    mesh = _mesh(4, 2)
    params = {
        "layers": {
            "attn": {
                "wq": QTensor(jnp.zeros((4, 128, 256), jnp.int8),
                              jnp.zeros((4, 256)),
                              None),
                "wo": QTensor(jnp.zeros((4, 256, 128), jnp.int8),
                              jnp.zeros((4, 128)),
                              None),
            }
        },
        "norm": jnp.zeros((128,)),
    }
    sh = params_shardings(mesh, params)
    wq = sh["layers"]["attn"]["wq"]
    assert isinstance(wq, QTensor)
    assert wq.values.spec == P(None, "data", "model")
    # scale follows the values' output-channel entry
    assert wq.scale.spec == P(None, "model")
    # out-type projections reverse -> scale rides the data axes
    wo = sh["layers"]["attn"]["wo"]
    assert wo.values.spec == P(None, "model", "data")
    assert wo.scale.spec == P(None, "data")


def test_integer_serving_engine_on_mesh():
    """End-to-end: quantized engine decode with the integer projections
    distributed over the mesh reproduces the single-device outputs."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
    il = IntegerLinConfig(policy="sorted_tiled_seq", acc_bits=24, k_tile=64,
                          backend="jnp")

    def run(mesh):
        rng = np.random.default_rng(1)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=3)
            for i in range(3)
        ]
        eng = ServingEngine(model, qparams, num_slots=2, max_len=16,
                            int_lin=il, mesh=mesh)
        eng.drain(reqs)
        return [r.output for r in reqs]

    assert run(None) == run(_mesh(4, 2))
