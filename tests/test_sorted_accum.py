"""Tests for the sorted dot product (paper Alg. 1 / §3.2 / §6).

The central invariant (paper §3.2): if the exact dot-product result fits
the accumulator, there exists a summation order with no intermediate
overflow — and Algorithm 1 finds one.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.overflow import transient_survivors
from repro.core.quant import qrange
from repro.core.sorted_accum import (
    alg1_sorted_dot,
    combine_schedule,
    combine_step,
    monotone_accumulate,
    pairwise_round,
    sorted_order,
    tiled_seq_order,
    tiled_sorted_order,
    tree_combine,
)


def test_pairwise_round_preserves_sum(rng):
    p = jnp.asarray(rng.integers(-1000, 1000, (16, 64)), jnp.int32)
    out = pairwise_round(p)
    np.testing.assert_array_equal(
        np.asarray(p.sum(-1)), np.asarray(out.sum(-1))
    )


def test_alg1_exact_sum(rng):
    p = jnp.asarray(rng.integers(-(2**20), 2**20, (8, 128)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(alg1_sorted_dot(p)), np.asarray(p.sum(-1))
    )


def test_monotone_accumulate_wide_is_exact(rng):
    p = jnp.asarray(rng.integers(-100, 100, (4, 32)), jnp.int32)
    acc, ovf = monotone_accumulate(p, acc_bits=30)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(p.sum(-1)))
    assert not bool(ovf.any())


def test_saturation_clips():
    p = jnp.asarray([[100, 100, 100, -100]], jnp.int32)  # 8-bit: max 127
    acc, ovf = monotone_accumulate(p, acc_bits=8, saturate=True)
    # 100 -> 127(sat from 200) -> 127(sat) -> 27
    assert int(acc[0]) == 27 and bool(ovf[0])


def test_wraparound():
    p = jnp.asarray([[127, 1]], jnp.int32)
    acc, ovf = monotone_accumulate(p, acc_bits=8, saturate=False)
    assert int(acc[0]) == -128 and bool(ovf[0])


def _transient_case():
    """A dot product whose exact sum fits 8 bits but whose natural order
    transiently overflows: [120, 60, -120] -> runs 120, 180(!), 60."""
    return jnp.asarray([[120, 60, -120]], jnp.int32)


def test_sorting_fixes_transient_case():
    p = _transient_case()
    qmin, qmax = qrange(8)
    run_nat = jnp.cumsum(p, -1)
    assert bool((run_nat > qmax).any())  # natural order overflows
    ordered = sorted_order(p, rounds=1)
    acc, ovf = monotone_accumulate(ordered, 8, saturate=True)
    assert int(acc[0]) == 60 and not bool(ovf[0])


@settings(max_examples=200, deadline=None)
@given(
    st.lists(st.integers(-(2**14), 2**14), min_size=2, max_size=64),
    st.integers(10, 16),
)
def test_property_alg1_eliminates_transients(vals, acc_bits):
    """THE paper invariant: if the final sum fits p bits, Algorithm 1's
    ordering never transiently overflows."""
    p = jnp.asarray([vals], jnp.int32)
    qmin, qmax = qrange(acc_bits)
    total = int(np.sum(vals))
    if not (qmin <= total <= qmax):
        return  # persistent: out of scope for this invariant
    # run the full multi-round algorithm, tracking every partial sum of
    # the final ordering
    ordered = sorted_order(p, rounds=int(np.ceil(np.log2(len(vals)))) + 1)
    run = np.cumsum(np.asarray(ordered)[0])
    assert run[-1] == total
    assert run.max() <= qmax and run.min() >= qmin, (
        f"transient survived: {vals} -> {run}"
    )


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-(2**18), 2**18), min_size=4, max_size=96))
def test_property_orders_preserve_sum(vals):
    pad = (-len(vals)) % 4
    p = jnp.asarray([vals + [0] * pad], jnp.int32)
    for order in (
        sorted_order(p, 1),
        sorted_order(p, 2),
        tiled_seq_order(p, 4, 1),
        tiled_sorted_order(p, 4, 2),
    ):
        assert int(order.sum()) == int(p.sum())


def test_tiled_orders_shapes(rng):
    p = jnp.asarray(rng.integers(-50, 50, (3, 5, 512)), jnp.int32)
    assert tiled_seq_order(p, 128).shape == p.shape
    assert tiled_sorted_order(p, 128).shape == p.shape
    with pytest.raises(ValueError):
        tiled_seq_order(p, 100)


def test_single_round_resolves_most_transients(rng):
    """Paper §3.2: one sorting round resolves the vast majority of
    transient overflows for NN-like (symmetric) products."""
    w = rng.normal(size=(64, 256))
    x = np.abs(rng.normal(size=(256,)))  # post-ReLU half-normal
    wq = np.clip(np.round(w / np.abs(w).max() * 127), -127, 127)
    xq = np.clip(np.round(x / x.max() * 127), 0, 127)
    prods = jnp.asarray(wq * xq, jnp.int32)
    acc_bits = 16
    nat = int(transient_survivors(prods, acc_bits, policy="natural"))
    srt = int(transient_survivors(prods, acc_bits, policy="sorted", rounds=1))
    assert nat > 0, "test setup should produce transient overflows"
    assert srt <= nat * 0.05  # >=95% resolved by a single round


def test_combine_schedule_is_log2_butterfly():
    """log2(S) levels of (i, i^2^l) pairs, ppermute-shaped; non-power-
    of-two shard counts are rejected (the mesh path falls back to
    gather + tree_combine for those)."""
    sched = combine_schedule(8)
    assert len(sched) == 3  # log2(8), not S-1: the interconnect win
    for level, perm in enumerate(sched):
        assert sorted(perm) == sorted(
            (i, i ^ (1 << level)) for i in range(8)
        )
        # a valid ppermute permutation: every member sends and receives
        assert sorted(s for s, _ in perm) == list(range(8))
        assert sorted(d for _, d in perm) == list(range(8))
    assert combine_schedule(1) == []
    for bad in (0, 3, 6, 12):
        with pytest.raises(ValueError):
            combine_schedule(bad)


def test_tree_combine_matches_schedule_walk(rng):
    """tree_combine's local halving walk IS combine_schedule executed
    member-wise: simulating the ppermute exchanges reproduces the same
    register on every member and the same per-level hits."""
    p = jnp.asarray(rng.integers(-(2**14), 2**14, (5, 8)), jnp.int32)
    out, novf = tree_combine(p, acc_bits=16, policy="clip")
    vals = [p[..., i] for i in range(8)]
    hits = jnp.zeros(p.shape[:-1], jnp.int32)
    for level, perm in enumerate(combine_schedule(8)):
        recv = {dst: vals[src] for src, dst in perm}
        merged = []
        for i in range(8):
            m, h = combine_step(vals[i], recv[i], 16, "clip")
            merged.append(m)
            if i % (1 << (level + 1)) == 0:  # count each merge once
                hits = hits + h.astype(jnp.int32)
        vals = merged
    for i in range(8):  # result replicated across all members
        np.testing.assert_array_equal(np.asarray(vals[i]), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(hits), np.asarray(novf))


def test_tree_combine_carrier_guard_rejects_wide_carrier():
    """Satellite of monotone_accumulate's acc_bits>30 raise: the combine
    carrier is int32 too, so the same static guard applies."""
    p = jnp.ones((2, 4), jnp.int32)
    for bad_bits in (31, 32, 40):
        with pytest.raises(ValueError, match="int32 carrier"):
            tree_combine(p, acc_bits=bad_bits)
        with pytest.raises(ValueError, match="int32 carrier"):
            combine_step(p[..., 0], p[..., 1], acc_bits=bad_bits)
    with pytest.raises(ValueError, match="int32 carrier"):
        monotone_accumulate(p, acc_bits=31)


def test_tree_combine_wide_flags_carrier_wrap():
    """The bug this PR flushed out: adversarial same-sign near-2**31
    partials silently wrapped the int32 carrier under ``wide`` and the
    census read zero. Now the wrap is detected and counted, while every
    valid-regime combine still reports zero."""
    big = np.int32(2**30 + 11)  # 2 of these overflow int32
    p = jnp.asarray([[big, big, -big, jnp.int32(-5)]], jnp.int32)
    out, novf = tree_combine(p, acc_bits=30, policy="wide")
    assert int(novf[0]) > 0  # the (big, big) merge wrapped the carrier
    # negative-side wrap detected too
    q = jnp.asarray([[-big, -big]], jnp.int32)
    _, novf_n = tree_combine(q, acc_bits=30, policy="wide")
    assert int(novf_n[0]) == 1
    # valid regime (int8 products, K <= 2**17 per shard): always zero,
    # even with every partial at the regime's extreme
    ext = jnp.int32(127 * 127 * (2**17) // 4)
    r = jnp.full((3, 4), ext, jnp.int32)
    exact, novf_ok = tree_combine(r, acc_bits=30, policy="wide")
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(4 * ext))
    assert int(np.asarray(novf_ok).sum()) == 0
    # mixed-sign adds can never wrap two's complement: not flagged
    s = jnp.asarray([[jnp.int32(2**31 - 1), jnp.int32(-1)]], jnp.int32)
    _, novf_m = tree_combine(s, acc_bits=30, policy="wide")
    assert int(novf_m[0]) == 0


def test_tree_combine_pads_non_power_of_two_exactly(rng):
    """Any shard count: zero-padding to the next power of two is
    additively inert under every register rule."""
    for policy in ("wide", "clip", "wrap"):
        for s in (1, 3, 5, 6, 7):
            p = jnp.asarray(
                rng.integers(-(2**12), 2**12, (4, s)), jnp.int32
            )
            out, _ = tree_combine(p, acc_bits=16, policy=policy)
            pad = jnp.pad(p, ((0, 0), (0, 8 - s)))
            out8, _ = tree_combine(pad, acc_bits=16, policy=policy)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(out8))


def test_tiled_sort_beats_natural_and_interleave_beats_seq(rng):
    """Paper §6 claim + our beyond-paper refinement ordering."""
    w = rng.normal(size=(256, 1024))
    x = np.abs(rng.normal(size=(1024,)))
    wq = np.clip(np.round(w / np.abs(w).max() * 127), -127, 127)
    xq = np.clip(np.round(x / x.max() * 127), 0, 127)
    prods = jnp.asarray(wq * xq, jnp.int32)
    acc_bits = 17
    nat = int(transient_survivors(prods, acc_bits, policy="natural"))
    seq = int(
        transient_survivors(prods, acc_bits, policy="sorted_tiled_seq",
                            k_tile=256)
    )
    two = int(
        transient_survivors(prods, acc_bits, policy="sorted_tiled",
                            k_tile=256)
    )
    full = int(transient_survivors(prods, acc_bits, policy="sorted", rounds=1))
    assert nat > 0
    assert seq < nat  # paper §6: tile-local sorting reduces transients
    # beyond-paper: sum-ranked tile interleave recovers (or beats)
    # full-sort quality while staying tile-local (EXPERIMENTS.md §Tiled)
    assert two <= seq
    assert two <= max(full, 1)
