"""Tests for the sorted dot product (paper Alg. 1 / §3.2 / §6).

The central invariant (paper §3.2): if the exact dot-product result fits
the accumulator, there exists a summation order with no intermediate
overflow — and Algorithm 1 finds one.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings
from _hypothesis_shim import strategies as st

from repro.core.overflow import transient_survivors
from repro.core.quant import qrange
from repro.core.sorted_accum import (
    alg1_sorted_dot,
    monotone_accumulate,
    pairwise_round,
    sorted_order,
    tiled_seq_order,
    tiled_sorted_order,
)


def test_pairwise_round_preserves_sum(rng):
    p = jnp.asarray(rng.integers(-1000, 1000, (16, 64)), jnp.int32)
    out = pairwise_round(p)
    np.testing.assert_array_equal(
        np.asarray(p.sum(-1)), np.asarray(out.sum(-1))
    )


def test_alg1_exact_sum(rng):
    p = jnp.asarray(rng.integers(-(2**20), 2**20, (8, 128)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(alg1_sorted_dot(p)), np.asarray(p.sum(-1))
    )


def test_monotone_accumulate_wide_is_exact(rng):
    p = jnp.asarray(rng.integers(-100, 100, (4, 32)), jnp.int32)
    acc, ovf = monotone_accumulate(p, acc_bits=30)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(p.sum(-1)))
    assert not bool(ovf.any())


def test_saturation_clips():
    p = jnp.asarray([[100, 100, 100, -100]], jnp.int32)  # 8-bit: max 127
    acc, ovf = monotone_accumulate(p, acc_bits=8, saturate=True)
    # 100 -> 127(sat from 200) -> 127(sat) -> 27
    assert int(acc[0]) == 27 and bool(ovf[0])


def test_wraparound():
    p = jnp.asarray([[127, 1]], jnp.int32)
    acc, ovf = monotone_accumulate(p, acc_bits=8, saturate=False)
    assert int(acc[0]) == -128 and bool(ovf[0])


def _transient_case():
    """A dot product whose exact sum fits 8 bits but whose natural order
    transiently overflows: [120, 60, -120] -> runs 120, 180(!), 60."""
    return jnp.asarray([[120, 60, -120]], jnp.int32)


def test_sorting_fixes_transient_case():
    p = _transient_case()
    qmin, qmax = qrange(8)
    run_nat = jnp.cumsum(p, -1)
    assert bool((run_nat > qmax).any())  # natural order overflows
    ordered = sorted_order(p, rounds=1)
    acc, ovf = monotone_accumulate(ordered, 8, saturate=True)
    assert int(acc[0]) == 60 and not bool(ovf[0])


@settings(max_examples=200, deadline=None)
@given(
    st.lists(st.integers(-(2**14), 2**14), min_size=2, max_size=64),
    st.integers(10, 16),
)
def test_property_alg1_eliminates_transients(vals, acc_bits):
    """THE paper invariant: if the final sum fits p bits, Algorithm 1's
    ordering never transiently overflows."""
    p = jnp.asarray([vals], jnp.int32)
    qmin, qmax = qrange(acc_bits)
    total = int(np.sum(vals))
    if not (qmin <= total <= qmax):
        return  # persistent: out of scope for this invariant
    # run the full multi-round algorithm, tracking every partial sum of
    # the final ordering
    ordered = sorted_order(p, rounds=int(np.ceil(np.log2(len(vals)))) + 1)
    run = np.cumsum(np.asarray(ordered)[0])
    assert run[-1] == total
    assert run.max() <= qmax and run.min() >= qmin, (
        f"transient survived: {vals} -> {run}"
    )


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-(2**18), 2**18), min_size=4, max_size=96))
def test_property_orders_preserve_sum(vals):
    pad = (-len(vals)) % 4
    p = jnp.asarray([vals + [0] * pad], jnp.int32)
    for order in (
        sorted_order(p, 1),
        sorted_order(p, 2),
        tiled_seq_order(p, 4, 1),
        tiled_sorted_order(p, 4, 2),
    ):
        assert int(order.sum()) == int(p.sum())


def test_tiled_orders_shapes(rng):
    p = jnp.asarray(rng.integers(-50, 50, (3, 5, 512)), jnp.int32)
    assert tiled_seq_order(p, 128).shape == p.shape
    assert tiled_sorted_order(p, 128).shape == p.shape
    with pytest.raises(ValueError):
        tiled_seq_order(p, 100)


def test_single_round_resolves_most_transients(rng):
    """Paper §3.2: one sorting round resolves the vast majority of
    transient overflows for NN-like (symmetric) products."""
    w = rng.normal(size=(64, 256))
    x = np.abs(rng.normal(size=(256,)))  # post-ReLU half-normal
    wq = np.clip(np.round(w / np.abs(w).max() * 127), -127, 127)
    xq = np.clip(np.round(x / x.max() * 127), 0, 127)
    prods = jnp.asarray(wq * xq, jnp.int32)
    acc_bits = 16
    nat = int(transient_survivors(prods, acc_bits, policy="natural"))
    srt = int(transient_survivors(prods, acc_bits, policy="sorted", rounds=1))
    assert nat > 0, "test setup should produce transient overflows"
    assert srt <= nat * 0.05  # >=95% resolved by a single round


def test_tiled_sort_beats_natural_and_interleave_beats_seq(rng):
    """Paper §6 claim + our beyond-paper refinement ordering."""
    w = rng.normal(size=(256, 1024))
    x = np.abs(rng.normal(size=(1024,)))
    wq = np.clip(np.round(w / np.abs(w).max() * 127), -127, 127)
    xq = np.clip(np.round(x / x.max() * 127), 0, 127)
    prods = jnp.asarray(wq * xq, jnp.int32)
    acc_bits = 17
    nat = int(transient_survivors(prods, acc_bits, policy="natural"))
    seq = int(
        transient_survivors(prods, acc_bits, policy="sorted_tiled_seq",
                            k_tile=256)
    )
    two = int(
        transient_survivors(prods, acc_bits, policy="sorted_tiled",
                            k_tile=256)
    )
    full = int(transient_survivors(prods, acc_bits, policy="sorted", rounds=1))
    assert nat > 0
    assert seq < nat  # paper §6: tile-local sorting reduces transients
    # beyond-paper: sum-ranked tile interleave recovers (or beats)
    # full-sort quality while staying tile-local (EXPERIMENTS.md §Tiled)
    assert two <= seq
    assert two <= max(full, 1)
