"""Two-pass K-streaming global-sort pipeline (kernels/sorted_stream.py).

The contract: bit-identical to the jnp oracle (core.overflow.accumulate)
for both global-permutation policies at ANY K — including K well above
the legacy one-pass kernel's MAX_RESIDENT_K — and identical to the old
one-pass sort_matmul wherever that still runs. All Pallas execution is
interpret mode (CPU container); the semantics are mode-independent.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import overflow
from repro.core.dispatch import pqs_dot
from repro.core.sorted_accum import pair_permutation, tiled_sorted_order
from repro.kernels import ops
from repro.kernels import sorted_matmul as sm
from repro.kernels import sorted_stream as ss


def _xw(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    return x, w


def _oracle(x, w, acc_bits, policy, k_tile, rounds=1):
    prods = overflow.partial_products(w, x)
    return np.asarray(overflow.accumulate(prods, acc_bits, policy, k_tile,
                                          rounds))


@pytest.mark.parametrize("policy", ["sorted", "sorted_tiled"])
@pytest.mark.parametrize("acc_bits", [8, 12, 16])
def test_two_pass_matches_oracle_small(policy, acc_bits):
    """Pre-padded small shapes, even/odd/single tile counts, rounds 1-2."""
    for k, kt in ((256, 64), (192, 64), (64, 64), (128, 32)):
        if policy == "sorted" and k & (k - 1):
            continue  # sorted needs pow2 K at the kernel layer
        x, w = _xw(8, k, 8, seed=acc_bits + k)
        for rounds in (1, 2):
            got = ss.stream_sort_matmul(
                x, w, policy=policy, acc_bits=acc_bits, k_tile=kt,
                rounds=rounds, bm=4, bn=8, interpret=True,
            )
            np.testing.assert_array_equal(
                np.asarray(got), _oracle(x, w, acc_bits, policy, kt, rounds),
                err_msg=f"{policy} k={k} kt={kt} rounds={rounds}",
            )


@pytest.mark.parametrize("policy", ["sorted", "sorted_tiled"])
def test_two_pass_matches_oracle_beyond_resident_k(policy):
    """The headline: exactness at K above the old compiled-kernel bound."""
    k = 8192 if policy == "sorted" else 4608  # both > MAX_RESIDENT_K
    assert ops.padded_k(k, policy, 256) > ops.MAX_RESIDENT_K
    x, w = _xw(4, k, 8, seed=11)
    got = ss.stream_sort_matmul(x, w, policy=policy, acc_bits=16,
                                k_tile=256, bm=4, bn=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), _oracle(x, w, 16, policy, 256))


@pytest.mark.parametrize("policy", ["sorted", "sorted_tiled"])
def test_dispatch_ragged_beyond_resident_k(policy):
    """Through pqs_dot: ragged M/N/K above MAX_RESIDENT_K, jnp == pallas
    (forcing the two-pass kernel) for the dispatch parity matrix bits."""
    for acc_bits in (8, 12, 16):
        x, w = _xw(5, 4500, 9, seed=acc_bits)
        a = pqs_dot(x, w, acc_bits=acc_bits, policy=policy, k_tile=256,
                    backend="jnp")
        b = pqs_dot(x, w, acc_bits=acc_bits, policy=policy, k_tile=256,
                    backend="pallas", block_m=4, block_n=8,
                    sort_impl="twopass")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{policy} @ {acc_bits}b")


@pytest.mark.parametrize("policy", ["sorted", "sorted_tiled"])
def test_one_pass_two_pass_parity(policy):
    """Where the legacy kernel still runs, old and new paths agree."""
    x, w = _xw(8, 512, 16, seed=7)
    old = sm.sort_matmul(x, w, policy=policy, acc_bits=14, k_tile=128,
                         rounds=1, bm=4, bn=8, interpret=True)
    new = ss.stream_sort_matmul(x, w, policy=policy, acc_bits=14,
                                k_tile=128, rounds=1, bm=4, bn=8,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_int32_carrier_matches_int8():
    """pqs_dot carriers may be int32 holding int8 values (qtensor_dot);
    the two-pass path narrows them to int8 slabs — results identical."""
    x, w = _xw(4, 4608, 8, seed=3)
    a = pqs_dot(x, w, acc_bits=16, policy="sorted_tiled", k_tile=256,
                backend="pallas", block_m=4, block_n=8, sort_impl="twopass")
    b = pqs_dot(x.astype(jnp.int32), w.astype(jnp.int32), acc_bits=16,
                policy="sorted_tiled", k_tile=256, backend="pallas",
                block_m=4, block_n=8, sort_impl="twopass")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tile_sums_equal_oracle_sums():
    """Pass 1's raw-product tile sums == the oracle's post-sort sums
    (sorting never changes a tile's sum; int32 addition is exact)."""
    x, w = _xw(4, 256, 8, seed=5)
    sums = ss.tile_sums_matmul(x, w, k_tile=64, bm=4, bn=8, interpret=True)
    prods = overflow.partial_products(w, x)  # (M, N, K)
    tiles = prods.reshape(4, 8, 4, 64)
    np.testing.assert_array_equal(np.asarray(sums),
                                  np.asarray(jnp.sum(tiles, axis=-1)))
    # and reconstructing the oracle's sequence FROM these sums + the
    # shared pairing rule reproduces tiled_sorted_order exactly — the
    # decomposition the two-pass kernel is built on
    from repro.core.sorted_accum import sorted_order

    perm = pair_permutation(jnp.sum(tiles, axis=-1))
    assert perm.shape == (4, 8, 4)
    # even slots take descending sum ranks, odd slots ascending
    sums_np = np.asarray(jnp.sum(tiles, axis=-1))
    np.testing.assert_array_equal(np.asarray(perm[..., 0]),
                                  sums_np.argmax(-1))
    np.testing.assert_array_equal(np.asarray(perm[..., 1]),
                                  sums_np.argmin(-1))
    sorted_tiles = sorted_order(tiles, rounds=1)
    paired = jnp.take_along_axis(sorted_tiles, perm[..., None], axis=-2)
    rebuilt = jnp.swapaxes(
        paired.reshape(4, 8, 2, 2, 64), -1, -2
    ).reshape(4, 8, 256)  # (a0, b0, a1, b1, ...) per tile pair
    ordered = tiled_sorted_order(prods, 64, rounds=1)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(ordered))


def test_sort_impl_resolution_bounds():
    """Kernel-selection logic for compiled calls (no TPU here, so the
    bound logic is tested as a pure function)."""
    # auto: legacy one-pass inside the resident bound, streaming above
    assert ops.resolve_sort_impl(4096, False) == "onepass"
    assert ops.resolve_sort_impl(4097, False) == "twopass"
    assert ops.resolve_sort_impl(32768, False) == "twopass"  # criterion
    assert ops.resolve_sort_impl(ops.MAX_STREAM_K, False) == "twopass"
    # explicit onepass keeps the legacy refusal above MAX_RESIDENT_K
    with pytest.raises(ValueError, match="MAX_RESIDENT_K|compiled-kernel"):
        ops.resolve_sort_impl(8192, False, "onepass")
    # twopass is refused only past the slab budget
    with pytest.raises(ValueError, match="MAX_STREAM_K"):
        ops.resolve_sort_impl(ops.MAX_STREAM_K + 1, False, "twopass")
    # interpret mode is unbounded
    assert ops.resolve_sort_impl(1 << 20, True) == "twopass"
    assert ops.resolve_sort_impl(1 << 20, True, "onepass") == "onepass"
    with pytest.raises(ValueError, match="sort_impl"):
        ops.resolve_sort_impl(64, True, "bogus")


def test_out_of_contract_carrier_raises():
    """Values outside int8 can't ride the int8 slabs: loud, not wrapped."""
    x = jnp.full((2, 64), 300, jnp.int32)
    w = jnp.ones((2, 64), jnp.int32)
    with pytest.raises(ValueError, match="int8 values"):
        ops.policy_matmul(x, w, policy="sorted_tiled", acc_bits=16,
                          k_tile=64, bm=2, bn=2, sort_impl="twopass")


def test_stream_k1_dot():
    """K=1 under sorted: next_pow2(1) == 1 keeps the dot unpadded."""
    x, w = _xw(3, 1, 4, seed=9)
    a = pqs_dot(x, w, acc_bits=8, policy="sorted", backend="jnp")
    b = pqs_dot(x, w, acc_bits=8, policy="sorted", backend="pallas",
                block_m=2, block_n=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
