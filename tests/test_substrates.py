"""Substrate tests: optimizer, data, checkpoint, fault tolerance, serving,
qtensor, and the sharding rule engine."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    cleanup,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core.qtensor import QTensor, quantize_tree, quantize_weight
from repro.data import TokenStream, synth_mnist
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd_momentum
from repro.runtime import (
    FailureInjector,
    StragglerMonitor,
    TrainSupervisor,
    elastic_remesh,
)


# --- optim -------------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(60):
        params, state = opt.update(jax.grad(loss)(params), state, params)
    assert float(loss(params)) < 0.05  # Adam oscillates near the optimum
    assert int(state.step) == 60


def test_sgd_momentum_converges():
    opt = sgd_momentum(lr=0.05, momentum=0.9)
    params = jnp.asarray([4.0])
    state = opt.init(params)
    for _ in range(150):
        g = 2 * params
        params, state = opt.update(g, state, params)
    assert abs(float(params[0])) < 1e-2


def test_weight_decay_skips_1d():
    opt = adamw(lr=0.0, weight_decay=1.0, max_grad_norm=None)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    new, _ = opt.update(zeros, state, params)
    # lr=0 -> nothing moves regardless; use lr>0 to check decay targeting
    opt = adamw(lr=0.1, weight_decay=1.0, max_grad_norm=None)
    state = opt.init(params)
    new, _ = opt.update(zeros, state, params)
    assert float(new["w"][0, 0]) < 1.0  # decayed
    assert float(new["b"][0]) == 1.0  # not decayed


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, 100, warmup_steps=10)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


# --- data --------------------------------------------------------------------


def test_token_stream_deterministic_and_restorable():
    a = TokenStream(vocab_size=100, seq_len=8, batch_size=2, seed=3)
    b1, b2 = a.next_batch(), a.next_batch()
    b = TokenStream(vocab_size=100, seq_len=8, batch_size=2, seed=3)
    b.restore({"step": 1})
    np.testing.assert_array_equal(b.next_batch()["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_token_stream_host_sharding():
    h0 = TokenStream(vocab_size=50, seq_len=4, batch_size=2, host_id=0,
                     num_hosts=2)
    h1 = TokenStream(vocab_size=50, seq_len=4, batch_size=2, host_id=1,
                     num_hosts=2)
    assert not np.array_equal(
        h0.next_batch()["tokens"], h1.next_batch()["tokens"]
    )


def test_classification_dataset():
    ds = synth_mnist(n=512, seed=1)
    assert ds.x.shape == (512, 784) and ds.num_classes == 10
    tr, te = ds.split(0.75)
    assert len(tr.x) == 384 and len(te.x) == 128
    batches = list(tr.batches(64, epochs=1))
    assert len(batches) == 6
    # learnable: a linear probe separates classes better than chance
    xs, ys = tr.x, tr.y
    means = np.stack([xs[ys == c].mean(0) for c in range(10)])
    pred = np.argmax(te.x @ means.T, axis=1)
    assert (pred == te.y).mean() > 0.3  # >> 0.1 chance


# --- checkpoint --------------------------------------------------------------


def _tree():
    return {
        "layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(7),
        "nested": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.bfloat16)],
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        save_checkpoint(d, 5, t)
        assert latest_step(d) == 5
        restored, step = restore_checkpoint(d, jax.tree_util.tree_map(
            jnp.zeros_like, t))
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_shape_check():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, _tree())
        cleanup(d, keep=2)
        assert latest_step(d) == 4
        assert len(os.listdir(d)) == 2
        bad = {"layer": {"w": jnp.zeros((9, 9))}, "step": jnp.asarray(0),
               "nested": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.bfloat16)]}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (10, 20, 30):
            ck.save(s, _tree())
        ck.wait()
        assert latest_step(d) == 30
        assert len(os.listdir(d)) == 2


# --- fault tolerance ---------------------------------------------------------


def test_supervisor_recovers_from_failures():
    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    with tempfile.TemporaryDirectory() as d:
        inj = FailureInjector({4, 9})
        sup = TrainSupervisor(d, step_fn, ckpt_every=2, failure_injector=inj,
                              max_restarts=3)
        state, step = sup.run(
            {"x": jnp.asarray(0.0)}, lambda: jnp.asarray(1.0), num_steps=12
        )
        assert step == 12 and sup.restarts == 2
        assert float(state["x"]) == 12.0  # no batch double-counted w/ ckpts?

    # too many failures -> raises
    with tempfile.TemporaryDirectory() as d:
        inj = FailureInjector({1, 2, 3, 4, 5})
        sup = TrainSupervisor(d, step_fn, ckpt_every=100,
                              failure_injector=inj, max_restarts=2)
        with pytest.raises(RuntimeError):
            sup.run({"x": jnp.asarray(0.0)}, lambda: jnp.asarray(1.0), 10)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(k=2.0, window=8)
    for step in range(8):
        rep = mon.observe(step, {0: 0.10, 1: 0.11, 2: 0.09})
        assert rep.stragglers == []
    rep = mon.observe(9, {0: 0.10, 1: 0.55, 2: 0.09})
    assert rep.stragglers == [1]


def test_elastic_remesh_reshards():
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = {"w": jnp.arange(8, dtype=jnp.float32)}

    def make_mesh(n):
        return jax.make_mesh((n,), ("data",))

    def rule(mesh):
        return {"w": NamedSharding(mesh, P(None))}

    new_state, mesh = elastic_remesh(state, make_mesh, 1, rule)
    np.testing.assert_array_equal(np.asarray(new_state["w"]),
                                  np.asarray(state["w"]))


# --- qtensor -----------------------------------------------------------------


def test_qtensor_roundtrip_error(rng):
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    qt = quantize_weight(w, bits=8)
    err = np.abs(np.asarray(qt.dequant(jnp.float32) - w))
    assert err.max() <= float(qt.scale.max()) / 2 + 1e-6


def test_qtensor_nm_pruned(rng):
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    qt = quantize_weight(w, bits=8, n_keep=4, m=16)
    vals = np.asarray(qt.values).reshape(4, 16, 32)
    nnz = (vals != 0).sum(axis=1)
    assert (nnz <= 4).all()  # N:M along the contraction axis


def test_quantize_tree_selectivity(rng):
    tree = {
        "w": jnp.asarray(rng.normal(size=(512, 256)), jnp.float32),
        "norm": jnp.ones((256,)),
        "small": jnp.ones((4, 4)),
        "ints": jnp.ones((512, 256), jnp.int32),
    }
    out = quantize_tree(tree, bits=8, min_size=1024)
    assert isinstance(out["w"], QTensor)
    assert not isinstance(out["norm"], QTensor)
    assert not isinstance(out["small"], QTensor)
    assert not isinstance(out["ints"], QTensor)


def test_quantized_model_end_to_end():
    """PQS as a serving feature: quantize a whole smoke model's params and
    check the forward still produces close logits."""
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    base = model.forward(params, batch).astype(jnp.float32)
    qparams = quantize_tree(params, bits=8, min_size=1 << 10, min_dim=16)
    quant = model.forward(qparams, batch).astype(jnp.float32)
    # int8 weights: logits close but not identical
    assert float(jnp.max(jnp.abs(base - quant))) < 0.5
    assert not (base == quant).all()


# --- sharding rule engine ----------------------------------------------------


def test_sharding_rules_divisibility():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import param_spec, sanitize

    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    # generic weight: fsdp x model
    spec = param_spec(mesh, "layers/attn/wq", (28, 1536, 1536))
    assert spec == P(None, ("pod", "data"), "model")
    # odd vocab drops fsdp components until divisible
    spec = param_spec(mesh, "embed", (49155, 1536))
    assert spec[0] is None
    # out-type reversed
    spec = param_spec(mesh, "layers/attn/wo", (28, 1536, 1536))
    assert spec == P(None, "model", ("pod", "data"))
    # expert-parallel when divisible
    spec = param_spec(mesh, "layers/moe/w_gate", (32, 16, 4096, 14336))
    assert spec[1] == "model"
    # TP-within-expert fallback when not divisible
    spec = param_spec(mesh, "layers/moe/w_gate", (32, 40, 1536, 512))
    assert spec[1] is None and spec[3] == "model"
    # sanitize drops non-dividing axes
    assert sanitize(mesh, P("model"), (7,)) == P(None)
    assert sanitize(mesh, P(("pod", "data")), (4,)) == P("pod")


def test_serving_engine_continuous_batching():
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, num_slots=2, max_len=32)
    reqs = [
        Request(uid=i, prompt=np.asarray([1, 2, 3], np.int32),
                max_new_tokens=3 + i)
        for i in range(5)
    ]
    eng.drain(reqs)
    assert all(r.done for r in reqs)
    assert [len(r.output) for r in reqs] == [3, 4, 5, 6, 7]
    # greedy sampling: identical prompts produce identical prefixes
    assert reqs[0].output == reqs[1].output[:3]
    # requests that would write past max_len are refused, not corrupted
    with pytest.raises(ValueError):
        eng.submit(Request(uid=99, prompt=np.asarray([1] * 30, np.int32),
                           max_new_tokens=10))
